"""Fig. 10 (beyond the paper) — deletion churn at fixed live size.

The workload none of the paper's figures touch: a long-running service
holding a steady live set under sustained insert/erase cycles.  Each
cycle erases the oldest batch and inserts a fresh one, so the live size
(and load factor) is constant — but tombstones accumulate, the EMPTY
frontier erodes, and every probe walk lengthens (tombstones do not stop
walks; paper §IV-B.5).  This is the degradation WarpSpeed names as the
WarpCore functionality gap, and the trigger the growth-policy layer
(``repro.core.migrate``) compacts on.

Trajectory recorded per cycle (BENCH_7): retrieval throughput over the
live set plus ``cycle`` / ``live_size`` / ``tombstone_density`` /
``load_factor`` / probe-length percentiles.  When the policy's
tombstone-density threshold trips, the cycle is re-measured on the
compacted table and emitted as a second row (``post_compaction=1``,
``recovered_slots=N``) — degradation and recovery sit side by side in
the same trajectory.  Probe lengths are the deterministic signal (wall
time follows but wobbles on shared CPU runners).

Parity gate (the CI smoke assertion): compaction must preserve the live
key/value set bit-exactly.  Every compaction re-retrieves the full live
set on old and new tables and RAISES on any mismatch of values or found
masks; a final sweep additionally asserts every erased key stays absent.
The ``fig10.churn.parity`` row records the gate passing plus the total
recovered-slot count.

Set ``REPRO_BENCH_SMOKE=1`` for the small CI config.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.util import (
    fmt_extras,
    row,
    table_metric_extras,
    time_stats,
    timing_extras,
)
from repro.core import migrate
from repro.core import single_value as sv
from repro.core.common import STATUS_FULL
from repro.obs import metrics

_U = jnp.uint32


class _ChurnCfg:
    def __init__(self, capacity, window, batch, keep, cycles, tomb_density):
        self.capacity = capacity      # table min_capacity
        self.window = window
        self.batch = batch            # erased + inserted per cycle
        self.keep = keep              # live batches (live = keep * batch)
        self.cycles = cycles
        self.policy = migrate.GrowthPolicy(
            max_load_factor=0.97,     # live size is fixed; never grow
            max_tombstone_density=tomb_density)


# live load ~0.86: high enough that tombstone buildup visibly lengthens
# walks, low enough that the table never saturates.  The density threshold
# sits just under the churn equilibrium (~0.14 at this geometry), so the
# trajectory shows several degrading cycles before the first compaction.
FULL = _ChurnCfg(capacity=4096, window=8, batch=512, keep=7, cycles=16,
                 tomb_density=0.13)
SMOKE = _ChurnCfg(capacity=1024, window=8, batch=128, keep=5, cycles=8,
                  tomb_density=0.10)


def _cfg() -> _ChurnCfg:
    return SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else FULL


def _batch_keys(cfg, c):
    return jnp.arange(1 + c * cfg.batch, 1 + (c + 1) * cfg.batch, dtype=_U)


def _value_of(keys):
    return keys ^ _U(0xABCD)


def _live_keys(cfg, next_cycle):
    """The fixed-size live set after ``next_cycle`` churn cycles."""
    return jnp.concatenate([_batch_keys(cfg, c)
                            for c in range(next_cycle,
                                           next_cycle + cfg.keep)])


def _assert_live_set(table, live_keys, dead_keys, where):
    """In-run parity gate: the live set is intact, the dead set absent."""
    vals, found = sv.retrieve(table, live_keys)
    if not bool(jnp.all(found)):
        raise AssertionError(f"fig10 parity [{where}]: live key lost")
    if not bool(jnp.all(vals == _value_of(live_keys))):
        raise AssertionError(f"fig10 parity [{where}]: live value corrupted")
    if dead_keys.shape[0]:
        _, dfound = sv.retrieve(table, dead_keys)
        if bool(jnp.any(dfound)):
            raise AssertionError(f"fig10 parity [{where}]: erased key "
                                 "resurrected")


def run(out=print):
    cfg = _cfg()
    live_size = cfg.keep * cfg.batch
    table = sv.create(cfg.capacity, window=cfg.window)
    for c in range(cfg.keep):
        table, status = sv.insert(table, _batch_keys(cfg, c),
                                  _value_of(_batch_keys(cfg, c)))
        if bool(jnp.any(status == STATUS_FULL)):
            raise AssertionError("fig10 prefill reported FULL")

    ret = jax.jit(lambda t, k: sv.retrieve(t, k))
    rets = jax.jit(lambda t, k: sv.retrieve(t, k, stats=True))

    def measure(t, live, cyc, post, extra=""):
        ts = time_stats(ret, t, live)
        _, _, s = rets(t, live)
        _, tomb, _ = metrics.slot_stats(t.ops, t.store)
        dens = float(tomb) / t.capacity
        name = f"fig10.churn.c{cyc:02d}" + (".post" if post else "")
        out(row(name, ts["seconds"], live_size,
                extra=fmt_extras(cycle=cyc, live_size=live_size,
                                 tombstone_density=dens,
                                 post_compaction=int(post))
                + (("," + extra) if extra else "")
                + "," + timing_extras(ts)
                + "," + table_metric_extras(s, ts["seconds"], live_size,
                                            window=cfg.window)))
        return ts["seconds"]

    compactions = 0
    recovered_total = 0
    last_post_seconds = None
    for cyc in range(cfg.cycles):
        old = _batch_keys(cfg, cyc)
        new = _batch_keys(cfg, cyc + cfg.keep)
        table, erased = sv.erase(table, old)
        if not bool(jnp.all(erased)):
            raise AssertionError("fig10 churn: erase missed a live key")
        table, status = sv.insert(table, new, _value_of(new))
        if bool(jnp.any(status == STATUS_FULL)):
            raise AssertionError("fig10 churn: insert reported FULL")
        live = _live_keys(cfg, cyc + 1)
        measure(table, live, cyc, post=False)

        # policy check after the measurement so the row shows the churned
        # state; force one compaction at the end so every run (incl. the
        # CI smoke config) exercises the parity gate
        candidate = migrate.maybe_migrate(table, cfg.policy)
        if candidate is table and cyc == cfg.cycles - 1 and compactions == 0:
            candidate = migrate.compact(table)
        if candidate is not table:
            _, tomb_before, _ = metrics.slot_stats(table.ops, table.store)
            _, tomb_after, _ = metrics.slot_stats(candidate.ops,
                                                  candidate.store)
            recovered = int(tomb_before) - int(tomb_after)
            # bit-exact live-set parity across the migration
            old_vals, old_found = ret(table, live)
            new_vals, new_found = ret(candidate, live)
            if not (bool(jnp.array_equal(old_found, new_found))
                    and bool(jnp.array_equal(old_vals, new_vals))):
                raise AssertionError("fig10 parity: compaction changed "
                                     "the live set")
            _assert_live_set(candidate, live, old, f"compact@c{cyc}")
            table = candidate
            compactions += 1
            recovered_total += recovered
            last_post_seconds = measure(
                table, live, cyc, post=True,
                extra=fmt_extras(recovered_slots=recovered))

    # final sweep: live set exact, every erased batch absent
    dead = jnp.concatenate([_batch_keys(cfg, c) for c in range(cfg.cycles)])
    _assert_live_set(table, _live_keys(cfg, cfg.cycles), dead, "final")
    if compactions == 0:
        raise AssertionError("fig10: no compaction ran — parity gate "
                             "never exercised")
    out(row("fig10.churn.parity", last_post_seconds, live_size,
            extra="parity=ok," + fmt_extras(
                compactions=compactions,
                recovered_slots=recovered_total,
                live_size=live_size,
                tombstone_density=0.0,
                post_compaction=1)))


if __name__ == "__main__":
    run()
