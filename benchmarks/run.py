"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus per-row extras).  Scale note:
CPU container, batch 2^13-2^14 vs the paper's 2^28 on a GV100; the curves'
*shapes* (who wins where, how throughput scales with density/multiplicity/
shards) are the reproduction target — see EXPERIMENTS.md §Paper-claims.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig5_single_value, fig6_weak_scaling,
                            fig7_multi_value, fig8_metagenomics)
    figures = {
        "fig5": fig5_single_value.run,
        "fig6": fig6_weak_scaling.run,
        "fig7": fig7_multi_value.run,
        "fig8": fig8_metagenomics.run,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived,extra")
    for name, fn in figures.items():
        if only and name != only:
            continue
        t0 = time.time()
        fn(print)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
