"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus per-row extras).  Scale note:
CPU container, batch 2^13-2^14 vs the paper's 2^28 on a GV100; the curves'
*shapes* (who wins where, how throughput scales with density/multiplicity/
shards) are the reproduction target — see EXPERIMENTS.md §Paper-claims.

Usage::

    python -m benchmarks.run [fig5|fig6|fig7|fig8|fig9] [--csv PATH]

``--csv PATH`` mirrors every CSV row (header + data, comments excluded)
into PATH so perf trajectory files (BENCH_*.csv) are produced
reproducibly instead of by shell redirection.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    from benchmarks import (fig5_single_value, fig6_weak_scaling,
                            fig7_multi_value, fig8_metagenomics,
                            fig9_relational)
    figures = {
        "fig5": fig5_single_value.run,
        "fig6": fig6_weak_scaling.run,
        "fig7": fig7_multi_value.run,
        "fig8": fig8_metagenomics.run,
        "fig9": fig9_relational.run,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", choices=sorted(figures),
                    help="run a single figure")
    ap.add_argument("--csv", metavar="PATH",
                    help="also write the CSV rows to PATH")
    args = ap.parse_args(argv)

    sink = open(args.csv, "w") if args.csv else None

    def out(line: str) -> None:
        print(line, flush=True)
        if sink and not line.startswith("#"):
            sink.write(line + "\n")
            sink.flush()

    try:
        out("name,us_per_call,derived,extra")
        for name, fn in figures.items():
            if args.only and name != args.only:
                continue
            t0 = time.time()
            fn(out)
            out(f"# {name} done in {time.time() - t0:.1f}s")
    finally:
        if sink:
            sink.close()


if __name__ == "__main__":
    main()
