"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus per-row extras).  Scale note:
CPU container, batch 2^13-2^14 vs the paper's 2^28 on a GV100; the curves'
*shapes* (who wins where, how throughput scales with density/multiplicity/
shards) are the reproduction target — see EXPERIMENTS.md §Paper-claims.

Usage::

    python -m benchmarks.run [fig5|...|fig9|fig10 ...] [--csv PATH] [--json PATH]

Any number of figures may be named (e.g. ``fig7 fig8``); none means all.

``--csv PATH`` mirrors every CSV row (header + data, comments excluded)
into PATH; ``--json PATH`` writes the parsed rows — name, us_per_call and
ops/s — as a perf-trajectory JSON (BENCH_<pr>.json files), so the
trajectory is machine-readable instead of empty shell redirections.
Set ``REPRO_BENCH_SMOKE=1`` for the small smoke config (CI).
"""

from __future__ import annotations

import argparse
import json
import time


#: non-numeric extras lifted into first-class (string) JSON fields;
#: everything else non-numeric stays in the joined ``extra`` string only
STRING_FIELDS = ("geometry",)


def parse_row(line: str):
    """CSV row -> {name, us_per_call, ops_per_s, extra?} (None if header/na).

    Numeric ``k=v`` extras (``probe_len_p99=4``, ``spread=0.03``, ...) are
    lifted into first-class fields of the JSON row; non-numeric ones stay
    in the joined ``extra`` string only, except the declared
    ``STRING_FIELDS`` (``geometry=p8191xW32``), which are lifted verbatim.
    """
    parts = line.split(",")
    if len(parts) < 3 or parts[0] == "name":
        return None
    try:
        us = float(parts[1])
    except ValueError:
        return None
    entry = {"name": parts[0], "us_per_call": us}
    if parts[2].endswith("Mops/s"):
        entry["ops_per_s"] = float(parts[2][:-len("Mops/s")]) * 1e6
    extras = [p for p in parts[3:] if p]
    if extras:
        entry["extra"] = ",".join(extras)
        for p in extras:
            k, sep, v = p.partition("=")
            if sep and k and k not in entry:
                try:
                    entry[k] = float(v)
                except ValueError:
                    if k in STRING_FIELDS:
                        entry[k] = v
    return entry


def main(argv=None) -> None:
    from benchmarks import (fig5_single_value, fig6_weak_scaling,
                            fig7_multi_value, fig8_metagenomics,
                            fig9_relational, fig10_churn, fig11_stream,
                            fig12_serve)
    figures = {
        "fig5": fig5_single_value.run,
        "fig6": fig6_weak_scaling.run,
        "fig7": fig7_multi_value.run,
        "fig8": fig8_metagenomics.run,
        "fig9": fig9_relational.run,
        "fig10": fig10_churn.run,
        "fig11": fig11_stream.run,
        "fig12": fig12_serve.run,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="*", choices=sorted(figures),
                    help="run only the named figure(s); default: all")
    ap.add_argument("--csv", metavar="PATH",
                    help="also write the CSV rows to PATH")
    ap.add_argument("--json", metavar="PATH",
                    help="write parsed rows (ops/s per figure) to PATH")
    ap.add_argument("--iters", type=int, metavar="N",
                    help="override timing iterations for every row "
                         "(util.ITERS_OVERRIDE)")
    args = ap.parse_args(argv)
    if args.iters:
        from benchmarks import util
        util.ITERS_OVERRIDE = args.iters

    sink = open(args.csv, "w") if args.csv else None
    records: dict[str, list] = {}
    current = [None]

    def out(line: str) -> None:
        print(line, flush=True)
        if sink and not line.startswith("#"):
            sink.write(line + "\n")
            sink.flush()
        entry = parse_row(line)
        if entry is not None and current[0] is not None:
            records.setdefault(current[0], []).append(entry)

    try:
        out("name,us_per_call,derived,extra")
        for name, fn in figures.items():
            if args.only and name not in args.only:
                continue
            current[0] = name
            t0 = time.time()
            fn(out)
            out(f"# {name} done in {time.time() - t0:.1f}s")
    finally:
        if sink:
            sink.close()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {sum(map(len, records.values()))} rows to {args.json}")


if __name__ == "__main__":
    main()
