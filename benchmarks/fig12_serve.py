"""Fig. 12 — elastic sharded serving: QPS, bloom filtering, kill/restore.

The elastic service (``repro.serving.elastic``) is the fig6 distributed
layout run as a *long-lived* process: P simulated shards behind one
donated serve step, per-shard bloom filters killing absent-key probes
before the exchange, and ``core.snapshot`` checkpoints underneath.
This figure measures the serving story end to end:

- ``fig12.serve.traffic`` — sustained mixed insert/lookup/erase traffic
  paced open-loop at a target QPS over 8 shards; rows carry
  ``p50_step_us``/``p99_step_us``, ``qps_target``/``qps_achieved`` and
  the in-graph bloom counters (``bloom_probes``/``bloom_skips``/
  ``bloom_false_positives``), retrace-free by construction.
- ``fig12.lookup.bloom``  — the filter in isolation: an all-absent
  lookup batch, ``skip_frac_absent`` gated >= 0.5 (a filter miss is
  proof of absence, so those queries never consume exchange slots).
- ``fig12.serve.restore`` — the kill -> restore leg: checkpoint through
  the async ``SnapshotWriter`` mid-run, keep serving (post-checkpoint
  mutations must not leak), drop the service, ``elastic.load`` — timed,
  with bit-exact shard-plane parity against the checkpoint-time state.
- ``fig12.serve.parity``  — resume serving on the restored table; the
  row records post-restore live count, lookup parity over the live set
  and the resumed leg's step latency percentiles.
- ``fig12.serve.reshard`` — restore-time elasticity: the same live set
  re-partitioned onto 2x the shards, ownership-exact (``owner_of``
  replayed, ``check_ownership`` asserts).
- ``fig12.bloom.rebuild`` — the compaction hook: erase churn leaves
  filters stale (permissive erase), ``compact_all`` rebuilds them from
  the live set; the row records the advertised-dead fraction before and
  after.

Smoke gates (``REPRO_BENCH_SMOKE=1``): bloom_skips > 0 under traffic,
skip_frac_absent >= 0.5, post-restore bit-exact parity + full live-set
lookup parity, ownership exactness after reshard, staleness drop after
rebuild, zero retraces, zero exchange overflow.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import fmt_extras, row, time_stats, timing_extras
from repro.core import snapshot
from repro.obs.registry import Registry
from repro.obs.trace import Tracer
from repro.serving import elastic

_SMOKE = dict(num_shards=8, capacity_per_shard=2048, batch=256,
              serve_steps=6, resume_steps=3, rate_hz=25.0,
              bloom_bits_per_key=16, slack=2.5)
_FULL = dict(num_shards=8, capacity_per_shard=1 << 14, batch=1024,
             serve_steps=16, resume_steps=6, rate_hz=10.0,
             bloom_bits_per_key=16, slack=2.5)

#: key universes — queries drawn from _ABSENT_BASE are never inserted,
#: so any admitted one is a bloom false positive by construction
_PRESENT_SPAN = 1 << 20
_ABSENT_BASE = 1 << 24


def _cfg():
    return _SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else _FULL


class _TrafficGen:
    """Deterministic mixed traffic with a python-side parity model.

    Each step: insert ``nb`` fresh keys, look up ``nb`` keys (half drawn
    from the inserted-so-far set, half from the disjoint absent
    universe), erase ``nb // 4`` previously-inserted keys.  ``live``
    tracks inserted-minus-erased for the restore-parity legs.
    """

    def __init__(self, nb: int, seed: int):
        self.nb = nb
        self.rng = np.random.default_rng(seed)
        self.live: set[int] = set()

    def batches(self, steps: int):
        nb, rng = self.nb, self.rng
        for _ in range(steps):
            ins = rng.integers(1, _PRESENT_SPAN, nb).astype(np.uint32)
            vals = rng.integers(0, 2**31, nb).astype(np.uint32)
            pool = np.fromiter(self.live, np.uint32) if self.live else ins
            present = rng.choice(pool, nb // 2)
            absent = rng.integers(_ABSENT_BASE, _ABSENT_BASE + _PRESENT_SPAN,
                                  nb - nb // 2).astype(np.uint32)
            get = np.concatenate([present, absent])
            dels = rng.choice(pool, nb // 4)
            self.live.update(int(k) for k in ins)
            self.live.difference_update(int(k) for k in dels)
            yield (jnp.asarray(ins), jnp.asarray(vals),
                   jnp.asarray(get), jnp.asarray(dels))


def _live_lookup_parity(st, live: set[int], what: str) -> None:
    """Every key the python model says is live must be found (chunked)."""
    keys = np.fromiter(live, np.uint32)
    jl = jax.jit(elastic.lookup)
    chunk = 4096
    # pad by cycling the WHOLE live set: padding with one repeated key
    # would route every pad slot to a single shard and overflow the
    # padded exchange (cap assumes roughly uniform owners)
    n_chunks = max(1, -(-len(keys) // chunk))
    padded = np.resize(keys, n_chunks * chunk)
    for lo in range(0, len(padded), chunk):
        part = padded[lo:lo + chunk]
        _, found, stats = jl(st, jnp.asarray(part))
        if int(stats["overflow"]):
            raise AssertionError(f"{what}: lookup exchange overflowed")
        if not bool(jnp.all(found)):
            raise AssertionError(
                f"{what}: {int(jnp.sum(~found))} live keys missing "
                "after restore — parity broken")


def run(out=print):
    p = _cfg()
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    nb, ns = p["batch"], p["serve_steps"]
    ops_per_step = 2 * nb + nb // 4
    st = elastic.create(p["num_shards"], p["capacity_per_shard"],
                        bloom_bits_per_key=p["bloom_bits_per_key"],
                        slack=p["slack"])

    # warmup the one serve-step compile on a throwaway same-geometry table
    warm = elastic.create(p["num_shards"], p["capacity_per_shard"],
                          bloom_bits_per_key=p["bloom_bits_per_key"],
                          slack=p["slack"])
    gen_w = _TrafficGen(nb, seed=99)
    warm, _, _, _ = elastic.serve_traffic(warm, gen_w.batches(1))
    del warm

    # ---- sustained traffic at target QPS --------------------------------
    gen = _TrafficGen(nb, seed=0)
    tracer = Tracer(registry=Registry())
    t0 = _time.perf_counter()
    st, tracer, steps, totals = elastic.serve_traffic(
        st, gen.batches(ns), rate_hz=p["rate_hz"], tracer=tracer)
    wall = _time.perf_counter() - t0
    pct = tracer.percentiles("elastic.serve_step")
    if smoke and totals["skips"] <= 0:
        raise AssertionError("bloom filter never skipped a probe under "
                             "mixed traffic — front-end not wired")
    if pct["p99_s"] <= 0:
        raise AssertionError("no p99 recorded for the serve leg")
    qps_target = p["rate_hz"] * ops_per_step
    out(row("fig12.serve.traffic", pct["sum_s"], steps * ops_per_step,
            extra=fmt_extras(steps_per_s=steps / pct["sum_s"],
                             p50_step_us=pct["p50_s"] * 1e6,
                             p99_step_us=pct["p99_s"] * 1e6,
                             qps_target=qps_target,
                             qps_achieved=steps * ops_per_step / wall,
                             bloom_probes=totals["probes"],
                             bloom_skips=totals["skips"],
                             bloom_false_positives=totals["false_positives"],
                             hits=totals["hits"], retraces=0)))

    # ---- the filter in isolation: absent-key batch ----------------------
    rng = np.random.default_rng(7)
    absent = jnp.asarray(rng.integers(
        _ABSENT_BASE, _ABSENT_BASE + _PRESENT_SPAN, nb).astype(np.uint32))
    jl = jax.jit(elastic.lookup)
    _, found_a, stats_a = jl(st, absent)          # warm + gate
    skip_frac = float(stats_a["skips"]) / float(stats_a["probes"])
    if skip_frac < 0.5:
        raise AssertionError(
            f"bloom skipped only {skip_frac:.2%} of absent-key probes "
            "(>= 50% required) — filters stale or mis-wired")
    if bool(jnp.any(found_a)):
        raise AssertionError("absent key reported found")
    ts = time_stats(lambda: jl(st, absent)[2]["skips"])
    out(row("fig12.lookup.bloom", ts["seconds"], nb,
            extra=fmt_extras(skip_frac_absent=skip_frac,
                             false_positives=int(stats_a["false_positives"]))
            + "," + timing_extras(ts)))

    # ---- kill -> restore: async checkpoint, mutate, drop, reload --------
    ckpt = tempfile.mkdtemp(prefix="fig12_ckpt_")
    try:
        t0 = _time.perf_counter()
        with snapshot.SnapshotWriter() as w:
            elastic.save(st, ckpt, writer=w)
            w.flush()
        save_s = _time.perf_counter() - t0
        live_at_ckpt = set(gen.live)
        ref_leaves = jax.device_get(jax.tree_util.tree_leaves(st.shards))
        count_at_ckpt = int(elastic.count(st))

        # keep serving AFTER the checkpoint — these mutations must not
        # leak into the restored state (the crash-consistency contract)
        st, _, _, _ = elastic.serve_traffic(st, gen.batches(2))
        del st  # the kill

        t0 = _time.perf_counter()
        st2 = elastic.load(ckpt)
        restore_s = _time.perf_counter() - t0
        got_leaves = jax.device_get(jax.tree_util.tree_leaves(st2.shards))
        for a, b in zip(ref_leaves, got_leaves):
            if a.dtype != b.dtype or a.shape != b.shape \
                    or not np.array_equal(a, b):
                raise AssertionError("restored shard plane is not bit-exact "
                                     "against the checkpoint-time state")
        if int(elastic.count(st2)) != count_at_ckpt:
            raise AssertionError("restored live count drifted")
        out(row("fig12.serve.restore", restore_s, count_at_ckpt,
                extra=fmt_extras(save_s=save_s, parity=1,
                                 shards=st2.num_shards,
                                 live_size=count_at_ckpt)))

        # ---- resume on the restored table + full live-set parity --------
        _live_lookup_parity(st2, live_at_ckpt, "fig12.serve.parity")
        gen2 = _TrafficGen(nb, seed=1)
        gen2.live = set(live_at_ckpt)
        tracer2 = Tracer(registry=Registry())
        st2, tracer2, rsteps, rtotals = elastic.serve_traffic(
            st2, gen2.batches(p["resume_steps"]), rate_hz=p["rate_hz"],
            tracer=tracer2)
        rpct = tracer2.percentiles("elastic.serve_step")
        out(row("fig12.serve.parity", rpct["sum_s"], rsteps * ops_per_step,
                extra=fmt_extras(parity=1, live_size=count_at_ckpt,
                                 p50_step_us=rpct["p50_s"] * 1e6,
                                 p99_step_us=rpct["p99_s"] * 1e6,
                                 bloom_skips=rtotals["skips"], retraces=0)))

        # ---- elastic restore: 2x the shards, ownership-exact ------------
        t0 = _time.perf_counter()
        st4 = elastic.load(ckpt, num_shards=2 * p["num_shards"])
        reshard_s = _time.perf_counter() - t0
        elastic.check_ownership(st4)
        if int(elastic.count(st4)) != count_at_ckpt:
            raise AssertionError("reshard dropped live entries")
        out(row("fig12.serve.reshard", reshard_s, count_at_ckpt,
                extra=fmt_extras(shards_from=p["num_shards"],
                                 shards_to=2 * p["num_shards"],
                                 ownership=1, live_size=count_at_ckpt)))
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)

    # ---- compaction rebuild: filter staleness recovers ------------------
    pool = np.fromiter(gen2.live, np.uint32)
    dead = pool[:min(len(pool) // 2, 4 * nb)]
    st2, _ = elastic.erase(st2, jnp.asarray(dead))
    gen2.live.difference_update(int(k) for k in dead)

    def _stale_frac(s):
        from repro.core import bloom, hashing
        from repro.core import single_value as sv
        keys_n = sv.normalize_key_batch(jnp.asarray(dead), s.key_words,
                                        "keys")
        words = sv.key_hash_word(keys_n)
        owners = hashing.hash_owner(words, s.num_shards)
        admit = bloom.contains_stack(
            s.filters[0], jnp.stack([f.bits for f in s.filters]),
            owners, words)
        return float(jnp.mean(admit.astype(jnp.float32)))

    before = _stale_frac(st2)
    ts_reb = time_stats(lambda: jax.block_until_ready(
        jnp.stack([f.bits for f in elastic.compact_all(st2).filters])),
        warmup=1, iters=2 if smoke else 3)
    st2 = elastic.compact_all(st2)
    after = _stale_frac(st2)
    if smoke and not after < before:
        raise AssertionError(
            f"filter staleness did not drop over rebuild "
            f"({before:.2f} -> {after:.2f})")
    _live_lookup_parity(st2, gen2.live, "fig12.bloom.rebuild")
    out(row("fig12.bloom.rebuild", ts_reb["seconds"],
            int(elastic.count(st2)),
            extra=fmt_extras(stale_frac_before=before,
                             stale_frac_after=after,
                             live_size=int(elastic.count(st2)))
            + "," + timing_extras(ts_reb)))


if __name__ == "__main__":
    run()
