"""Fig. 8 — metagenomic reference-database construction.

Pipeline (paper §V-C): genomes -> canonical k-mers (Pallas minhash kernel)
-> minhash subsample -> BucketListHashTable insert.  Baselines: the same
pipeline into the OA multi-value table, and a pure-python dict build
(the Kraken2/MetaCache CPU stand-in for the orders-of-magnitude
comparison).  Derived figure: k-mers indexed per second + speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import (
    row,
    table_metric_extras,
    time_fn,
    time_stats,
    timing_extras,
)
from repro.core import bucket_list as bl
from repro.core import multi_value as mv
from repro.kernels.minhash import ops as mh
from repro.kernels.minhash.ref import INVALID

K, S = 16, 64
N_GENOMES, GENOME_LEN = 4, 20000


def _sketches():
    rng = np.random.default_rng(0)
    genomes = rng.integers(0, 4, (N_GENOMES, GENOME_LEN)).astype(np.uint8)
    sk = np.asarray(mh.sketch_reads(jnp.asarray(genomes), k=K, s=2048))
    keys, vals = [], []
    for gid in range(N_GENOMES):
        h = sk[gid][sk[gid] != INVALID]
        keys.append(np.minimum(h, 0xFFFFFFFD))
        vals.append(np.full(len(h), gid, np.uint32))
    return (jnp.asarray(np.concatenate(keys)),
            jnp.asarray(np.concatenate(vals)), genomes)


def run(out=print):
    keys, vals, genomes = _sketches()
    n = int(keys.shape[0])

    # k-mer generation throughput (the kernel front half)
    sec_kmer = time_fn(
        lambda g: mh.sketch_reads(g, k=K, s=2048), jnp.asarray(genomes))
    out(row("fig8.sketch.minhash-kernel", sec_kmer,
            N_GENOMES * (GENOME_LEN - K + 1)))

    # DB build: bucket list (the paper's winner) — batched engine build,
    # with the sequential-scan reference as a parity-gated comparison row
    t0 = bl.create(2 * n, pool_capacity=4 * n, s0=1, growth=1.1)
    ins_bl = jax.jit(lambda t, k, v: bl.insert(t, k, v))
    tbl = time_stats(ins_bl, t0, keys, vals)
    sec_bl = tbl["seconds"]
    t0s = bl.create(2 * n, pool_capacity=4 * n, s0=1, growth=1.1,
                    backend="scan")
    ins_bls = jax.jit(lambda t, k, v: bl.insert(t, k, v))
    sec_bls = time_fn(ins_bls, t0s, keys, vals)
    tb, stb = ins_bl(t0, keys, vals)
    ts, sts = ins_bls(t0s, keys, vals)
    from benchmarks.fig7_multi_value import _assert_bl_parity
    _assert_bl_parity(tb, ts, stb, sts)
    _, _, blstats = jax.jit(lambda t, k, v: bl.insert(t, k, v, stats=True))(
        t0, keys, vals)
    out(row("fig8.build.wc-bl", sec_bl, n,
            extra=f"speedup-vs-scan={sec_bls / sec_bl:.2f}x,parity=ok,"
                  + table_metric_extras(blstats, sec_bl, n,
                                        window=tb.key_store.window) + ","
                  + timing_extras(tbl)))
    out(row("fig8.build.wc-bl.scan", sec_bls, n))

    # DB build: OA multi-value
    t1 = mv.create(int(n / 0.8), window=32)
    ins_mv = jax.jit(lambda t, k, v: mv.insert(t, k, v))
    tmv = time_stats(ins_mv, t1, keys, vals)
    sec_mv = tmv["seconds"]
    _, _, mvstats = jax.jit(lambda t, k, v: mv.insert(t, k, v, stats=True))(
        t1, keys, vals)
    out(row("fig8.build.wc-oa", sec_mv, n,
            extra=table_metric_extras(mvstats, sec_mv, n, window=32) + ","
                  + timing_extras(tmv)))

    # CPU python dict build (MetaCache/Kraken2 stand-in)
    kl = np.asarray(keys).tolist()
    vl = np.asarray(vals).tolist()
    t0_ = time.perf_counter()
    d: dict = {}
    for k, v in zip(kl, vl):
        d.setdefault(k, []).append(v)
    sec_py = time.perf_counter() - t0_
    out(row("fig8.build.pydict", sec_py, n,
            extra=f"speedup_bl={sec_py / sec_bl:.1f}x"))


if __name__ == "__main__":
    run()
