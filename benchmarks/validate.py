"""Validate a BENCH_*.json against ``benchmarks/schema.json``.

Dependency-free (no jsonschema in the container): implements exactly the
subset of JSON Schema the checked-in schema uses — ``type`` (object /
array / string / number / boolean), ``required``, ``properties``,
``additionalProperties`` (as a sub-schema), ``items``, ``minimum`` /
``maximum``.  The CI smoke step runs this over the BENCH json produced by
the fig5 smoke row, so a benchmark emitting a malformed row (string where
a lifted numeric extra belongs, negative timing, load factor > 1) fails
the build instead of silently polluting the perf trajectory.

Usage::

    python -m benchmarks.validate BENCH_6.json [BENCH_7.json ...] [--schema PATH]

Any number of bench files may be named; each is validated independently.
Exit status 0 iff all are valid; errors are printed one per line as
``<json-path>: <message>``.
"""

from __future__ import annotations

import argparse
import json
import os

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "boolean": bool,
}


def validate(value, schema: dict, path: str = "$") -> list[str]:
    """Errors for ``value`` under ``schema`` (empty list == valid)."""
    errs: list[str] = []
    t = schema.get("type")
    if t is not None:
        py = _TYPES[t]
        # bool is an int subclass; don't let True pass as a number
        if isinstance(value, bool) and t != "boolean":
            errs.append(f"{path}: expected {t}, got boolean")
            return errs
        if not isinstance(value, py):
            errs.append(f"{path}: expected {t}, "
                        f"got {type(value).__name__}")
            return errs

    if isinstance(value, (int, float)) and not isinstance(value, bool):
        if "minimum" in schema and value < schema["minimum"]:
            errs.append(f"{path}: {value} < minimum {schema['minimum']}")
        if "maximum" in schema and value > schema["maximum"]:
            errs.append(f"{path}: {value} > maximum {schema['maximum']}")

    if isinstance(value, dict):
        for req in schema.get("required", ()):
            if req not in value:
                errs.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        addl = schema.get("additionalProperties")
        for k, v in value.items():
            sub = f"{path}.{k}"
            if k in props:
                errs.extend(validate(v, props[k], sub))
            elif isinstance(addl, dict):
                errs.extend(validate(v, addl, sub))
            elif addl is False:
                errs.append(f"{sub}: additional key not allowed")

    if isinstance(value, list) and "items" in schema:
        for i, v in enumerate(value):
            errs.extend(validate(v, schema["items"], f"{path}[{i}]"))

    return errs


def default_schema_path() -> str:
    return os.path.join(os.path.dirname(__file__), "schema.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="+", help="BENCH_*.json file(s) to validate")
    ap.add_argument("--schema", default=default_schema_path())
    args = ap.parse_args(argv)
    with open(args.schema) as f:
        schema = json.load(f)
    failed = False
    for bench_path in args.bench:
        with open(bench_path) as f:
            bench = json.load(f)
        errs = validate(bench, schema)
        for e in errs:
            print(f"{bench_path}: {e}")
        n_rows = sum(map(len, bench.values())) if isinstance(bench, dict) else 0
        if errs:
            failed = True
        else:
            print(f"# {bench_path}: {n_rows} rows valid against {args.schema}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
