"""Fig. 6 — weak scaling of distributed-mode insertion with runtime
breakdown (partition / exchange / insert) and efficiency.

The paper scales 1..8 GPUs on a DGX-1 with 2 GB per GPU; we scale 1..8 host
devices with a fixed per-shard batch (weak scaling), reporting the same
phase breakdown.  Runs in a subprocess so only this benchmark sees 8
devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_WORKER = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import distributed as dist
from repro.core import single_value as sv
from repro.core.compat import axis_size_compat, make_mesh_compat, shard_map_compat

def bench(num_shards, per_shard):
    mesh = make_mesh_compat((num_shards,), ('x',))
    table = dist.create_sharded(mesh, 'x', per_shard * 2, window=32)
    n = num_shards * per_shard
    keys = jnp.asarray(np.random.default_rng(0).permutation(
        np.arange(1, n + 1, dtype=np.uint32)))
    vals = keys * 3

    spec = jax.tree.map(lambda _: P('x'), table)

    # phase 1+2: partition (multisplit) + all_to_all exchange only
    def route(k, v):
        num = axis_size_compat('x')
        k2 = sv.normalize_words(k, 1, 'k')
        owners = dist.owner_of(k2, num, 1)
        cap = int(np.ceil(k.shape[0] / num * 2.0))
        plan = dist.make_plan(owners, num, cap)
        kb = dist.scatter_to_buffer(plan, k2, num)
        vb = dist.scatter_to_buffer(plan, sv.normalize_words(v, 1, 'v'), num)
        return dist.exchange(kb, 'x'), dist.exchange(vb, 'x')

    froute = jax.jit(shard_map_compat(route, mesh,
                                      in_specs=(P('x'), P('x')),
                                      out_specs=(P('x'), P('x'))))
    fall = jax.jit(lambda t, k, v: dist.shard_insert(mesh, 'x', t, k, v))

    def t(f, *a, iters=3):
        jax.block_until_ready(f(*a))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter(); jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        med = ts[len(ts) // 2]
        return med, (med - ts[0]) / med if med > 0 else 0.0

    t_route, _ = t(froute, keys, vals)
    t_total, spread = t(fall, table, keys, vals)
    return dict(shards=num_shards, n=n, t_route=t_route,
                t_insert=max(t_total - t_route, 0.0), t_total=t_total,
                spread=spread)

per_shard = 1 << 12
out = [bench(s, per_shard) for s in (1, 2, 4, 8)]
print("JSON:" + json.dumps(out))
"""


def run(out=print):
    env = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _WORKER], capture_output=True,
                       text=True, timeout=900, env=env)
    if r.returncode != 0:
        out(f"fig6.FAILED,{r.stderr[-200:]}")
        return
    data = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("JSON:")][0][5:])
    t1 = data[0]["t_total"]
    for d in data:
        # all "devices" share ONE physical core here, so ideal weak scaling
        # is t_N = N * t_1; eff_1core = N*t1/tN isolates the per-shard
        # overhead added by multisplit + all_to_all (the paper's Fig-6
        # breakdown), which IS measurable without real chips.
        eff = d["shards"] * t1 / d["t_total"]
        route_frac = d["t_route"] / d["t_total"]
        spread = d.get("spread", 0.0)
        out(f"fig6.insert.shards{d['shards']},{d['t_total']*1e6:.0f},"
            f"{d['n']/d['t_total']/1e6:.3f}Mops/s,"
            f"route_frac={route_frac:.2f},eff_1core={eff:.2f},"
            f"spread={spread:.4g},noisy={int(spread > 0.20)}")


if __name__ == "__main__":
    run()
