"""Fig. 5 — single-value bulk insert/retrieve throughput vs storage density.

Contestants (paper §V-A, adapted):
  wc-cops     : WarpCore COPS (window 32, DH outer + windowed LP inner)
  lp-scalar   : one-slot linear probing (cuDF-style baseline)
  dh-scalar   : one-slot double hashing (cuDPP-style baseline)
  pydict      : python dict, the CPU reference (TBB stand-in)

The paper's claim validated here is the SHAPE: COPS throughput stays flat
to rho = 0.97 while scalar LP degrades sharply past 0.8 (primary
clustering lengthens probe chains).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.configs.warpcore import CONFIG
from repro.core import single_value as sv

VARIANTS = {
    "wc-cops": dict(window=32, scheme="cops"),
    "lp-scalar": dict(window=1, scheme="linear"),
    "dh-scalar": dict(window=1, scheme="cops"),
}


def _pairs(n, rng):
    keys = rng.choice(np.arange(1, 16 * n, dtype=np.uint32), size=n,
                      replace=False)
    return jnp.asarray(keys), jnp.asarray(keys ^ np.uint32(0xABCD))


def run(out=print):
    n = CONFIG.n_pairs
    rng = np.random.default_rng(0)
    keys, vals = _pairs(n, rng)
    for density in CONFIG.densities:
        capacity = int(n / density)
        for name, kw in VARIANTS.items():
            t0 = sv.create(capacity, max_probes=4096, **kw)
            ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
            sec_i = time_fn(ins, t0, keys, vals)
            t1, status = ins(t0, keys, vals)
            ok = float(jnp.mean((status == 0).astype(jnp.float32)))
            ret = jax.jit(lambda t, k: sv.retrieve(t, k))
            sec_r = time_fn(ret, t1, keys)
            out(row(f"fig5.insert.{name}.rho{density}", sec_i, n,
                    extra=f"ok={ok:.3f}"))
            out(row(f"fig5.retrieve.{name}.rho{density}", sec_r, n))
        # python dict reference (insert+retrieve once per density)
        if density == CONFIG.densities[0]:
            import time as _t
            kl = np.asarray(keys).tolist()
            vl = np.asarray(vals).tolist()
            t0_ = _t.perf_counter()
            d = dict(zip(kl, vl))
            sec = _t.perf_counter() - t0_
            out(row("fig5.insert.pydict", sec, n))
            t0_ = _t.perf_counter()
            s = 0
            for k in kl:
                s += d[k]
            out(row("fig5.retrieve.pydict", _t.perf_counter() - t0_, n))


if __name__ == "__main__":
    run()
