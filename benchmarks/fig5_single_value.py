"""Fig. 5 — single-value bulk insert/retrieve throughput vs storage density.

Contestants (paper §V-A, adapted):
  wc-cops     : WarpCore COPS (window 32, DH outer + windowed LP inner)
  lp-scalar   : one-slot linear probing (cuDF-style baseline)
  dh-scalar   : one-slot double hashing (cuDPP-style baseline)
  pydict      : python dict, the CPU reference (TBB stand-in)

The paper's claim validated here is the SHAPE: COPS throughput stays flat
to rho = 0.97 while scalar LP degrades sharply past 0.8 (primary
clustering lengthens probe chains).

The ``bulk-vs-scan`` section compares the vectorized bulk-build engine
(repro.core.bulk — the default ``backend="jax"`` insert path) against the
sequential ``backend="scan"`` reference at n = 2^14: the PR-trajectory
number for the scatter-arbitration build (its speedup is recorded in
BENCH_*.json via ``--json``).

Set ``REPRO_BENCH_SMOKE=1`` to run the small SMOKE config (CI smoke step).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.configs.warpcore import CONFIG, SMOKE
from repro.core import single_value as sv

VARIANTS = {
    "wc-cops": dict(window=32, scheme="cops"),
    "lp-scalar": dict(window=1, scheme="linear"),
    "dh-scalar": dict(window=1, scheme="cops"),
}


def _cfg():
    return SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else CONFIG


def _pairs(n, rng):
    keys = rng.choice(np.arange(1, 16 * n, dtype=np.uint32), size=n,
                      replace=False)
    return jnp.asarray(keys), jnp.asarray(keys ^ np.uint32(0xABCD))


def run(out=print):
    cfg = _cfg()
    n = cfg.n_pairs
    rng = np.random.default_rng(0)
    keys, vals = _pairs(n, rng)
    for density in cfg.densities:
        capacity = int(n / density)
        for name, kw in VARIANTS.items():
            t0 = sv.create(capacity, max_probes=4096, **kw)
            ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
            sec_i = time_fn(ins, t0, keys, vals)
            t1, status = ins(t0, keys, vals)
            ok = float(jnp.mean((status == 0).astype(jnp.float32)))
            ret = jax.jit(lambda t, k: sv.retrieve(t, k))
            sec_r = time_fn(ret, t1, keys)
            out(row(f"fig5.insert.{name}.rho{density}", sec_i, n,
                    extra=f"ok={ok:.3f}"))
            out(row(f"fig5.retrieve.{name}.rho{density}", sec_r, n))
        # python dict reference (insert+retrieve once per density)
        if density == cfg.densities[0]:
            import time as _t
            kl = np.asarray(keys).tolist()
            vl = np.asarray(vals).tolist()
            t0_ = _t.perf_counter()
            d = dict(zip(kl, vl))
            sec = _t.perf_counter() - t0_
            out(row("fig5.insert.pydict", sec, n))
            t0_ = _t.perf_counter()
            s = 0
            for k in kl:
                s += d[k]
            out(row("fig5.retrieve.pydict", _t.perf_counter() - t0_, n))

    # bulk engine vs sequential-scan reference (PR-trajectory comparison):
    # same table geometry, same keys — the only difference is the insert
    # path.  Interleaved timing halves the noise on shared CPU runners.
    rho = cfg.densities[0]
    capacity = int(n / rho)
    t_bulk = sv.create(capacity, max_probes=4096, window=32)
    t_scan = sv.create(capacity, max_probes=4096, window=32, backend="scan")
    ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
    jax.block_until_ready(ins(t_bulk, keys, vals))
    jax.block_until_ready(ins(t_scan, keys, vals))
    import time as _t
    tb, ts = [], []
    for _ in range(9):
        a = _t.perf_counter()
        jax.block_until_ready(ins(t_bulk, keys, vals))
        tb.append(_t.perf_counter() - a)
        a = _t.perf_counter()
        jax.block_until_ready(ins(t_scan, keys, vals))
        ts.append(_t.perf_counter() - a)
    # best-of (timeit-style): on a shared 2-core runner the minimum is the
    # interference-free estimate; applied symmetrically to both paths.
    sec_b, sec_s = min(tb), min(ts)
    out(row(f"fig5.insert.wc-cops.bulk.rho{rho}", sec_b, n,
            extra=f"speedup-vs-scan={sec_s / sec_b:.2f}x"))
    out(row(f"fig5.insert.wc-cops.scan.rho{rho}", sec_s, n))


if __name__ == "__main__":
    run()
