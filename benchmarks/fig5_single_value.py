"""Fig. 5 — single-value bulk insert/retrieve throughput vs storage density.

Contestants (paper §V-A, adapted):
  wc-cops     : WarpCore COPS (window 32, DH outer + windowed LP inner)
  lp-scalar   : one-slot linear probing (cuDF-style baseline)
  dh-scalar   : one-slot double hashing (cuDPP-style baseline)
  pydict      : python dict, the CPU reference (TBB stand-in)

The paper's claim validated here is the SHAPE: COPS throughput stays flat
to rho = 0.97 while scalar LP degrades sharply past 0.8 (primary
clustering lengthens probe chains).

The ``bulk-vs-scan`` section compares the vectorized bulk-build engine
(repro.core.bulk — the default ``backend="jax"`` insert path) against the
sequential ``backend="scan"`` reference at n = 2^14: the PR-trajectory
number for the scatter-arbitration build (its speedup is recorded in
BENCH_*.json via ``--json``).

The ``fused-vs-twowalk`` retrieval section does the same for the fused
bulk-retrieval engine (repro.core.bulk_retrieve): multi-value
``retrieve_all`` with the single fused walk (``backend="jax"``) against
the paper's count-pass + gather-re-probe two-walk reference
(``backend="scan"``), same table, same probe batch.  The comparison
FAILS (raises) on any fused/scan output mismatch, so every benchmark run
— including the CI smoke step — doubles as a parity gate.

Set ``REPRO_BENCH_SMOKE=1`` to run the small SMOKE config (CI smoke step).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import (
    fmt_extras,
    row,
    table_metric_extras,
    time_fn,
    time_stats,
    timing_extras,
)
from repro.configs.warpcore import CONFIG, SMOKE
from repro.core import multi_value as mv
from repro.core import single_value as sv

VARIANTS = {
    "wc-cops": dict(window=32, scheme="cops"),
    "lp-scalar": dict(window=1, scheme="linear"),
    "dh-scalar": dict(window=1, scheme="cops"),
}


def _cfg():
    return SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else CONFIG


def _pairs(n, rng):
    keys = rng.choice(np.arange(1, 16 * n, dtype=np.uint32), size=n,
                      replace=False)
    return jnp.asarray(keys), jnp.asarray(keys ^ np.uint32(0xABCD))


def run(out=print):
    cfg = _cfg()
    n = cfg.n_pairs
    rng = np.random.default_rng(0)
    keys, vals = _pairs(n, rng)
    for density in cfg.densities:
        capacity = int(n / density)
        for name, kw in VARIANTS.items():
            t0 = sv.create(capacity, max_probes=4096, **kw)
            ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
            ti = time_stats(ins, t0, keys, vals)
            sec_i = ti["seconds"]
            t1, status = ins(t0, keys, vals)
            ok = float(jnp.mean((status == 0).astype(jnp.float32)))
            ret = jax.jit(lambda t, k: sv.retrieve(t, k))
            tr = time_stats(ret, t1, keys)
            sec_r = tr["seconds"]
            extra_i = fmt_extras(ok=ok) + "," + timing_extras(ti)
            extra_r = timing_extras(tr)
            if name == "wc-cops":
                # roofline-normalized table metrics from a stats=True run
                # (separate call — the timed call stays stats=False)
                _, _, istats = jax.jit(
                    lambda t, k, v: sv.insert(t, k, v, stats=True))(
                        t0, keys, vals)
                _, _, rstats = jax.jit(
                    lambda t, k: sv.retrieve(t, k, stats=True))(t1, keys)
                extra_i += "," + table_metric_extras(
                    istats, sec_i, n, window=kw["window"])
                extra_r += "," + table_metric_extras(
                    rstats, sec_r, n, window=kw["window"])
            out(row(f"fig5.insert.{name}.rho{density}", sec_i, n,
                    extra=extra_i))
            out(row(f"fig5.retrieve.{name}.rho{density}", sec_r, n,
                    extra=extra_r))
        # python dict reference (insert+retrieve once per density)
        if density == cfg.densities[0]:
            import time as _t
            kl = np.asarray(keys).tolist()
            vl = np.asarray(vals).tolist()
            t0_ = _t.perf_counter()
            d = dict(zip(kl, vl))
            sec = _t.perf_counter() - t0_
            out(row("fig5.insert.pydict", sec, n))
            t0_ = _t.perf_counter()
            s = 0
            for k in kl:
                s += d[k]
            out(row("fig5.retrieve.pydict", _t.perf_counter() - t0_, n))

    # --- bucketed two-choice storage lane (high-load-factor fix) -----------
    # Fixed-width buckets probed as a vector lane: every key has exactly two
    # candidate buckets, so the probe walk is length <= 2 at ANY load factor
    # and retrieve throughput stays flat to rho = 0.95 (the collapse the
    # classic walks suffer).  Each row runs the jax engine against the scan
    # reference on the same keys as an in-run BIT-EXACT parity gate
    # (statuses, hits, retrieved values) — the run raises on any mismatch —
    # and records the bucket ``geometry`` (prime rows x window lanes) plus
    # ``bits_per_slot`` (32 plain; < 32 on the quotient lane, where slots
    # hold ``q*2 + choice`` remainders instead of raw keys).
    bucketed_ret = {}
    for quotient in (False, True):
        lane = "wc-bucketedq" if quotient else "wc-bucketed"
        for density in (0.5, 0.95):
            capacity = int(n / density)
            tj = sv.create(capacity, kind="bucketed", quotient=quotient,
                           window=32)
            tsc = sv.create(capacity, kind="bucketed", quotient=quotient,
                            window=32, backend="scan")
            ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
            ti = time_stats(ins, tj, keys, vals)
            t1, st_j = ins(tj, keys, vals)
            t1s, st_s = sv.insert(tsc, keys, vals)
            ret = jax.jit(lambda t, k: sv.retrieve(t, k))
            tr = time_stats(ret, t1, keys)
            rv_j, hit_j = ret(t1, keys)
            rv_s, hit_s = sv.retrieve(t1s, keys)
            same = (bool(jnp.array_equal(st_j, st_s))
                    and bool(jnp.array_equal(hit_j, hit_s))
                    and bool(jnp.array_equal(jnp.where(hit_j, rv_j, 0),
                                             jnp.where(hit_s, rv_s, 0))))
            if not same:
                raise AssertionError(
                    f"fig5 bucketed jax/scan parity FAILED "
                    f"({lane} rho{density})")
            ok = float(jnp.mean((st_j <= 1).astype(jnp.float32)))
            geom = f"p{tj.num_rows}xW{tj.window}"
            _, _, rstats = jax.jit(
                lambda t, k: sv.retrieve(t, k, stats=True))(t1, keys)
            base = "parity=ok," + fmt_extras(
                geometry=geom, bits_per_slot=tj.ops.bits_per_slot)
            extra_r = base + "," + timing_extras(tr) + "," \
                + table_metric_extras(rstats, tr["seconds"], n, window=32)
            bucketed_ret[(lane, density)] = tr["seconds"]
            if density == 0.95:
                # flatness vs the rho=0.5 counterpart (>= 0.8x is the
                # acceptance bar for the two-choice lane)
                flat = bucketed_ret[(lane, 0.5)] / tr["seconds"]
                extra_r += f",flatness-vs-rho0.5={flat:.2f}x"
            out(row(f"fig5.insert.{lane}.rho{density}", ti["seconds"], n,
                    extra=fmt_extras(ok=ok, geometry=geom,
                                     bits_per_slot=tj.ops.bits_per_slot)
                    + "," + timing_extras(ti)))
            out(row(f"fig5.retrieve.{lane}.rho{density}", tr["seconds"], n,
                    extra=extra_r))

    # bulk engine vs sequential-scan reference (PR-trajectory comparison):
    # same table geometry, same keys — the only difference is the insert
    # path.  Interleaved timing halves the noise on shared CPU runners.
    rho = cfg.densities[0]
    capacity = int(n / rho)
    t_bulk = sv.create(capacity, max_probes=4096, window=32)
    t_scan = sv.create(capacity, max_probes=4096, window=32, backend="scan")
    ins = jax.jit(lambda t, k, v: sv.insert(t, k, v))
    jax.block_until_ready(ins(t_bulk, keys, vals))
    jax.block_until_ready(ins(t_scan, keys, vals))
    import time as _t
    tb, ts = [], []
    for _ in range(9):
        a = _t.perf_counter()
        jax.block_until_ready(ins(t_bulk, keys, vals))
        tb.append(_t.perf_counter() - a)
        a = _t.perf_counter()
        jax.block_until_ready(ins(t_scan, keys, vals))
        ts.append(_t.perf_counter() - a)
    # best-of (timeit-style): on a shared 2-core runner the minimum is the
    # interference-free estimate; applied symmetrically to both paths.
    sec_b, sec_s = min(tb), min(ts)
    out(row(f"fig5.insert.wc-cops.bulk.rho{rho}", sec_b, n,
            extra=f"speedup-vs-scan={sec_s / sec_b:.2f}x"))
    out(row(f"fig5.insert.wc-cops.scan.rho{rho}", sec_s, n))

    # fused single-walk retrieval vs the paper's count+gather two walks
    # (PR-trajectory comparison + parity gate).  Multi-value table with
    # multiplicity 4 — the workload whose output sizing needs the
    # counting pass — probed by the full batch incl. duplicates/misses.
    # default max_probes (= num_rows): the fused arena path requires a
    # revisit-free walk (bulk_retrieve.fused_ok)
    mult = 4
    mt_fused = mv.create(capacity, window=32)
    mt_scan = mv.create(capacity, window=32, backend="scan")
    mkeys = jnp.tile(keys[: n // mult], mult)
    mvals = jnp.arange(mkeys.shape[0], dtype=jnp.uint32)
    mt_fused, _ = mv.insert(mt_fused, mkeys, mvals)
    mt_scan, _ = mv.insert(mt_scan, mkeys, mvals)
    out_cap = int(jnp.sum(mv.count_values(mt_scan, keys)))
    ret = jax.jit(lambda t, k: mv.retrieve_all(t, k, out_cap))
    jax.block_until_ready(ret(mt_fused, keys))
    jax.block_until_ready(ret(mt_scan, keys))
    tf, tw = [], []
    for _ in range(9):
        a = _t.perf_counter()
        jax.block_until_ready(ret(mt_fused, keys))
        tf.append(_t.perf_counter() - a)
        a = _t.perf_counter()
        jax.block_until_ready(ret(mt_scan, keys))
        tw.append(_t.perf_counter() - a)
    sec_f, sec_w = min(tf), min(tw)
    # parity gate: the CI smoke step fails on any fused/scan mismatch
    vf, of, cf = ret(mt_fused, keys)
    vw_, ow, cw = ret(mt_scan, keys)
    for name_, a, b in (("values", vf, vw_), ("offsets", of, ow),
                        ("counts", cf, cw)):
        if not bool(jnp.array_equal(a, b)):
            raise AssertionError(
                f"fused/scan retrieval parity mismatch on {name_}")
    # table metrics of the fused walk (stats=True run, separately compiled)
    _, _, _, fstats = jax.jit(
        lambda t, k: mv.retrieve_all(t, k, out_cap, stats=True))(
            mt_fused, keys)
    metric_extra = table_metric_extras(
        fstats, sec_f, n, window=32, value_ops=out_cap / max(n, 1))
    out(row(f"fig5.retrieve.wc-cops.fused.rho{rho}", sec_f, n,
            extra=f"speedup-vs-twowalk={sec_w / sec_f:.2f}x,parity=ok,"
                  + metric_extra))
    out(row(f"fig5.retrieve.wc-cops.twowalk.rho{rho}", sec_w, n))


if __name__ == "__main__":
    run()
