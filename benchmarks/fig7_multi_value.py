"""Fig. 7 — multi-value insert/retrieve throughput vs key multiplicity r.

Contestants (paper §V-B):
  wc-oa       : MultiValueHashTable (COPS OA), target load 0.8
  wc-bl-1     : BucketListHashTable, default growth (lambda=1.1, s0=1)
  wc-bl-2     : BucketListHashTable, tuned growth  (lambda=1.0, s0=r)
  lp-oa       : scalar-LP multi-value baseline (cuDF-style)

Claims validated in shape: OA degrades as r grows (longer probe chains);
bucket lists stay ~flat and overtake OA at high r; tuned growth (BL-2)
allocates fewer buckets than default (BL-1).

The ``bulk-vs-scan`` section compares the bucket list's batched engine
build (``backend="jax"`` — sort/segment dedup + prefix-sum bucket
allocator + scatter-arbitration handle claims) and its fused chain-walk
retrieval against the sequential ``backend="scan"`` reference, same
table, same batch.  The comparison RAISES on any state or output
mismatch (key-store planes, handles, pool, alloc_top, statuses, values/
offsets/counts), so every run — including the CI smoke step — doubles as
the bucket-store parity gate.

Set ``REPRO_BENCH_SMOKE=1`` for the small smoke config (CI).
"""

from __future__ import annotations

import os
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import (
    fmt_extras,
    row,
    table_metric_extras,
    time_stats,
    timing_extras,
)
from repro.configs.warpcore import CONFIG, SMOKE
from repro.core import bucket_list as bl
from repro.core import multi_value as mv

PARITY_R = 8                     # multiplicity of the bulk-vs-scan section


def _cfg():
    return SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else CONFIG


def _workload(n, r):
    n_keys = max(1, n // r)
    base = np.random.default_rng(r).choice(
        np.arange(1, 8 * n_keys, dtype=np.uint32), n_keys, replace=False)
    keys = jnp.asarray(np.repeat(base, r))
    vals = jnp.arange(n_keys * r, dtype=jnp.uint32)
    return keys, vals, jnp.asarray(base), n_keys


def _assert_bl_parity(tb, ts, stb, sts):
    for pb, ps in zip(jax.tree_util.tree_leaves(tb.key_store.store),
                      jax.tree_util.tree_leaves(ts.key_store.store)):
        if not bool(jnp.array_equal(pb, ps)):
            raise AssertionError("bucket-list bulk/scan key-store mismatch")
    for name, a, b in (("pool", tb.pool, ts.pool),
                       ("alloc_top", tb.alloc_top, ts.alloc_top),
                       ("count", tb.key_store.count, ts.key_store.count),
                       ("status", stb, sts)):
        if not bool(jnp.array_equal(a, b)):
            raise AssertionError(f"bucket-list bulk/scan {name} mismatch")


def run(out=print):
    cfg = _cfg()
    n = cfg.n_pairs // 2
    load = 0.8
    for r in cfg.multiplicities:
        keys, vals, q, n_keys = _workload(n, r)
        total = n_keys * r

        for name, mk in {
            "wc-oa": lambda: mv.create(int(total / load), window=32),
            "lp-oa": lambda: mv.create(int(total / load), window=1,
                                       scheme="linear", max_probes=8192),
        }.items():
            t0 = mk()
            ins = jax.jit(lambda t, k, v: mv.insert(t, k, v))
            ti = time_stats(ins, t0, keys, vals)
            sec_i = ti["seconds"]
            t1, _ = ins(t0, keys, vals)
            ret = jax.jit(lambda t, k: mv.retrieve_all(t, k, total))
            tr = time_stats(ret, t1, q)
            sec_r = tr["seconds"]
            extra_i, extra_r = timing_extras(ti), timing_extras(tr)
            if name == "wc-oa":
                # probe/occupancy telemetry from a stats=True run (the
                # timed call stays stats=False)
                _, _, istats = jax.jit(
                    lambda t, k, v: mv.insert(t, k, v, stats=True))(
                        t0, keys, vals)
                _, _, _, rstats = jax.jit(
                    lambda t, k: mv.retrieve_all(t, k, total, stats=True))(
                        t1, q)
                extra_i += "," + table_metric_extras(
                    istats, sec_i, total, window=32)
                extra_r += "," + table_metric_extras(
                    rstats, sec_r, n_keys, window=32,
                    value_ops=total / max(n_keys, 1))
            out(row(f"fig7.insert.{name}.r{r}", sec_i, total, extra=extra_i))
            out(row(f"fig7.retrieve.{name}.r{r}", sec_r, total,
                    extra=extra_r))

        for name, (growth, s0) in {
            "wc-bl-1": (cfg.bl_growth_default[0], cfg.bl_growth_default[1]),
            "wc-bl-2": (1.0, r),
        }.items():
            t0 = bl.create(int(n_keys / load), pool_capacity=2 * total + 64,
                           s0=s0, growth=growth)
            ins = jax.jit(lambda t, k, v: bl.insert(t, k, v))
            ti = time_stats(ins, t0, keys, vals)
            sec_i = ti["seconds"]
            t1, _ = ins(t0, keys, vals)
            ret = jax.jit(lambda t, k: bl.retrieve_all(t, k, total))
            tr = time_stats(ret, t1, q)
            sec_r = tr["seconds"]
            used = int(t1.alloc_top)
            _, _, istats = jax.jit(
                lambda t, k, v: bl.insert(t, k, v, stats=True))(
                    t0, keys, vals)
            out(row(f"fig7.insert.{name}.r{r}", sec_i, total,
                    extra=fmt_extras(pool_used=used) + ","
                          + table_metric_extras(
                              istats, sec_i, total,
                              window=t1.key_store.window) + ","
                          + timing_extras(ti)))
            out(row(f"fig7.retrieve.{name}.r{r}", sec_r, total,
                    extra=timing_extras(tr)))

    # bucket-list engine vs sequential-scan reference (PR-trajectory rows +
    # parity gate).  Same geometry, same batch; only the backend differs.
    r = PARITY_R
    keys, vals, q, n_keys = _workload(n, r)
    total = n_keys * r
    mk = lambda backend: bl.create(int(n_keys / load),
                                   pool_capacity=2 * total + 64, s0=1,
                                   growth=1.1, backend=backend)
    t_bulk, t_scan = mk("jax"), mk("scan")
    ins = jax.jit(lambda t, k, v: bl.insert(t, k, v))
    jax.block_until_ready(ins(t_bulk, keys, vals))
    jax.block_until_ready(ins(t_scan, keys, vals))
    tb_s, ts_s = [], []
    for _ in range(5):
        a = _time.perf_counter()
        jax.block_until_ready(ins(t_bulk, keys, vals))
        tb_s.append(_time.perf_counter() - a)
        a = _time.perf_counter()
        jax.block_until_ready(ins(t_scan, keys, vals))
        ts_s.append(_time.perf_counter() - a)
    sec_b, sec_s = min(tb_s), min(ts_s)
    # parity gate on the full post-insert state + statuses
    t_bulk, stb = ins(t_bulk, keys, vals)
    t_scan, sts = ins(t_scan, keys, vals)
    _assert_bl_parity(t_bulk, t_scan, stb, sts)
    out(row(f"fig7.insert.wc-bl-1.bulk.r{r}", sec_b, total,
            extra=f"speedup-vs-scan={sec_s / sec_b:.2f}x,parity=ok"))
    out(row(f"fig7.insert.wc-bl-1.scan.r{r}", sec_s, total))

    # fused chain-walk retrieval vs the two-pass reference, duplicate- and
    # miss-riddled probe batch, with the same in-run parity gate
    probe = jnp.concatenate([keys, q + jnp.uint32(1)])
    ret = jax.jit(lambda t, k: bl.retrieve_all(t, k, total))
    jax.block_until_ready(ret(t_bulk, probe))
    jax.block_until_ready(ret(t_scan, probe))
    tf, tw = [], []
    for _ in range(5):
        a = _time.perf_counter()
        jax.block_until_ready(ret(t_bulk, probe))
        tf.append(_time.perf_counter() - a)
        a = _time.perf_counter()
        jax.block_until_ready(ret(t_scan, probe))
        tw.append(_time.perf_counter() - a)
    sec_f, sec_w = min(tf), min(tw)
    vf, of, cf = ret(t_bulk, probe)
    vw_, ow, cw = ret(t_scan, probe)
    for name, a, b in (("values", vf, vw_), ("offsets", of, ow),
                       ("counts", cf, cw)):
        if not bool(jnp.array_equal(a, b)):
            raise AssertionError(
                f"bucket-list fused/scan retrieval mismatch on {name}")
    if os.environ.get("REPRO_BENCH_SMOKE") and sec_w / sec_f < 1.0:
        # the BENCH_4 gap regression gate: the fused walk's dense
        # gather-form emit must at least match the two-pass reference
        # even at smoke scale (it sat at 0.52x before the fix)
        raise AssertionError(
            f"fused retrieval slower than two-pass reference: "
            f"{sec_w / sec_f:.2f}x")
    out(row(f"fig7.retrieve.wc-bl-1.fused.r{r}", sec_f, total,
            extra=f"speedup-vs-twopass={sec_w / sec_f:.2f}x,parity=ok"))
    out(row(f"fig7.retrieve.wc-bl-1.twopass.r{r}", sec_w, total))


if __name__ == "__main__":
    run()
