"""Fig. 7 — multi-value insert/retrieve throughput vs key multiplicity r.

Contestants (paper §V-B):
  wc-oa       : MultiValueHashTable (COPS OA), target load 0.8
  wc-bl-1     : BucketListHashTable, default growth (lambda=1.1, s0=1)
  wc-bl-2     : BucketListHashTable, tuned growth  (lambda=1.0, s0=r)
  lp-oa       : scalar-LP multi-value baseline (cuDF-style)

Claims validated in shape: OA degrades as r grows (longer probe chains);
bucket lists stay ~flat and overtake OA at high r; tuned growth (BL-2)
allocates fewer buckets than default (BL-1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.configs.warpcore import CONFIG
from repro.core import bucket_list as bl
from repro.core import multi_value as mv


def run(out=print):
    n = CONFIG.n_pairs // 2
    load = 0.8
    for r in CONFIG.multiplicities:
        n_keys = max(1, n // r)
        base = np.random.default_rng(r).choice(
            np.arange(1, 8 * n_keys, dtype=np.uint32), n_keys, replace=False)
        keys = jnp.asarray(np.repeat(base, r))
        vals = jnp.arange(n_keys * r, dtype=jnp.uint32)
        q = jnp.asarray(base)
        total = n_keys * r

        for name, mk in {
            "wc-oa": lambda: mv.create(int(total / load), window=32),
            "lp-oa": lambda: mv.create(int(total / load), window=1,
                                       scheme="linear", max_probes=8192),
        }.items():
            t0 = mk()
            ins = jax.jit(lambda t, k, v: mv.insert(t, k, v))
            sec_i = time_fn(ins, t0, keys, vals)
            t1, _ = ins(t0, keys, vals)
            ret = jax.jit(lambda t, k: mv.retrieve_all(t, k, total))
            sec_r = time_fn(ret, t1, q)
            out(row(f"fig7.insert.{name}.r{r}", sec_i, total))
            out(row(f"fig7.retrieve.{name}.r{r}", sec_r, total))

        for name, (growth, s0) in {
            "wc-bl-1": (CONFIG.bl_growth_default[0], CONFIG.bl_growth_default[1]),
            "wc-bl-2": (1.0, r),
        }.items():
            t0 = bl.create(int(n_keys / load), pool_capacity=2 * total + 64,
                           s0=s0, growth=growth)
            ins = jax.jit(lambda t, k, v: bl.insert(t, k, v))
            sec_i = time_fn(ins, t0, keys, vals)
            t1, _ = ins(t0, keys, vals)
            ret = jax.jit(lambda t, k: bl.retrieve_all(t, k, total))
            sec_r = time_fn(ret, t1, q)
            used = int(t1.alloc_top)
            out(row(f"fig7.insert.{name}.r{r}", sec_i, total,
                    extra=f"pool_used={used}"))
            out(row(f"fig7.retrieve.{name}.r{r}", sec_r, total))


if __name__ == "__main__":
    run()
