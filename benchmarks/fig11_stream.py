"""Fig. 11 — sustained streaming ingestion: scan engine vs per-batch re-entry.

The streaming engine (``repro.data.stream``) runs the dedup -> watchlist
join -> aggregate chunk pipeline as ONE compiled ``lax.scan`` with the
table carry donated and tombstone compaction in-graph.  This figure
measures what that buys over the per-batch path the repo had before
(``pipeline.relational_stage`` re-entered from Python per chunk, forget
and compaction as separate host round-trips):

- ``fig11.stream.scan``   — the engine: whole-stream wall time, rows
  carry ``steps_per_s``, ``compactions_in_graph``, ``retraces`` (asserted
  zero after warmup via the jit cache) and the parity gate.
- ``fig11.stream.eager``  — the per-batch re-entry baseline
  (``stream.reference_run``), bit-exactness enforced in-run: keep masks,
  hit counts and EVERY carry leaf (table store included) must match the
  scan engine, including across the in-graph compaction boundary.
- ``fig11.stream.step``   — the jitted single-step driver (double
  buffering, one compilation) with per-chunk latency percentiles
  (``p50_step_us`` / ``p99_step_us``).
- ``fig11.serve.table``   — the serving-loop variant: mixed
  insert/lookup/erase traffic against one donated table
  (``serving.serve_loop.serve_table_traffic``), per-step latency
  percentiles, retrace-free by construction (the driver raises).
- ``fig11.e2e.sketch-build-query`` — the fig8 front half feeding the
  stream: minhash-sketch synthetic genomes, build the watchlist from the
  sketch hashes, then stream token chunks through the engine
  (sketch -> build -> query end to end, tokens/s).

Smoke gates (``REPRO_BENCH_SMOKE=1``): parity everywhere, zero retraces,
at least one in-graph compaction, and scan >= 1.5x the eager per-batch
baseline.
"""

from __future__ import annotations

import os
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import fmt_extras, row
from repro.core import single_value as sv
from repro.data import pipeline, stream
from repro.obs.registry import Registry
from repro.obs.trace import Tracer
from repro.serving import serve_loop

_SMOKE = dict(n_chunks=16, chunk_batch=16, seq_len=32, vocab=96,
              dedup_capacity=4096, forget_after=4, compact_every=4,
              max_tombstone_density=0.005, serve_steps=8, serve_batch=256)
_FULL = dict(n_chunks=48, chunk_batch=64, seq_len=64, vocab=512,
             dedup_capacity=1 << 15, forget_after=8, compact_every=8,
             max_tombstone_density=0.005, serve_steps=32, serve_batch=2048)


def _cfg():
    return _SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else _FULL


def _stream_workload(p):
    cfg = stream.StreamConfig(
        seq_len=p["seq_len"], chunk_batch=p["chunk_batch"],
        dedup_capacity=p["dedup_capacity"], forget_after=p["forget_after"],
        compact_every=p["compact_every"],
        max_tombstone_density=p["max_tombstone_density"])
    rng = np.random.default_rng(0)
    chunks = rng.integers(
        0, p["vocab"],
        (p["n_chunks"], p["chunk_batch"], p["seq_len"])).astype(np.int32)
    watch = pipeline.build_watchlist(rng.choice(
        p["vocab"], size=p["vocab"] // 3, replace=False).astype(np.uint32))
    return cfg, jnp.asarray(chunks), watch


def _best_of(fn, iters=5):
    ts = []
    for _ in range(iters):
        a = _time.perf_counter()
        fn()
        ts.append(_time.perf_counter() - a)
    return min(ts)


def run(out=print):
    p = _cfg()
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    cfg, chunks, watch = _stream_workload(p)
    n_chunks = chunks.shape[0]
    tokens = int(np.prod(chunks.shape))

    # ---- scan engine: warmup (the one compilation), then time -----------
    cache0 = stream.stream_scan._cache_size()
    fin, (keep, hits) = stream.stream_scan(
        stream.create_state(cfg), watch, chunks)
    jax.block_until_ready(hits)
    compiles = stream.stream_scan._cache_size() - cache0

    def scan_once():
        f, o = stream.stream_scan(stream.create_state(cfg), watch, chunks)
        jax.block_until_ready(o)
    sec_scan = _best_of(scan_once)
    retraces = stream.stream_scan._cache_size() - cache0 - compiles
    if retraces:
        raise AssertionError(f"stream scan retraced {retraces}x after "
                             "warmup — single-compilation contract broken")

    # ---- per-batch eager re-entry baseline + bit-exact parity gate ------
    np_chunks = np.asarray(chunks)
    ref_fin, rkeep, rhits = stream.reference_run(
        stream.create_state(cfg), watch, np_chunks)
    for name, a, b in (("keep", keep, rkeep), ("hits", hits, rhits)):
        if not bool(jnp.array_equal(a, b)):
            raise AssertionError(f"stream/eager mismatch on {name}")
    for a, b in zip(jax.tree_util.tree_leaves(fin),
                    jax.tree_util.tree_leaves(ref_fin)):
        if not bool(jnp.array_equal(a, b)):
            raise AssertionError("stream/eager mismatch on a carry leaf")
    compactions = int(fin.counters.compactions)
    if smoke and compactions < 1:
        raise AssertionError("in-graph compaction never fired in the "
                             "smoke churn window")

    def eager_once():
        _, _, h = stream.reference_run(
            stream.create_state(cfg), watch, np_chunks)
        jax.block_until_ready(h)
    sec_eager = _best_of(eager_once, iters=3 if smoke else 2)
    speedup = sec_eager / sec_scan
    if smoke and speedup < 1.5:
        raise AssertionError(
            f"stream engine only {speedup:.2f}x over per-batch re-entry "
            "(>= 1.5x required)")

    out(row("fig11.stream.scan", sec_scan, tokens,
            extra=fmt_extras(steps_per_s=n_chunks / sec_scan,
                             compactions_in_graph=compactions,
                             retraces=0)
            + f",speedup-vs-eager={speedup:.2f}x,parity=ok"))
    out(row("fig11.stream.eager", sec_eager, tokens,
            extra=fmt_extras(steps_per_s=n_chunks / sec_eager)))

    # ---- jitted per-step driver: latency percentiles --------------------
    tracer = Tracer(registry=Registry())
    state = stream.create_state(cfg)
    state, k2, h2 = stream.stream(state, watch, list(np_chunks),
                                  tracer=tracer)  # warm + traced in one run
    if not (bool(jnp.array_equal(k2, rkeep))
            and bool(jnp.array_equal(h2, rhits))):
        raise AssertionError("step-driver/eager mismatch")
    # the first driver run above compiled the step; re-run traced so the
    # latency row excludes the compile span
    tracer2 = Tracer(registry=Registry())
    _, _, h3 = stream.stream(stream.create_state(cfg), watch,
                             list(np_chunks), tracer=tracer2)
    pct = tracer2.percentiles("stream.step")
    sec_step = pct["sum_s"]
    out(row("fig11.stream.step", sec_step, tokens,
            extra=fmt_extras(steps_per_s=pct["count"] / sec_step,
                             p50_step_us=pct["p50_s"] * 1e6,
                             p99_step_us=pct["p99_s"] * 1e6)
            + f",scan-speedup-vs-step={sec_step / sec_scan:.2f}x"))

    # ---- serving loop: mixed table traffic, donated, retrace-free -------
    rng = np.random.default_rng(1)
    nb, ns = p["serve_batch"], p["serve_steps"]

    def traffic():
        for _ in range(ns):
            yield (jnp.asarray(rng.integers(1, 1 << 20, nb), jnp.uint32),
                   jnp.asarray(rng.integers(0, 2**31, nb), jnp.uint32),
                   jnp.asarray(rng.integers(1, 1 << 20, nb), jnp.uint32),
                   jnp.asarray(rng.integers(1, 1 << 20, nb // 2),
                               jnp.uint32))

    table = sv.create(max(8 * nb, 1 << 14))
    # warmup once (compile), then measure a traced run
    table, _, _ = serve_loop.serve_table_traffic(
        table, traffic(), tracer=Tracer(registry=Registry()))
    tracer3 = Tracer(registry=Registry())
    table, tracer3, steps = serve_loop.serve_table_traffic(
        table, traffic(), tracer=tracer3)
    sp = tracer3.percentiles("serve.table_step")
    ops = steps * (2 * nb + nb // 2)
    out(row("fig11.serve.table", sp["sum_s"], ops,
            extra=fmt_extras(steps_per_s=steps / sp["sum_s"],
                             p50_step_us=sp["p50_s"] * 1e6,
                             p99_step_us=sp["p99_s"] * 1e6)
            + ",retraces=0"))

    # ---- fig8 sketch -> build -> query, end to end ----------------------
    from repro.kernels.minhash import ops as mh
    from repro.kernels.minhash.ref import INVALID
    g_rng = np.random.default_rng(2)
    genomes = g_rng.integers(0, 4, (2, 4000 if smoke else 20000)) \
        .astype(np.uint8)
    t0 = _time.perf_counter()
    sk = np.asarray(mh.sketch_reads(jnp.asarray(genomes), k=16, s=256))
    hashes = np.unique(sk[sk != INVALID])
    tracked = np.unique(hashes % p["vocab"]).astype(np.uint32)
    e2e_watch = pipeline.build_watchlist(tracked)
    sec_front = _time.perf_counter() - t0
    fin4, (k4, h4) = stream.stream_scan(
        stream.create_state(cfg), e2e_watch, chunks)
    jax.block_until_ready(h4)

    def e2e_query():
        _, o = stream.stream_scan(stream.create_state(cfg), e2e_watch,
                                  chunks)
        jax.block_until_ready(o)
    sec_query = _best_of(e2e_query, iters=3)
    out(row("fig11.e2e.sketch-build-query", sec_front + sec_query, tokens,
            extra=fmt_extras(sketch_build_s=sec_front,
                             query_s=sec_query,
                             watchlist=len(tracked),
                             hits_total=int(fin4.counters.hits))))


if __name__ == "__main__":
    run()
