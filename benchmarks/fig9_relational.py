"""Fig. 9 (extension) — relational operator throughput on WarpCore tables.

The paper benchmarks raw table ops against cuDF (§V); this figure runs
the *relational* layer those cuDF numbers stand in for:

  join      : inner hash join throughput (build+probe pairs/s) across
              build-table load factors (rho) and build:probe ratios
  join-how  : inner vs left vs semi vs anti at a fixed shape
  groupby   : group-by aggregate throughput across group counts (g) for
              sum / count / mean
  composite : two-column (key_words=2) join / group-by / distinct via the
              tuple-of-columns API, with an IN-RUN PARITY GATE against
              the same columns packed into single u32 words — the run
              RAISES on any output mismatch (build_idx/probe_idx/valid/
              matched/total, lookup aggregates, first-occurrence masks),
              so every benchmark run doubles as the composite-key
              correctness gate (rows carry ``parity=ok``)
  distinct  : dedup throughput at fixed duplication factor

Same CSV contract as fig5-8 (name,us_per_call,derived,extra); CPU-
container scale, shape-level comparison (see benchmarks/util.py).
Set ``REPRO_BENCH_SMOKE=1`` for the small smoke config (CI).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import (
    fmt_extras,
    row,
    table_metric_extras,
    time_fn,
    time_stats,
    timing_extras,
)
from repro.configs.warpcore import CONFIG, SMOKE
from repro.core import multi_value as mv
from repro.relational import distinct as rdistinct
from repro.relational import groupby as rgroupby
from repro.relational import join as rjoin


def _cfg():
    return SMOKE if os.environ.get("REPRO_BENCH_SMOKE") else CONFIG


def _keys(rng, n, universe):
    return jnp.asarray(rng.integers(1, universe, n).astype(np.uint32))


def run(out=print):
    n = _cfg().n_pairs // 2
    rng = np.random.default_rng(7)

    # --- join vs build load factor (probe = build size) ---------------------
    for rho in (0.5, 0.7, 0.85, 0.95):
        bk = jnp.asarray(rng.choice(np.arange(1, 8 * n, dtype=np.uint32), n,
                                    replace=False))
        pk = _keys(rng, n, 8 * n)
        cap = int(n / rho)
        f = jax.jit(lambda b, p: rjoin.hash_join(
            b, p, 2 * n, "inner", capacity=cap))
        ts = time_stats(f, bk, pk)
        sec = ts["seconds"]
        res = f(bk, pk)
        # probe-phase table metrics: same build table, stats=True counting
        # walk (separately compiled; the timed join stays stats=False)
        btable, _ = rjoin.build(bk, capacity=cap)
        _, jstats = jax.jit(
            lambda t, k: mv.count_values(t, k, stats=True))(btable, pk)
        out(row(f"fig9.join.inner.rho{rho}", sec, 2 * n,
                extra=fmt_extras(pairs=int(res.total)) + ","
                      + table_metric_extras(jstats, sec, 2 * n,
                                            window=btable.window) + ","
                      + timing_extras(ts)))

    # --- bucketed build table at high load (two-choice storage lane) --------
    # The build table on the bucketed lane keeps probe walks at <= 2 buckets
    # regardless of rho, so inner-join throughput should hold flat to 0.95
    # where the cops walk above degrades.  Each row gates jax-vs-scan join
    # output parity in-run (build_idx/probe_idx/valid/matched/total) and
    # records the bucket geometry.
    for rho in (0.5, 0.95):
        bk = jnp.asarray(rng.choice(np.arange(1, 8 * n, dtype=np.uint32), n,
                                    replace=False))
        pk = _keys(rng, n, 8 * n)
        cap = int(n / rho)
        fj = jax.jit(lambda b, p, cap=cap: rjoin.hash_join(
            b, p, 2 * n, "inner", capacity=cap, scheme="bucketed"))
        res_j = fj(bk, pk)
        res_s = rjoin.hash_join(bk, pk, 2 * n, "inner", capacity=cap,
                                scheme="bucketed", backend="scan")
        for fld in ("build_idx", "probe_idx", "valid", "matched"):
            if not bool((getattr(res_j, fld) == getattr(res_s, fld)).all()):
                raise AssertionError(
                    f"fig9 bucketed join jax/scan parity FAILED on {fld} "
                    f"(rho{rho})")
        if int(res_j.total) != int(res_s.total):
            raise AssertionError(
                f"fig9 bucketed join jax/scan parity FAILED on total "
                f"(rho{rho})")
        ts = time_stats(fj, bk, pk)
        btable, _ = rjoin.build(bk, capacity=cap, scheme="bucketed")
        _, jstats = jax.jit(
            lambda t, k: mv.count_values(t, k, stats=True))(btable, pk)
        out(row(f"fig9.join.inner.bucketed.rho{rho}", ts["seconds"], 2 * n,
                extra="parity=ok,"
                      + fmt_extras(pairs=int(res_j.total),
                                   geometry=f"p{btable.num_rows}"
                                            f"xW{btable.window}",
                                   bits_per_slot=btable.ops.bits_per_slot)
                      + "," + table_metric_extras(jstats, ts["seconds"],
                                                  2 * n,
                                                  window=btable.window)
                      + "," + timing_extras(ts)))

    # --- join vs build:probe ratio (fixed rho 0.5) --------------------------
    for ratio in (4, 2, 1):
        nb, npb = n // ratio, n
        bk = jnp.asarray(rng.choice(np.arange(1, 8 * nb, dtype=np.uint32), nb,
                                    replace=False))
        pk = _keys(rng, npb, 8 * nb)
        f = jax.jit(lambda b, p: rjoin.hash_join(b, p, 2 * n, "inner"))
        sec = time_fn(f, bk, pk)
        res = f(bk, pk)
        out(row(f"fig9.join.inner.bp1to{ratio}", sec, nb + npb,
                extra=f"pairs={int(res.total)}"))

    # --- join flavors at a fixed shape --------------------------------------
    bk = jnp.asarray(rng.choice(np.arange(1, 8 * n, dtype=np.uint32), n,
                                replace=False))
    pk = _keys(rng, n, 8 * n)
    for how in rjoin.HOW:
        f = jax.jit(lambda b, p, how=how: rjoin.hash_join(b, p, 2 * n, how))
        sec = time_fn(f, bk, pk)
        out(row(f"fig9.join.{how}", sec, 2 * n))

    # --- group-by vs group count --------------------------------------------
    vals = _keys(rng, n, 1 << 16)
    for g in (64, 1024, n // 4):
        gk = jnp.asarray(rng.integers(1, g + 1, n).astype(np.uint32))
        for agg in ("sum", "count", "mean"):
            f = jax.jit(lambda k, v, agg=agg, g=g: rgroupby.aggregate(
                k, v, rgroupby.capacity_for(g), agg))
            ts = time_stats(f, gk, vals)
            out(row(f"fig9.groupby.{agg}.g{g}", ts["seconds"], n,
                    extra=timing_extras(ts)))

    # --- distinct at duplication factor 8 ------------------------------------
    dk = jnp.asarray(rng.integers(1, max(n // 8, 2), n).astype(np.uint32))
    f = jax.jit(lambda k: rdistinct.distinct(k, n))
    sec = time_fn(f, dk)
    _, n_unique, _ = f(dk)
    out(row("fig9.distinct.dup8", sec, n, extra=f"unique={int(n_unique)}"))

    # --- composite two-column keys + parity gates ----------------------------
    # 16-bit column values, so the SAME columns also pack into one u32
    # word ((hi << 16) | lo): the packed run is the single-word reference
    # every composite output must match bit for bit.  Placement differs
    # completely between the representations (different hash words), so
    # agreement is a real end-to-end gate on the multi-plane path.
    bh = jnp.asarray(rng.integers(0, 1 << 10, n).astype(np.uint32))
    bl = jnp.asarray(rng.integers(1, 1 << 16, n).astype(np.uint32))
    ph = jnp.asarray(rng.integers(0, 1 << 10, n).astype(np.uint32))
    plo = jnp.asarray(rng.integers(1, 1 << 16, n).astype(np.uint32))
    pack = lambda h, l: (h << 16) | l

    fc = jax.jit(lambda a, b, c, d: rjoin.hash_join((a, b), (c, d), 2 * n,
                                                    "inner"))
    fp = jax.jit(lambda b, p: rjoin.hash_join(b, p, 2 * n, "inner"))
    res_c = fc(bh, bl, ph, plo)
    res_p = fp(pack(bh, bl), pack(ph, plo))
    for fld in ("build_idx", "probe_idx", "valid", "matched"):
        if not bool((getattr(res_c, fld) == getattr(res_p, fld)).all()):
            raise AssertionError(
                f"fig9 composite join parity FAILED on {fld}")
    if int(res_c.total) != int(res_p.total):
        raise AssertionError("fig9 composite join parity FAILED on total")
    sec_c = time_fn(fc, bh, bl, ph, plo)
    sec_p = time_fn(fp, pack(bh, bl), pack(ph, plo))
    out(row("fig9.join.inner.composite2", sec_c, 2 * n,
            extra=f"parity=ok,vs-packed={sec_p / sec_c:.2f}x"))
    out(row("fig9.join.inner.packed1", sec_p, 2 * n))

    gv = _keys(rng, n, 1 << 16)
    gc = jax.jit(lambda a, b, v: rgroupby.aggregate(
        (a, b), v, rgroupby.capacity_for(max(n // 8, 8)), "sum"))
    gp = jax.jit(lambda k, v: rgroupby.aggregate(
        k, v, rgroupby.capacity_for(max(n // 8, 8)), "sum"))
    gh = jnp.asarray(rng.integers(0, 16, n).astype(np.uint32))
    gl = jnp.asarray(rng.integers(1, max(n // 128, 2), n).astype(np.uint32))
    _, _, _, tc = gc(gh, gl, gv)
    _, _, _, tp = gp(pack(gh, gl), gv)
    out_c, f_c = rgroupby.lookup(tc, "sum", (gh, gl))
    out_p, f_p = rgroupby.lookup(tp, "sum", pack(gh, gl))
    if not (bool((out_c == out_p).all()) and bool((f_c == f_p).all())
            and int(tc.count) == int(tp.count)):
        raise AssertionError("fig9 composite groupby parity FAILED")
    sec = time_fn(gc, gh, gl, gv)
    out(row("fig9.groupby.sum.composite2", sec, n,
            extra=f"parity=ok,groups={int(tc.count)}"))

    dc = jax.jit(lambda a, b: rdistinct.distinct((a, b), n))
    dp = jax.jit(lambda k: rdistinct.distinct(k, n))
    (uh, ul), n_c, fr_c = dc(gh, gl)
    up, n_p, fr_p = dp(pack(gh, gl))
    if not (int(n_c) == int(n_p) and bool((fr_c == fr_p).all())
            and bool((pack(uh, ul) == up).all())):
        raise AssertionError("fig9 composite distinct parity FAILED")
    sec = time_fn(dc, gh, gl)
    out(row("fig9.distinct.composite2", sec, n,
            extra=f"parity=ok,unique={int(n_c)}"))


if __name__ == "__main__":
    run()
