"""Fig. 9 (extension) — relational operator throughput on WarpCore tables.

The paper benchmarks raw table ops against cuDF (§V); this figure runs
the *relational* layer those cuDF numbers stand in for:

  join     : inner hash join throughput (build+probe pairs/s) across
             build-table load factors (rho) and build:probe ratios
  join-how : inner vs left vs semi vs anti at a fixed shape
  groupby  : group-by aggregate throughput across group counts (g) for
             sum / count / mean
  distinct : dedup throughput at fixed duplication factor

Same CSV contract as fig5-8 (name,us_per_call,derived,extra); CPU-
container scale, shape-level comparison (see benchmarks/util.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.configs.warpcore import CONFIG
from repro.relational import distinct as rdistinct
from repro.relational import groupby as rgroupby
from repro.relational import join as rjoin


def _keys(rng, n, universe):
    return jnp.asarray(rng.integers(1, universe, n).astype(np.uint32))


def run(out=print):
    n = CONFIG.n_pairs // 2
    rng = np.random.default_rng(7)

    # --- join vs build load factor (probe = build size) ---------------------
    for rho in (0.5, 0.7, 0.85, 0.95):
        bk = jnp.asarray(rng.choice(np.arange(1, 8 * n, dtype=np.uint32), n,
                                    replace=False))
        pk = _keys(rng, n, 8 * n)
        cap = int(n / rho)
        f = jax.jit(lambda b, p: rjoin.hash_join(
            b, p, 2 * n, "inner", capacity=cap))
        sec = time_fn(f, bk, pk)
        res = f(bk, pk)
        out(row(f"fig9.join.inner.rho{rho}", sec, 2 * n,
                extra=f"pairs={int(res.total)}"))

    # --- join vs build:probe ratio (fixed rho 0.5) --------------------------
    for ratio in (4, 2, 1):
        nb, npb = n // ratio, n
        bk = jnp.asarray(rng.choice(np.arange(1, 8 * nb, dtype=np.uint32), nb,
                                    replace=False))
        pk = _keys(rng, npb, 8 * nb)
        f = jax.jit(lambda b, p: rjoin.hash_join(b, p, 2 * n, "inner"))
        sec = time_fn(f, bk, pk)
        res = f(bk, pk)
        out(row(f"fig9.join.inner.bp1to{ratio}", sec, nb + npb,
                extra=f"pairs={int(res.total)}"))

    # --- join flavors at a fixed shape --------------------------------------
    bk = jnp.asarray(rng.choice(np.arange(1, 8 * n, dtype=np.uint32), n,
                                replace=False))
    pk = _keys(rng, n, 8 * n)
    for how in rjoin.HOW:
        f = jax.jit(lambda b, p, how=how: rjoin.hash_join(b, p, 2 * n, how))
        sec = time_fn(f, bk, pk)
        out(row(f"fig9.join.{how}", sec, 2 * n))

    # --- group-by vs group count --------------------------------------------
    vals = _keys(rng, n, 1 << 16)
    for g in (64, 1024, n // 4):
        gk = jnp.asarray(rng.integers(1, g + 1, n).astype(np.uint32))
        for agg in ("sum", "count", "mean"):
            f = jax.jit(lambda k, v, agg=agg, g=g: rgroupby.aggregate(
                k, v, rgroupby.capacity_for(g), agg))
            sec = time_fn(f, gk, vals)
            out(row(f"fig9.groupby.{agg}.g{g}", sec, n))

    # --- distinct at duplication factor 8 ------------------------------------
    dk = jnp.asarray(rng.integers(1, max(n // 8, 2), n).astype(np.uint32))
    f = jax.jit(lambda k: rdistinct.distinct(k, n))
    sec = time_fn(f, dk)
    _, n_unique, _ = f(dk)
    out(row("fig9.distinct.dup8", sec, n, extra=f"unique={int(n_unique)}"))


if __name__ == "__main__":
    run()
