"""Benchmark timing helpers.

CPU-container scale: batch sizes are 2^13-2^14 (the paper uses 2^28 on a
GV100).  Throughput numbers are therefore *shape* comparisons against the
paper's curves (which implementation wins where, how throughput scales with
density/multiplicity), not absolute-magnitude reproductions — recorded as
such in EXPERIMENTS.md.
"""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, seconds: float, n_ops: int, extra: str = "") -> str:
    """CSV row: name,us_per_call,derived(Mops/s)[,extra]"""
    us = seconds * 1e6
    mops = n_ops / seconds / 1e6
    out = f"{name},{us:.1f},{mops:.3f}Mops/s"
    if extra:
        out += f",{extra}"
    return out
