"""Benchmark timing helpers.

CPU-container scale: batch sizes are 2^13-2^14 (the paper uses 2^28 on a
GV100).  Throughput numbers are therefore *shape* comparisons against the
paper's curves (which implementation wins where, how throughput scales with
density/multiplicity), not absolute-magnitude reproductions — recorded as
such in EXPERIMENTS.md.

``time_stats`` is the instrumented timer: besides the median it reports the
min, the min-vs-median spread (a noise signal — shared CPU containers
wobble; rows with spread > NOISY_SPREAD are flagged ``noisy=1``) and the
``iters``/``warmup`` actually used, so every emitted row records how it was
measured.  ``ITERS_OVERRIDE`` (set by ``benchmarks.run --iters``) globally
overrides the per-call ``iters`` without threading a parameter through
every figure module.
"""

from __future__ import annotations

import time

import jax

#: set by ``benchmarks.run --iters N``; overrides every time_* call's iters
ITERS_OVERRIDE: int | None = None

#: min-vs-median spread above which a row is flagged noisy
NOISY_SPREAD = 0.20


def _measure(fn, args, warmup: int, iters: int) -> dict:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    med = ts[len(ts) // 2]
    spread = (med - ts[0]) / med if med > 0 else 0.0
    return {"seconds": med, "min_s": ts[0], "spread": spread,
            "iters": iters, "warmup": warmup,
            "noisy": spread > NOISY_SPREAD}


def time_stats(fn, *args, warmup: int = 1, iters: int = 3) -> dict:
    """Timing summary of fn(*args) with block_until_ready.

    Returns ``{seconds, min_s, spread, iters, warmup, noisy}`` where
    ``seconds`` is the median, ``spread = (median - min) / median`` and
    ``noisy`` flags spread > NOISY_SPREAD.

    If the first measurement trips the noisy flag, the run is retried
    exactly once with doubled iters (bounded — no further retries) and the
    quieter of the two summaries wins.  ``iters`` in the returned dict
    records the iteration count actually used, so the retry is visible in
    every emitted row's provenance extras.
    """
    if ITERS_OVERRIDE:
        iters = ITERS_OVERRIDE
    out = _measure(fn, args, warmup, iters)
    if out["noisy"]:
        retry = _measure(fn, args, 0, iters * 2)
        if retry["spread"] < out["spread"]:
            out = retry
    return out


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    return time_stats(fn, *args, warmup=warmup, iters=iters)["seconds"]


def fmt_extras(**kv) -> str:
    """Render ``k=v`` extras for ``row`` (floats compact, bools as 0/1)."""
    parts = []
    for k, v in kv.items():
        if isinstance(v, bool):
            parts.append(f"{k}={int(v)}")
        elif isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return ",".join(parts)


def timing_extras(ts: dict) -> str:
    """The measurement provenance extras of a ``time_stats`` summary."""
    return fmt_extras(iters=ts["iters"], warmup=ts["warmup"],
                      spread=ts["spread"], noisy=ts["noisy"])


def table_metric_extras(stats, seconds: float, n_ops: int, *, window: int,
                        key_words: int = 1, value_words: int = 1,
                        value_ops: float = 1.0) -> str:
    """Roofline-normalized table metrics for one benchmark row.

    ``stats`` is an ``obs.metrics.TableStats`` from the timed op run with
    ``stats=True`` (a separate call — the timed call itself stays
    stats=False).  Emits ``probe_len_p50/p99``, ``load_factor``,
    ``bytes_moved`` (the walk-bytes model) and ``pct_of_roofline``.
    """
    from repro.launch import roofline
    d = stats.as_dict()
    walkers = max(int(stats.probe_n), 1)
    bytes_moved = roofline.table_walk_bytes(
        walkers, d["probe_len_mean"] or 1.0, window=window,
        key_words=key_words, value_words=value_words, value_ops=value_ops)
    return fmt_extras(probe_len_p50=d["probe_len_p50"],
                      probe_len_p99=d["probe_len_p99"],
                      load_factor=d["load_factor"],
                      bytes_moved=bytes_moved,
                      pct_of_roofline=roofline.pct_of_roofline(bytes_moved,
                                                               seconds))


def row(name: str, seconds: float, n_ops: int, extra: str = "") -> str:
    """CSV row: name,us_per_call,derived(Mops/s)[,extra]"""
    us = seconds * 1e6
    mops = n_ops / seconds / 1e6
    out = f"{name},{us:.1f},{mops:.3f}Mops/s"
    if extra:
        out += f",{extra}"
    return out
