"""Relational operators on WarpCore tables: join / group-by / distinct.

A miniature "orders x customers" analytics pass run entirely on device —
the workload class the paper benchmarks cuDF against (§V), built from
the repo's hash-table primitives.  Includes composite multi-column keys:
a (customer, month) two-column join and a (region, month) two-column
group-by via the tuple-of-columns API (see README.md §Quickstart).

    PYTHONPATH=src python examples/relational.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational import distinct, groupby, join
from repro.relational.util import unpack_columns


def main():
    rng = np.random.default_rng(0)

    # --- tiny star schema: customers (build) and orders (probe) -------------
    n_customers, n_orders = 500, 4000
    customer_id = jnp.arange(1, n_customers + 1, dtype=jnp.uint32)
    region = jnp.asarray(rng.integers(1, 6, n_customers).astype(np.uint32))
    order_customer = jnp.asarray(
        rng.integers(1, int(1.2 * n_customers), n_orders).astype(np.uint32))
    order_amount = jnp.asarray(rng.integers(1, 100, n_orders).astype(np.uint32))

    # --- inner join: orders -> customer rows ---------------------------------
    res = jax.jit(lambda b, p: join.hash_join(b, p, n_orders, "inner"))(
        customer_id, order_customer)
    print(f"inner join: {int(res.total)}/{n_orders} orders matched a customer")

    # anti join = orders referencing unknown customers (FK violations)
    anti = join.hash_join(customer_id, order_customer, n_orders, "anti")
    print(f"anti join: {int(anti.total)} orphan orders")

    # --- join payload gather + group-by: revenue per region ------------------
    cust_region, amounts = join.gather_payload(res, region, order_amount)
    gk, revenue, live, _ = jax.jit(lambda k, v: groupby.aggregate(
        k, v, groupby.capacity_for(8), "sum", mask=res.valid))(
            cust_region, amounts)
    per_region = {int(k): int(v) for k, v, l in zip(gk, revenue, live) if l}
    print(f"revenue by region (group-by sum over joined rows): {per_region}")
    total = int(np.asarray(amounts)[np.asarray(res.valid)].sum())
    assert sum(per_region.values()) == total, "group-by sum mismatch"

    # mean order value per region
    gk_m, mean_v, live_m, _ = groupby.aggregate(
        cust_region, amounts, groupby.capacity_for(8), "mean", mask=res.valid)
    print("mean order value by region:",
          {int(k): round(float(v), 1)
           for k, v, l in zip(gk_m, mean_v, live_m) if l})

    # --- distinct: unique customers that ordered -----------------------------
    uniq, n_uniq, first = jax.jit(
        lambda k: distinct.distinct(k, n_customers * 2))(order_customer)
    print(f"distinct: {int(n_uniq)} unique ordering customers "
          f"(first-occurrence mask drops {int((~first).sum())} dups)")

    # --- composite keys: join + group-by on (customer, month) ----------------
    # real pipelines join on multi-column keys; pass a TUPLE of u32
    # columns and key_words is inferred (core.hashing.pack_columns packs
    # them into key planes — two columns == the table-native u64 layout)
    order_month = jnp.asarray(rng.integers(1, 13, n_orders).astype(np.uint32))
    cust_month = jnp.asarray(
        np.stack(np.meshgrid(np.arange(1, n_customers + 1),
                             np.arange(1, 13)), -1).reshape(-1, 2)
        .astype(np.uint32))
    res2 = jax.jit(lambda bh, bl, ph, pl: join.hash_join(
        (bh, bl), (ph, pl), n_orders, "inner"))(
            cust_month[:, 0], cust_month[:, 1], order_customer, order_month)
    print(f"composite join on (customer, month): {int(res2.total)}/{n_orders} "
          f"orders matched a (customer, month) row")

    # revenue per (region, month): a two-column group-by over joined rows
    cm_region = region[jnp.clip(cust_month[:, 0] - 1, 0, n_customers - 1)]
    reg_of_order, amt = join.gather_payload(res2, cm_region, order_amount)
    mon_of_order, _ = join.gather_payload(res2, cust_month[:, 1], None)
    gk2, rev2, live2, _ = groupby.aggregate(
        (reg_of_order, mon_of_order), amt, groupby.capacity_for(5 * 12),
        "sum", mask=res2.valid)
    g_reg, g_mon = unpack_columns(gk2)
    top = sorted(((int(v), int(r), int(m)) for r, m, v, l in
                  zip(g_reg, g_mon, rev2, live2) if l), reverse=True)[:3]
    print("top (region, month) revenue cells:",
          [(f"region {r}", f"month {m}", v) for v, r, m in top])

    # two-column DISTINCT comes back as columns too
    (u_cust, u_mon), n_cm, _ = distinct.distinct(
        (order_customer, order_month), n_orders)
    print(f"distinct (customer, month) pairs: {int(n_cm)}")

    # --- sharded join (needs >1 device; skipped on a single-device host) -----
    if len(jax.devices()) >= 2:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), ("x",))
        # shard_map needs batch sizes divisible by the axis size
        nb = n_customers // ndev * ndev
        np_ = n_orders // ndev * ndev
        out = join.shard_join(mesh, "x", customer_id[:nb],
                              order_customer[:np_], n_orders, "inner")
        print(f"sharded join: {int(np.asarray(out['valid']).sum())} pairs, "
              f"overflow={int(np.asarray(out['overflow']).sum())}")
    else:
        print("sharded join: single device, skipped "
              "(run with XLA_FLAGS=--xla_force_host_platform_device_count=8)")


if __name__ == "__main__":
    main()
