"""Multi-device hash tables: the paper's distributed + independent modes
(§IV-E) on 8 host devices.

    PYTHONPATH=src python examples/distributed_tables.py
(sets XLA_FLAGS itself — run as a standalone script)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core import distributed as dist                    # noqa: E402


def main():
    from repro.core.compat import make_mesh_compat
    mesh = make_mesh_compat((8,), ("x",))
    print(f"mesh: {mesh.devices.size} devices")

    # distributed mode: each key owned by exactly one shard
    table = dist.create_sharded(mesh, "x", capacity_per_shard=4096, window=32)
    n = 8 * 2048
    keys = jnp.asarray(np.random.default_rng(0).permutation(
        np.arange(1, n + 1, dtype=np.uint32)))
    vals = keys * 3

    table, status, overflow = dist.shard_insert(mesh, "x", table, keys, vals)
    print(f"distributed insert: {n} pairs, exchange overflow="
          f"{int(np.asarray(overflow).sum())} (padded all-to-all, slack 2.0)")

    got, found, _ = dist.shard_retrieve(mesh, "x", table, keys)
    print(f"distributed retrieve: all found={bool(np.asarray(found).all())}, "
          f"values ok={bool((np.asarray(got) == np.asarray(vals)).all())}")

    # per-shard occupancy (hash_owner balance)
    from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY
    kp = np.asarray(table.key_planes())[:, 0]
    occ = [(int(((kp[s] != EMPTY_KEY) & (kp[s] != TOMBSTONE_KEY)).sum()))
           for s in range(8)]
    print(f"per-shard keys: {occ} (balanced by hash_owner)")


if __name__ == "__main__":
    main()
