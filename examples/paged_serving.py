"""Paged-KV-cache serving demo: the hash table as a page table.

Serves a smoke-scale LM where every (sequence, page) -> physical-page
translation goes through a WarpCore SingleValueHashTable (DESIGN.md §3.3):
pages allocate lazily on first touch, sequences free their pages on
completion (tombstone erase), and new requests reuse the slots.

    PYTHONPATH=src python examples/paged_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model_zoo as zoo
from repro.obs import metrics
from repro.obs.registry import REGISTRY
from repro.serving import kv_cache as pkv


def sequence_flood(num_pages: int = 512, waves: int = 8, batch: int = 64,
                   pages_per_seq: int = 1, verbose: bool = False) -> dict:
    """Flood the cache with new sequences until every physical page is out.

    The robustness scenario: the page table starts deliberately undersized
    (slack 0.125 — it could hold ~1/8 of the pages) but carries an
    auto-growth policy (``repro.core.migrate.GrowthPolicy``), so table
    occupancy NEVER fails an allocation — the table grows online and the
    flood runs until genuine physical-page exhaustion.  Returns the tally
    the serving test asserts on: ``failures`` must be 0 and the table must
    have grown.
    """
    from repro.core.migrate import GrowthPolicy
    cache = pkv.create(1, num_pages, 8, 1, 8, table_slack=0.125,
                       policy=GrowthPolicy(max_load_factor=0.8))
    cap0 = cache.page_table.capacity
    failures = 0
    allocated = 0
    per_wave = (batch // pages_per_seq) * pages_per_seq
    for wave in range(waves):
        seq = jnp.arange(per_wave // pages_per_seq, dtype=jnp.int32) \
            + jnp.int32(wave * 10_000)
        sq = jnp.repeat(seq, pages_per_seq)
        pg = jnp.tile(jnp.arange(pages_per_seq, dtype=jnp.int32),
                      seq.shape[0])
        cache, _, ok = pkv.allocate_pages(cache, sq, pg)
        failures += int(jnp.sum(~ok))
        allocated += int(jnp.sum(ok))
        if verbose:
            print(f"  wave {wave}: {int(jnp.sum(ok))}/{sq.shape[0]} pages, "
                  f"table capacity {cache.page_table.capacity}")
    return {"failures": failures, "allocated": allocated,
            "capacity_before": cap0,
            "capacity_after": cache.page_table.capacity,
            "free_top": int(cache.free_top), "num_pages": num_pages}


def main():
    cfg = configs.get_smoke_config("smollm-360m")
    model = zoo.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    nb = cfg.num_layers
    hd, hkv = cfg.resolved_head_dim, cfg.num_kv_heads

    page_size, num_pages = 8, 64
    cache = pkv.create(nb, num_pages, page_size, hkv, hd)
    print(f"paged cache: {num_pages} pages x {page_size} tokens, "
          f"page table capacity {cache.page_table.capacity}")

    # serve two "requests" of different lengths via the paged path:
    # a dense per-step decode whose K/V rows are committed to pages
    seq_ids = jnp.asarray([101, 202], jnp.int32)
    dense = model.init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(12):
        logits, dense = model.decode_step(params, dense, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # commit this token's K/V (from the dense cache) into pages
        k = dense["k"][:, :, pos]                     # (L, B, Hkv, hd)
        v = dense["v"][:, :, pos]
        cache = pkv.append_token(cache, seq_ids,
                                 jnp.full((2,), pos, jnp.int32), k, v)
    print(f"after 12 tokens x 2 seqs: {int(cache.free_top)} pages allocated "
          f"(expect 2 x ceil(12/8) = 4)")

    k, v = pkv.gather_kv(cache, seq_ids, max_len=12)
    ref = dense["k"][:, :, :12]
    ok = np.allclose(np.asarray(k, np.float32), np.asarray(ref, np.float32))
    print(f"paged gather matches dense cache: {ok}")

    # request 101 finishes -> free its pages
    cache, freed = pkv.free_sequences(cache, seq_ids[:1], max_pages=4)
    print(f"freed {int(freed)} page-table entries for seq 101 "
          f"(tombstoned; slots reusable)")
    _, found = pkv.lookup_pages(cache, jnp.asarray([101, 202]),
                                jnp.asarray([0, 0]))
    print(f"post-free lookups: seq101={bool(found[0])} seq202={bool(found[1])}")

    # telemetry: page-table stats (probe lengths, occupancy) + the registry
    # counters kv_cache recorded during the eager alloc/free calls above
    t = cache.page_table
    stats = metrics.bolt_on_stats(
        t, pkv._pt_key(jnp.repeat(seq_ids, 4),
                       jnp.tile(jnp.arange(4, dtype=jnp.int32), 2)))
    print(f"page table: load_factor={float(stats.load_factor):.3f} "
          f"live={int(stats.live_slots)} tombstones={int(stats.tombstone_slots)} "
          f"mean_probe_len={stats.mean_probe_len():.2f}")
    # robustness: a sequence flood against an undersized page table —
    # the auto-growth policy keeps allocations succeeding until the
    # physical pages themselves run out
    print("--- sequence flood (auto-growth) ---")
    tally = sequence_flood(verbose=True)
    print(f"flood: {tally['allocated']}/{tally['num_pages']} pages handed "
          f"out, {tally['failures']} failures, page table "
          f"{tally['capacity_before']} -> {tally['capacity_after']} slots")
    assert tally["failures"] == 0, "allocation failed despite growth policy"

    print("--- metrics registry ---")
    print(REGISTRY.render())


if __name__ == "__main__":
    main()
