"""Metagenomic classification end-to-end (paper §V-C, Fig. 8).

Builds a reference k-mer database from synthetic genomes with the
minhash Pallas kernel + BucketListHashTable, then classifies reads by
k-mer voting — the MetaCache-style pipeline entirely in JAX.

    PYTHONPATH=src python examples/metagenomics.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucket_list as bl
from repro.kernels.minhash import ops as mh
from repro.kernels.minhash.ref import INVALID

K, SKETCH_DB, SKETCH_READ = 16, 1024, 48
N_GENOMES, GENOME_LEN = 8, 30_000
N_READS, READ_LEN = 50, 300


def build_database(genomes):
    """genomes -> (bucket-list table mapping kmer hash -> genome id)."""
    n_est = N_GENOMES * SKETCH_DB
    table = bl.create(2 * n_est, pool_capacity=4 * n_est, s0=1, growth=1.1)
    for gid, g in enumerate(genomes):
        sk = np.asarray(mh.sketch_reads(jnp.asarray(g[None]), k=K,
                                        s=SKETCH_DB))[0]
        h = np.minimum(sk[sk != INVALID], 0xFFFFFFFD)
        table, status = bl.insert(table, jnp.asarray(h),
                                  jnp.full(len(h), gid, jnp.uint32))
        assert (np.asarray(status) == 0).all()
    return table


def classify(table, read):
    sk = np.asarray(mh.sketch_reads(jnp.asarray(read[None]), k=K,
                                    s=SKETCH_READ))[0]
    q = np.minimum(sk[sk != INVALID], 0xFFFFFFFD)
    out, off, cnt = bl.retrieve_all(table, jnp.asarray(q),
                                    out_capacity=len(q) * 16)
    hits = np.asarray(out)[:int(np.asarray(off)[-1])]
    if len(hits) == 0:
        return -1, 0
    votes = np.bincount(hits, minlength=N_GENOMES)
    return int(votes.argmax()), int(votes.max())


def main():
    rng = np.random.default_rng(7)
    genomes = [rng.integers(0, 4, GENOME_LEN).astype(np.uint8)
               for _ in range(N_GENOMES)]

    t0 = time.time()
    table = build_database(genomes)
    n_kmers = N_GENOMES * (GENOME_LEN - K + 1)
    print(f"database: {int(table.num_keys())} distinct minhash k-mers from "
          f"{n_kmers} total k-mers in {time.time() - t0:.2f}s "
          f"(pool used {int(table.alloc_top)}/{table.pool_capacity})")

    correct = total = 0
    t0 = time.time()
    for _ in range(N_READS):
        gid = int(rng.integers(0, N_GENOMES))
        start = int(rng.integers(0, GENOME_LEN - READ_LEN))
        read = genomes[gid][start:start + READ_LEN]
        # 2% simulated sequencing errors
        errs = rng.random(READ_LEN) < 0.02
        read = np.where(errs, rng.integers(0, 4, READ_LEN), read).astype(np.uint8)
        pred, votes = classify(table, read)
        correct += int(pred == gid)
        total += 1
    print(f"classified {correct}/{total} reads correctly "
          f"in {time.time() - t0:.2f}s")
    assert correct / total > 0.8


if __name__ == "__main__":
    main()
