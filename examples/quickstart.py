"""Quickstart: the WarpCore-on-TPU hash table API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

The top-level README.md has the full tour: architecture map (store
protocol -> bulk engines -> tables -> relational/distributed layers),
the scan/jax/pallas backend matrix, composite multi-column keys, and how
to run the tier-1 tests and benchmarks.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom, bucket_list, counting, multi_value, single_value


def main():
    # --- single-value table: upsert / retrieve / erase -----------------------
    table = single_value.create(10_000, window=32)        # capacity -> p*W
    keys = jnp.arange(1, 5001, dtype=jnp.uint32)
    vals = keys * 7
    table, status = jax.jit(single_value.insert)(table, keys, vals)
    got, found = jax.jit(single_value.retrieve)(table, keys)
    print(f"single-value: inserted {int(table.count)} "
          f"(load {float(table.load_factor()):.2f}), all found={bool(found.all())}")

    table, erased = single_value.erase(table, keys[:100])
    print(f"erased {int(erased.sum())} keys; count={int(table.count)}")

    # --- the same table on the Pallas kernel path ----------------------------
    ktable = single_value.create(10_000, window=32, backend="pallas")
    ktable, _ = single_value.insert(ktable, keys, vals)   # COPS kernel
    same = jax.tree.map(lambda a, b: bool((a == b).all()),
                        ktable.store, single_value.create(
                            10_000, window=32).store)
    print("pallas kernel path: table built (interpret mode on CPU)")

    # --- multi-value + bucket list -------------------------------------------
    mkeys = jnp.asarray(np.repeat(np.arange(1, 101, dtype=np.uint32), 5))
    mvals = jnp.arange(500, dtype=jnp.uint32)
    mtable = multi_value.create(2048)
    mtable, _ = multi_value.insert(mtable, mkeys, mvals)
    out, offsets, cnt = multi_value.retrieve_all(
        mtable, jnp.arange(1, 101, dtype=jnp.uint32), out_capacity=500)
    print(f"multi-value: counts all 5 -> {bool((cnt == 5).all())}")

    btable = bucket_list.create(1024, pool_capacity=4096, s0=1, growth=1.1)
    btable, _ = bucket_list.insert(btable, mkeys, mvals)
    print(f"bucket list: {int(btable.num_keys())} keys, "
          f"{int(btable.alloc_top)} pool slots used, O(1) counts "
          f"{bool((bucket_list.count_values(btable, jnp.arange(1, 101, dtype=jnp.uint32)) == 5).all())}")

    # --- counting table + bloom filter ---------------------------------------
    ctable = counting.create(1024)
    ctable, _ = counting.insert(ctable, mkeys)
    print(f"counting: key 1 occurs {int(counting.counts(ctable, jnp.asarray([1], jnp.uint32))[0])}x")

    f = bloom.create(1 << 14, k=4)
    f = bloom.insert(f, keys[:1000])
    fp = bloom.contains(f, jnp.arange(10**6, 10**6 + 1000, dtype=jnp.uint32))
    print(f"bloom: no false negatives={bool(bloom.contains(f, keys[:1000]).all())}, "
          f"fp rate={float(fp.mean()):.4f}")


if __name__ == "__main__":
    main()
