"""Hash mixers for WarpCore-on-TPU.

The paper uses two independent hash functions: ``h`` for the initial probe
position and ``g`` for the double-hashing step (§II, §IV-B.2).  We provide
murmur3/xxhash-style avalanche mixers over uint32 lanes — cheap on the VPU
(multiplies + shifts + xors, all 32-bit native) — plus combiners for 64-bit
keys represented as (hi, lo) uint32 planes (DESIGN.md §2: TPU vector units
are 32-bit native, so "64-bit support" = two planes, not int64 vectors).

All functions are shape-polymorphic and jit/vmap/pallas-safe (pure jnp ops on
uint32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_U = jnp.uint32

# murmur3 fmix32 constants
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
# xxhash32 primes (used for the second, independent mixer)
_X2 = np.uint32(0x85EBCA77)
_X3 = np.uint32(0xC2B2AE3D)
_X4 = np.uint32(0x27D4EB2F)


def _shr(x, n):
    return jax.lax.shift_right_logical(x, _U(n))


def mix_murmur3(x: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer — full avalanche."""
    x = x.astype(_U)
    x = x ^ _shr(x, 16)
    x = x * _M1
    x = x ^ _shr(x, 13)
    x = x * _M2
    x = x ^ _shr(x, 16)
    return x


# modular inverses of the fmix32 multiply constants (odd => invertible
# mod 2^32); computed once so unmix stays in cheap 32-bit arithmetic
_M1_INV = np.uint32(pow(int(_M1), -1, 1 << 32))
_M2_INV = np.uint32(pow(int(_M2), -1, 1 << 32))


def unmix_murmur3(x: jax.Array) -> jax.Array:
    """Exact inverse of :func:`mix_murmur3` (fmix32 is a bijection on u32).

    xorshift-by-16 is self-inverse; xorshift-by-13 inverts as
    ``x ^ (x>>13) ^ (x>>26)``; the multiplies invert via the modular
    inverses of the (odd) constants.  The quotient store decodes stored
    remainders back to user keys with this (migration sweeps, debugging).
    """
    x = x.astype(_U)
    x = x ^ _shr(x, 16)
    x = x * _M2_INV
    x = x ^ _shr(x, 13) ^ _shr(x, 26)
    x = x * _M1_INV
    x = x ^ _shr(x, 16)
    return x


def full_hash(key_word: jax.Array, seed: int) -> jax.Array:
    """The (invertible) pre-modulo hash behind :func:`hash_rows`.

    Quotient stores keep ``h // p`` in the table instead of the key, so
    they need ``h`` itself — encode with this, decode with
    :func:`unfull_hash`.
    """
    return mix_murmur3(key_word.astype(_U) ^ _U(np.uint32(seed)))


def unfull_hash(h: jax.Array, seed: int) -> jax.Array:
    """Recover the key word from :func:`full_hash` output."""
    return unmix_murmur3(h) ^ _U(np.uint32(seed))


def mix_xxhash(x: jax.Array) -> jax.Array:
    """xxhash32 avalanche — independent second mixer for double hashing."""
    x = x.astype(_U)
    x = x ^ _shr(x, 15)
    x = x * _X2
    x = x ^ _shr(x, 13)
    x = x * _X3
    x = x ^ _shr(x, 16)
    x = x * _X4
    return x


def mix_identity(x: jax.Array) -> jax.Array:
    """Pathological hash for adversarial tests (primary clustering on LP)."""
    return x.astype(_U)


MIXERS = {
    "murmur3": mix_murmur3,
    "xxhash": mix_xxhash,
    "identity": mix_identity,
}


def combine_planes(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Fold a 64-bit key's (hi, lo) planes into one well-mixed u32 word.

    boost::hash_combine-style: asymmetric so (a,b) != (b,a).
    """
    h = mix_murmur3(lo)
    h = h ^ (mix_murmur3(hi) + _U(0x9E3779B9) + (h << _U(6)) + _shr(h, 2))
    return h


# ---------------------------------------------------------------------------
# composite multi-column keys — pack N u32 columns into key planes
# ---------------------------------------------------------------------------
#
# Relational workloads join / group on *tuples* of columns (the cuDF
# comparison class the paper benchmarks, §V).  A composite key is stored
# as ``key_words = N`` u32 planes, reusing exactly the representation the
# tables already use for 64-bit keys: plane 0 is the PRIMARY plane
# (carries the EMPTY/TOMBSTONE sentinels) and holds the LEAST significant
# column, so for two columns the planes are bit-for-bit the (hi, lo)
# planes of the u64 key ``(col0 << 32) | col1`` — the u64 fast path:
# packing is pure plane placement, no arithmetic, and a 2-column
# composite table is indistinguishable from a u64-keyed one.

def pack_columns(columns) -> jax.Array:
    """Pack a sequence of N (n,) u32 columns into (n, N) key planes.

    Column 0 is the MOST significant: lexicographic order over
    ``(col0, col1, ...)`` equals numeric order of the concatenated
    big-endian integer, and for N == 2 the result equals the table-native
    (hi, lo) planes of ``(col0 << 32) | col1`` (see ``common.split_u64``).
    The in-band sentinel restriction (``common.MAX_USER_KEY``) lands on
    the LAST column, which becomes plane 0.
    """
    if len(columns) == 0:
        raise ValueError("pack_columns needs at least one column")
    cols = []
    for i, c in enumerate(columns):
        c = jnp.asarray(c)
        if c.dtype == jnp.int32:
            c = c.astype(_U)
        if c.dtype != jnp.uint32:
            raise TypeError(f"column {i} must be uint32, got {c.dtype}")
        if c.ndim != 1:
            raise ValueError(f"column {i} must be 1-D, got shape {c.shape}")
        cols.append(c)
    if any(c.shape != cols[0].shape for c in cols):
        raise ValueError("key columns must share one length")
    # column 0 -> highest plane; plane 0 (sentinels) is the last column
    return jnp.stack(list(reversed(cols)), axis=1)


def unpack_columns(keys: jax.Array) -> tuple[jax.Array, ...]:
    """Inverse of ``pack_columns``: (n, N) key planes -> N (n,) columns."""
    keys = jnp.asarray(keys)
    if keys.ndim == 1:
        keys = keys[:, None]
    kw = keys.shape[-1]
    return tuple(keys[..., kw - 1 - i] for i in range(kw))


def hash_rows(key_word: jax.Array, num_rows: int, seed: int) -> jax.Array:
    """Initial probe row: h1(k) in [0, num_rows)."""
    return (full_hash(key_word, seed) % _U(num_rows)).astype(_U)


def hash_step(key_word: jax.Array, num_rows: int, seed: int) -> jax.Array:
    """Double-hashing row step: g(k) in [1, num_rows-1].

    With num_rows prime, every step generates the full cyclic group Z_p,
    i.e. the probe sequence visits every row exactly once (paper's
    cycle-freeness guarantee, §IV-B.2).
    """
    h = mix_xxhash(key_word ^ _U((int(seed) * 0x9E3779B1) & 0xFFFFFFFF))
    return (h % _U(num_rows - 1) + _U(1)).astype(_U)


def hash_owner(key_word: jax.Array, num_owners: int, seed: int = 0x5BD1E995) -> jax.Array:
    """Shard-owner assignment for the distributed mode (paper §IV-E).

    Independent from hash_rows/hash_step so intra-shard probing stays uniform
    after partitioning by owner.
    """
    h = mix_xxhash(mix_murmur3(key_word) ^ _U(np.uint32(seed)))
    return (h % _U(num_owners)).astype(_U)
