"""Owner-routing core: multisplit -> padded buffers -> all-to-all.

Every key is owned by exactly one shard (``hash_owner``); a batch headed
for the table must be routed to its owners and results routed back.  The
seed inlined this block (owner_of -> make_plan -> scatter -> all_to_all)
three times in ``repro.core.distributed`` and once more per relational
operator; this module is the single home.  ``repro.distributed.sharding``
re-exports ``ownership_exchange`` / ``ownership_return`` for relational
callers, and ``repro.core.distributed`` builds its insert/retrieve/erase
routing on them — without ``repro.core`` ever importing
``repro.distributed``.

The exchange is *padded*: each (src, dst) segment gets ``cap`` slots
(MoE-capacity-factor style), because fixed shapes are what TPU collectives
want.  Overflow is counted and returned — callers size ``slack`` so it is
zero (tests assert this).  A uniform hash keeps segment sizes balanced;
``jax.lax.ragged_all_to_all`` is a drop-in upgrade on runtimes that
support it.

All functions here run *inside* shard_map (they use axis names); build the
shard_map with ``repro.core.compat.shard_map_compat``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.common import EMPTY_KEY
from repro.core.compat import axis_size_compat

_U = jnp.uint32
_I = jnp.int32


# ---------------------------------------------------------------------------
# multisplit (paper [16] — TPU rendering: stable sort by owner)
# ---------------------------------------------------------------------------

def multisplit(owners: jax.Array, num_parts: int, *arrays: jax.Array):
    """Partition arrays by ``owners`` (values in [0, num_parts)).

    Returns (sorted_owners, counts, order, *sorted_arrays) where ``order``
    is the stable permutation (argsort by owner).
    """
    order = jnp.argsort(owners, stable=True)
    sorted_owners = owners[order]
    counts = jnp.bincount(owners, length=num_parts)
    return sorted_owners, counts, order, *[a[order] for a in arrays]


def owner_of(keys: jax.Array, num_owners: int, key_words: int) -> jax.Array:
    """Shard owner per key (independent mixer from probing — DESIGN.md §2).

    Folds ALL ``key_words`` planes (``key_hash_word``) before
    ``hash_owner``, so composite/u64 keys that differ only in a high
    plane land on independent owners — co-partitioning stays uniform for
    multi-column relational keys, not just the primary plane.
    """
    from repro.core import single_value as sv
    word = sv.key_hash_word(sv.normalize_key_batch(keys, key_words, "keys"))
    return hashing.hash_owner(word, num_owners)


# ---------------------------------------------------------------------------
# padded send-buffer construction + all-to-all exchange
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExchangePlan:
    """Bookkeeping to route a batch to owners and the results back."""
    slot: jax.Array        # (n,) destination slot in the send buffer (or OOR)
    valid_send: jax.Array  # (P*cap,) which send slots are populated
    overflow: jax.Array    # scalar: elements dropped because a segment overflowed
    cap: int


def make_plan(owners: jax.Array, num_parts: int, cap: int,
              mask: jax.Array | None = None) -> ExchangePlan:
    """Slot assignment for the padded exchange.

    ``mask`` (optional, (n,) bool) drops elements from the exchange
    entirely: a masked-out element routes to a virtual overflow segment,
    so it consumes NO slot in any real segment (a bloom-filtered lookup
    admitting 10% of a batch really does send 10% of the traffic), its
    ``slot`` is out-of-range (scatter drops it, the return gather fills),
    and it never counts as overflow.
    """
    n = owners.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    # masked-out elements rank inside a virtual segment `num_parts` that
    # gets no slots; with an all-True mask the math is the unmasked plan
    owners_eff = jnp.where(mask, owners, num_parts)
    counts = jnp.bincount(owners_eff, length=num_parts + 1)
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])[:-1]
    # stable rank of each element within its segment
    order = jnp.argsort(owners_eff, stable=True)
    rank_sorted = jnp.arange(n) - start[owners_eff[order]]
    rank = jnp.zeros((n,), rank_sorted.dtype).at[order].set(rank_sorted)
    ok = mask & (rank < cap)
    slot = jnp.where(ok, owners.astype(_I) * cap + rank.astype(_I), num_parts * cap)
    valid = jnp.zeros((num_parts * cap,), bool).at[slot].set(True, mode="drop")
    return ExchangePlan(slot=slot, valid_send=valid,
                        overflow=jnp.sum(mask & (rank >= cap), dtype=_I),
                        cap=cap)


def scatter_to_buffer(plan: ExchangePlan, x: jax.Array, num_parts: int,
                      fill=0) -> jax.Array:
    buf_shape = (num_parts * plan.cap,) + x.shape[1:]
    buf = jnp.full(buf_shape, fill, dtype=x.dtype)
    return buf.at[plan.slot].set(x, mode="drop")


def gather_from_buffer(plan: ExchangePlan, buf: jax.Array, fill=0) -> jax.Array:
    slot = jnp.minimum(plan.slot, buf.shape[0] - 1)
    out = buf[slot]
    ok = plan.slot < buf.shape[0]
    return jnp.where(ok.reshape((-1,) + (1,) * (out.ndim - 1)), out, fill)


def exchange(buf: jax.Array, axis: str) -> jax.Array:
    """All-to-all a (P*cap, ...) buffer over mesh axis ``axis``."""
    return jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=True)


# ---------------------------------------------------------------------------
# the consolidated owner-routing block
# ---------------------------------------------------------------------------

def ownership_exchange(keys, payload, axis: str, *, key_words: int = 1,
                       slack: float = 2.0, fill_key=None, mask=None):
    """Route (key, payload) batches to their owner shard over mesh ``axis``.

    Call inside shard_map.  Returns ``(recv_keys, recv_payload, recv_mask,
    plan)`` where the received arrays hold the elements this shard owns
    (padded segments; ``recv_mask`` marks live slots).  ``payload`` is a
    pytree of per-element arrays routed alongside the keys.  ``plan`` (an
    ``ExchangePlan``) carries the overflow count and lets per-received-slot
    results travel the reverse path (all_to_all is its own inverse here)
    via ``ownership_return``.  One shard is the sole writer for every key
    it receives — ownership partitioning as in DESIGN.md §2 / paper §IV-E.
    ``mask`` pre-filters the batch: masked-out elements never enter the
    all_to_all (their return-path result is the gather fill) — this is
    how the bloom front-end kills absent-key traffic locally.
    """
    from repro.core import single_value as sv
    num = axis_size_compat(axis)
    keys = sv.normalize_key_batch(keys, key_words, "keys")
    n = keys.shape[0]
    cap = int(np.ceil(n / num * slack))
    owners = owner_of(keys, num, key_words)
    plan = make_plan(owners, num, cap, mask=mask)
    kbuf = scatter_to_buffer(
        plan, keys, num, fill=EMPTY_KEY if fill_key is None else fill_key)
    recv_keys = exchange(kbuf, axis)
    recv_payload = jax.tree.map(
        lambda x: exchange(scatter_to_buffer(plan, x, num), axis), payload)
    recv_mask = exchange(plan.valid_send, axis)
    return recv_keys, recv_payload, recv_mask, plan


def ownership_return(plan: ExchangePlan, per_recv_slot, axis: str, fill=0):
    """Route a per-received-slot result back to the shard that sent it,
    realigned with that shard's original batch order."""
    back = exchange(per_recv_slot, axis)
    return gather_from_buffer(plan, back, fill=fill)
