"""CountingHashTable — counts distinct key occurrences (paper §IV).

A SingleValueHashTable whose value is a saturating u32 counter; inserting an
existing key increments it.  Built on ``single_value.update_values`` (the
read-modify-write upsert), so probing, layouts and backends are shared.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import single_value as sv
from repro.core.common import DEFAULT_SEED, DEFAULT_WINDOW

CountingHashTable = sv.SingleValueHashTable

_U32_MAX = jnp.uint32(0xFFFFFFFF)


def create(min_capacity: int, *, key_words: int = 1, window: int = DEFAULT_WINDOW,
           scheme: str = "cops", layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax") -> CountingHashTable:
    return sv.create(min_capacity, key_words=key_words, value_words=1,
                     window=window, scheme=scheme, layout=layout, seed=seed,
                     max_probes=max_probes, backend=backend)


def _sat_add(a, b):
    """Saturating u32 add — associative, so duplicate occurrences can be
    pre-merged by the bulk engine before the single table RMW."""
    s = a + b
    return jnp.where(s < a, _U32_MAX, s)


def insert(table: CountingHashTable, keys, mask=None, stats: bool = False):
    """Count each key occurrence (saturating at 2^32 - 1).

    The per-element operand is 1; the fold is a saturating add.  The
    ``("add",)`` combiner spec lets ``update_values`` take the vectorized
    bulk path (duplicates in the batch collapse to one RMW of the summed
    count); plain add is exact here — n operands of 1 cannot wrap u32 —
    and the saturation lives in the fold, where combined and stepwise
    increments agree.  ``stats`` (static) appends an in-graph
    ``obs.metrics.TableStats`` to the return.
    """
    def bump(old, key, new):
        return _sat_add(old, new)
    return sv.update_values(table, keys, bump, jnp.uint32(1), mask,
                            combine=("add",), stats=stats)


def insert_or_grow(table: CountingHashTable, keys, mask=None, *,
                   policy=None, max_attempts: int = 4):
    """``insert`` under the auto-growth policy (see ``repro.core.migrate``).

    The RMW fold rides through ``insert_or_grow``'s adapter hook: counter
    state migrates with the values (a grow/compact sweep carries each
    key's running count into the fresh store untouched)."""
    from repro.core import migrate
    return migrate.insert_or_grow(
        table, keys, None, mask,
        policy=migrate.DEFAULT_POLICY if policy is None else policy,
        insert_fn=lambda t, k, v, m: insert(t, k, m),
        max_attempts=max_attempts)


def counts(table: CountingHashTable, keys, stats: bool = False):
    """Occurrence count per key (0 when absent).

    Rides ``single_value.retrieve``'s backend dispatch: the default path
    is the fused bulk-retrieval engine (``repro.core.bulk_retrieve`` —
    duplicate query keys walk the table once), ``backend="scan"`` keeps
    the direct reference walk and ``"pallas"`` the lookup kernel.
    ``stats`` rides along (see ``single_value.retrieve``).
    """
    res = sv.retrieve(table, keys, stats=stats)
    vals, found = res[:2]
    out = jnp.where(found, vals, jnp.uint32(0))
    return (out, res[2]) if stats else out
