"""CountingHashTable — counts distinct key occurrences (paper §IV).

A SingleValueHashTable whose value is a saturating u32 counter; inserting an
existing key increments it.  Built on ``single_value.update_values`` (the
read-modify-write upsert), so probing, layouts and backends are shared.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import single_value as sv
from repro.core.common import DEFAULT_SEED, DEFAULT_WINDOW

CountingHashTable = sv.SingleValueHashTable

_U32_MAX = jnp.uint32(0xFFFFFFFF)


def create(min_capacity: int, *, key_words: int = 1, window: int = DEFAULT_WINDOW,
           scheme: str = "cops", layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax") -> CountingHashTable:
    return sv.create(min_capacity, key_words=key_words, value_words=1,
                     window=window, scheme=scheme, layout=layout, seed=seed,
                     max_probes=max_probes, backend=backend)


def insert(table: CountingHashTable, keys, mask=None,
           ) -> tuple[CountingHashTable, jax.Array]:
    """Count each key occurrence (saturating at 2^32 - 1)."""
    def bump(old, key, new):
        c = old[0]
        return jnp.where(c == _U32_MAX, c, c + jnp.uint32(1))[None]
    return sv.update_values(table, keys, bump, jnp.uint32(1), mask)


def counts(table: CountingHashTable, keys) -> jax.Array:
    """Occurrence count per key (0 when absent)."""
    vals, found = sv.retrieve(table, keys)
    return jnp.where(found, vals, jnp.uint32(0))
