"""jax-version compatibility shims (the container pins jax 0.4.37).

The seed was written against newer jax (``jax.shard_map`` with
``check_vma=``, ``jax.lax.axis_size``, ``jax.make_mesh(axis_types=...)``,
``jax.set_mesh``).  These helpers bridge every band back to 0.4.x, where
shard_map lives in ``jax.experimental.shard_map`` with ``check_rep=`` /
``auto=``, axis sizes come from a constant-folded ``psum(1, axis)``, mesh
axis types do not exist, and the Mesh object itself is the ambient-mesh
context manager.

Lives in ``repro.core`` (not ``repro.distributed``) so the core table
modules can use the shims without a core -> distributed import cycle;
``repro.distributed.sharding`` re-exports them for existing callers.
"""

from __future__ import annotations

import contextlib
import inspect

import jax


def supports_u64_sort() -> bool:
    """True when XLA sorts *genuine* uint64 operands on this config.

    jax's default (x64-disabled) config silently canonicalizes uint64
    arrays down to uint32, which would corrupt a packed two-plane sort
    word — so the check is on the **effective** dtype, not the jax
    version: ``canonicalize_dtype(uint64)`` only survives as uint64 when
    ``jax_enable_x64`` is on (globally or via the
    ``jax.experimental.enable_x64`` context).  Evaluated at trace time on
    every call (it is one dict lookup) because the x64 config can toggle
    mid-process; jit caches are keyed on that config, so a flip retraces
    into the matching lane.
    """
    import numpy as np
    try:
        return jax.dtypes.canonicalize_dtype(np.uint64) == np.dtype("uint64")
    except Exception:
        return False


def axis_size_compat(axis) -> int:
    """Static mesh-axis size inside shard_map, across jax versions
    (``lax.axis_size`` is recent; ``psum(1, axis)`` constant-folds)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` across jax versions.

    Newest jax exposes ``jax.shard_map(..., check_vma=)``; the 0.6.x band
    has ``jax.shard_map(..., check_rep=)``; older releases only have
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  Replication
    checking is disabled either way (table pytrees carry per-shard state on
    purpose).  ``axis_names`` restricts manual axes (new jax); on old jax
    it maps to the complementary ``auto=`` set.
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kwargs = {("check_vma" if "check_vma" in params else "check_rep"): False}
    if axis_names is not None:
        if "axis_names" in params:
            kwargs["axis_names"] = frozenset(axis_names)
        else:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def make_mesh_compat(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the version has them.

    ``jax.sharding.AxisType`` / the ``axis_types=`` kwarg only exist on
    newer jax; 0.4.x meshes behave like Auto everywhere already.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh_compat(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` is recent; on 0.4.x the Mesh object itself is the
    context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
