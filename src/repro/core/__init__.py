"""repro.core — WarpCore-on-TPU: hash table data structures in JAX.

Paper structures (§IV): SingleValueHashTable, MultiValueHashTable,
BucketListHashTable, HashSet, CountingHashTable, BloomFilter, plus the
multi-GPU distributed/independent modes rendered over jax.shard_map.
"""

from repro.core.common import (
    EMPTY_KEY,
    TOMBSTONE_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_POOL_FULL,
    STATUS_UPDATED,
    table_geometry,
)
from repro.core.single_value import SingleValueHashTable
from repro.core.multi_value import MultiValueHashTable
from repro.core.bucket_list import BucketListHashTable
from repro.core.hashset import HashSet
from repro.core.counting import CountingHashTable
from repro.core.bloom import BloomFilter

from repro.core import (
    bloom,
    bucket_list,
    bulk,
    compat,
    counting,
    distributed,
    exchange,
    hashing,
    hashset,
    layouts,
    multi_value,
    probing,
    single_value,
)

__all__ = [
    "EMPTY_KEY", "TOMBSTONE_KEY",
    "STATUS_INSERTED", "STATUS_UPDATED", "STATUS_FULL", "STATUS_MASKED",
    "STATUS_POOL_FULL",
    "table_geometry",
    "SingleValueHashTable", "MultiValueHashTable", "BucketListHashTable",
    "HashSet", "CountingHashTable", "BloomFilter",
    "bloom", "bucket_list", "bulk", "compat", "counting", "distributed",
    "exchange", "hashing", "hashset", "layouts", "multi_value", "probing",
    "single_value",
]
