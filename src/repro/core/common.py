"""Shared constants, capacity arithmetic and pytree helpers for repro.core.

WarpCore stores keys in-band: an ``EMPTY`` sentinel marks a never-occupied
slot and a ``TOMBSTONE`` marks a deleted one (paper §IV-B.5).  User keys must
avoid both sentinels on the *primary* 32-bit plane (the paper has the same
``k_e`` restriction).

Capacity follows the paper's cycle-freeness rule ``c = p * W`` with ``p``
prime (§IV-B.2, generalized from the warp width 32 to a configurable probe
window ``W``): the table is laid out as a 2-D ``(p, W)`` array so that one
probe window is one hardware-aligned row — the TPU analogue of "all 32 lanes
hit one cache line".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# In-band sentinels (uint32 key plane).
EMPTY_KEY = np.uint32(0xFFFFFFFF)
TOMBSTONE_KEY = np.uint32(0xFFFFFFFE)
MAX_USER_KEY = np.uint32(0xFFFFFFFD)

# Insert status codes (per input element).
STATUS_INSERTED = 0      # claimed a fresh slot
STATUS_UPDATED = 1       # single-value: key existed, value overwritten ("duplicate warning")
STATUS_FULL = 2          # probing exhausted without finding a slot
STATUS_MASKED = 3        # input element was masked out
STATUS_POOL_FULL = 4     # bucket-list: value pool exhausted

# Probe-window widths supported (paper CG sizes 1..32; TPU lanes allow 128).
SUPPORTED_WINDOWS = (1, 2, 4, 8, 16, 32, 64, 128)

DEFAULT_WINDOW = 32
DEFAULT_SEED = 0x9E3779B9


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n % 2 == 0:
        return n == 2
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


def next_prime(n: int) -> int:
    """Smallest prime >= n."""
    n = max(2, int(n))
    while not is_prime(n):
        n += 1
    return n


def table_geometry(min_capacity: int, window: int) -> tuple[int, int]:
    """Return ``(num_rows, capacity)`` with num_rows prime and capacity = rows * window.

    Guarantees capacity >= min_capacity.  num_rows prime keeps double hashing
    over rows cycle-free (step sizes drawn from [1, p-1] generate Z_p).
    """
    if window not in SUPPORTED_WINDOWS:
        raise ValueError(f"window={window} not in {SUPPORTED_WINDOWS}")
    rows = next_prime(max(3, math.ceil(min_capacity / window)))
    return rows, rows * window


def register_struct(cls):
    """Register a dataclass as a jax pytree; fields with ``metadata={'static': True}``
    become aux data."""
    data_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    meta_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]
    jax.tree_util.register_dataclass(cls, data_fields=data_fields, meta_fields=meta_fields)
    return cls


def static_field(**kwargs):
    return dataclasses.field(metadata={"static": True}, **kwargs)


def as_u32(x) -> jax.Array:
    x = jnp.asarray(x)
    if x.dtype == jnp.uint32:
        return x
    if x.dtype in (jnp.int32, jnp.int64, jnp.uint64):
        return x.astype(jnp.uint32)
    raise TypeError(f"cannot reinterpret {x.dtype} as uint32 keys")


def split_u64(x) -> tuple[jax.Array, jax.Array]:
    """Split 64-bit integers into (hi, lo) uint32 planes.

    Works without jax_enable_x64 when given a numpy uint64 array (planes are
    extracted host-side); for traced inputs requires x64 or an (..., 2) u32 rep.
    """
    if isinstance(x, np.ndarray) and x.dtype == np.uint64:
        lo = (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (x >> np.uint64(32)).astype(np.uint32)
        return jnp.asarray(hi), jnp.asarray(lo)
    x = jnp.asarray(x)
    if x.dtype == jnp.uint64:
        return (x >> 32).astype(jnp.uint32), (x & 0xFFFFFFFF).astype(jnp.uint32)
    raise TypeError(f"expected uint64, got {x.dtype}")


def join_u64(hi: jax.Array, lo: jax.Array) -> np.ndarray:
    """Join (hi, lo) u32 planes into numpy uint64 (host-side convenience)."""
    hi = np.asarray(hi, dtype=np.uint64)
    lo = np.asarray(lo, dtype=np.uint64)
    return (hi << np.uint64(32)) | lo


def check_user_keys(keys: jax.Array) -> jax.Array:
    """Debug guard: no key may collide with a sentinel on the primary plane."""
    bad = (keys == EMPTY_KEY) | (keys == TOMBSTONE_KEY)
    return ~bad


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))
