"""Probing schemes over the (p, W) row layout — COPS and baselines.

The table is a 2-D array of ``p`` rows (p prime) by ``W`` lanes.  One *probe
window* is one row: the whole row is examined with vector ops — the TPU
analogue of the paper's warp-cooperative window (§IV-B.2).  The *outer*
scheme walks rows; the *inner* scheme is always "linear over the W lanes of
the row", resolved by a vectorized vote (see ``vote_*`` below).

Schemes (outer walk), all incremental to stay u32-overflow-safe:

- ``"cops"``   — double hashing over rows: row_{l+1} = (row_l + g(k)) mod p.
                 This is the paper's COPS (DH outer + LP inner).  With W=1 it
                 degenerates to scalar double hashing (cuDPP-style baseline).
- ``"linear"`` — row_{l+1} = (row_l + 1) mod p.  With W=1 this is the
                 one-thread-per-key linear probing baseline (cuDF-style);
                 with W>1 it is "blocked LP".  Exhibits primary clustering.
- ``"quadratic"`` — row_{l+1} = (row_l + 2l + 1) mod p (incremental l^2).
- ``"bucketed"`` — two-choice bucket placement (Compact Parallel Hash
                 Tables, PAPERS.md): a key has exactly TWO candidate
                 buckets, b1 = h1(k) mod p and b2 = (b1 + g(k)) mod p with
                 g in [1, p-1] (so b2 != b1 always).  The walk is a COPS
                 walk truncated to two rows; the insert path adds bounded
                 cuckoo eviction on top (see ``core.cuckoo``).  Constant
                 probe length makes retrieval throughput flat in the load
                 factor — the high-rho lane.

Each key's walk starts at ``h1(k) mod p`` and runs at most ``max_probes``
attempts (default p: DH/LP visit every row exactly once, the paper's abort
criterion "all slots visited").

**Coverage clamp** (:func:`scheme_coverage` / :func:`effective_probes`):
a scheme only ever reaches ``scheme_coverage(scheme, p)`` *distinct* rows —
p for cops/linear, (p+1)/2 for quadratic (the quadratic residues
``l^2 mod p`` repeat as soon as ``l > (p-1)/2`` since ``l^2 = (p-l)^2``),
2 for bucketed.  Walks beyond that budget revisit rows: retrieval wastes
probes, multi-value counting double-counts, and the bulk engine's
claim fixpoint gives revisited rows a second chance the sequential
reference never takes.  Every engine clamps its per-walk budget to
``effective_probes`` so all walks are revisit-free by construction.

**Quotient storage** (``quotient=True`` store geometries): the bucketed
lane can store ``q*2 + choice`` instead of the key, where ``h = mix(k ^
seed)``, ``b1 = h mod p``, ``q = h // p`` and ``choice`` says whether the
slot is the key's first or second bucket.  ``g`` is derived from ``q``
alone so the full hash (and hence the key — the mixer is a bijection) is
recoverable from (row, stored word); see ``hashing.unmix_murmur3``.  The
helpers below (:func:`initial_row` / :func:`row_step` with
``quotient=True``, :func:`match_word`, :func:`stored_word`) let the
engines treat the pre-mixed hash as the "key word": the probe compare
target becomes attempt-dependent (``q*2 + attempt``), everything else is
unchanged.  Stored words satisfy ``q*2+1 < TOMBSTONE_KEY`` for p >= 3, so
the in-band sentinels stay unambiguous.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

_U = jnp.uint32

SCHEMES = ("cops", "linear", "quadratic", "bucketed")

#: walks whose clamped budget is at most this many windows are unrolled by
#: the bulk engines instead of run as an early-exit while_loop — the
#: bucketed two-choice walk (budget 2) then costs the same at every load
#: factor, which is what keeps its retrieve throughput flat in rho
UNROLL_PROBES = 2


def scheme_coverage(scheme: str, num_rows: int) -> int:
    """Number of DISTINCT rows a scheme's walk can ever reach (static).

    cops/linear generate Z_p (full coverage); quadratic reaches only the
    (p+1)/2 quadratic residues (``l^2 mod p`` collides for l and p-l);
    bucketed is two-choice by definition.
    """
    if scheme == "quadratic":
        return (num_rows + 1) // 2
    if scheme == "bucketed":
        return min(2, num_rows)
    if scheme in ("cops", "linear"):
        return num_rows
    raise ValueError(f"unknown scheme {scheme!r}")


def effective_probes(scheme: str, max_probes: int, num_rows: int) -> int:
    """Per-walk probe budget clamped to the scheme's distinct-row coverage.

    The coverage-clamp bugfix: walking past the coverage revisits rows —
    spurious FULL/absent reports on quadratic (budget burnt on repeats),
    double-counted matches in multi-value counting, and jax/scan fixpoint
    divergence.  Semantics-preserving for cops/linear (clamp is a no-op).
    """
    return max(1, min(int(max_probes), scheme_coverage(scheme, num_rows)))


def stops_at_empty(scheme: str) -> bool:
    """Whether a walk may stop at the first window containing EMPTY.

    True for every scheme: inserts always claim the earliest candidate row
    of their probe sequence and deletes write TOMBSTONE (never EMPTY), so
    "window has EMPTY => key cannot live in any later row" is an invariant
    even under bucketed cuckoo eviction (victims move OUT of full buckets,
    and their vacated slot becomes a TOMBSTONE).  Kept as an explicit
    predicate so future schemes that break the invariant have one switch
    to flip.
    """
    return True


def initial_row(key_word: jax.Array, num_rows: int, seed: int,
                quotient: bool = False) -> jax.Array:
    """First probe row.  With ``quotient=True`` the engine's "key word" is
    already the full mixed hash ``h`` (see module docstring): the row is
    plainly ``h mod p`` — re-mixing would lose invertibility."""
    if quotient:
        return (key_word.astype(_U) % _U(num_rows)).astype(_U)
    return hashing.hash_rows(key_word, num_rows, seed)


def row_step(scheme: str, key_word: jax.Array, num_rows: int, seed: int,
             quotient: bool = False) -> jax.Array:
    """Per-key row increment (constant across attempts for cops/linear)."""
    if scheme in ("cops", "bucketed"):
        if quotient:
            # step must be a function of q = h // p ONLY so that decoding
            # a stored word (which keeps q but drops b1) can re-derive it
            return hashing.hash_step(key_word.astype(_U) // _U(num_rows),
                                     num_rows, seed)
        return hashing.hash_step(key_word, num_rows, seed)
    if scheme == "linear":
        return jnp.ones_like(key_word)
    if scheme == "quadratic":
        # placeholder; quadratic uses the attempt counter, see advance_row
        return jnp.ones_like(key_word)
    raise ValueError(f"unknown scheme {scheme!r}")


def advance_row(scheme: str, row: jax.Array, step: jax.Array, attempt: jax.Array,
                num_rows: int) -> jax.Array:
    """Next row after ``attempt`` completed probes (attempt counts from 0)."""
    p = _U(num_rows)
    if scheme == "quadratic":
        # (l+1)^2 - l^2 = 2l + 1
        inc = (_U(2) * attempt.astype(_U) + _U(1)) % p
    else:
        inc = step
    return (row + inc) % p


# ---------------------------------------------------------------------------
# quotient-store helpers (bucketed lane, key_words == 1)
# ---------------------------------------------------------------------------

def match_word(key_word: jax.Array, num_rows: int, attempt,
               quotient: bool = False) -> jax.Array:
    """Probe-compare target at ``attempt`` (0 = first bucket).

    Non-quotient stores compare the raw key word (attempt-independent).
    Quotient stores hold ``q*2 + choice``; a probe at attempt ``a``
    matches exactly the stored word ``q*2 + a``.
    """
    if not quotient:
        return key_word
    q = key_word.astype(_U) // _U(num_rows)
    a = attempt if isinstance(attempt, int) else attempt.astype(_U)
    return q * _U(2) + _U(1) * a


def stored_word(key_word: jax.Array, num_rows: int, choice,
                quotient: bool = False) -> jax.Array:
    """Word written into the key plane when a claim lands.

    ``choice`` is 0 when the slot's row is the key's first bucket, 1 for
    the second (for quotient stores; ignored otherwise).
    """
    if not quotient:
        return key_word
    q = key_word.astype(_U) // _U(num_rows)
    c = choice if isinstance(choice, int) else choice.astype(_U)
    return q * _U(2) + _U(1) * c


# ---------------------------------------------------------------------------
# In-window votes — the vector analogue of __ballot_sync + __ffs (paper step 3/4)
# ---------------------------------------------------------------------------

def vote_lowest(mask: jax.Array) -> jax.Array:
    """Index of the lowest set lane, or W if none.  mask: (..., W) bool."""
    w = mask.shape[-1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, mask.shape, mask.ndim - 1)
    return jnp.min(jnp.where(mask, lanes, jnp.int32(w)), axis=-1)


def vote_any(mask: jax.Array) -> jax.Array:
    """Group-any over the window lanes."""
    return jnp.any(mask, axis=-1)


def vote_count(mask: jax.Array) -> jax.Array:
    """Population count over the window lanes (multi-value counting pass).
    Pinned to i32 (bare integer sums promote to i64 under x64)."""
    return jnp.sum(mask, axis=-1, dtype=jnp.int32)
