"""Probing schemes over the (p, W) row layout — COPS and baselines.

The table is a 2-D array of ``p`` rows (p prime) by ``W`` lanes.  One *probe
window* is one row: the whole row is examined with vector ops — the TPU
analogue of the paper's warp-cooperative window (§IV-B.2).  The *outer*
scheme walks rows; the *inner* scheme is always "linear over the W lanes of
the row", resolved by a vectorized vote (see ``vote_*`` below).

Schemes (outer walk), all incremental to stay u32-overflow-safe:

- ``"cops"``   — double hashing over rows: row_{l+1} = (row_l + g(k)) mod p.
                 This is the paper's COPS (DH outer + LP inner).  With W=1 it
                 degenerates to scalar double hashing (cuDPP-style baseline).
- ``"linear"`` — row_{l+1} = (row_l + 1) mod p.  With W=1 this is the
                 one-thread-per-key linear probing baseline (cuDF-style);
                 with W>1 it is "blocked LP".  Exhibits primary clustering.
- ``"quadratic"`` — row_{l+1} = (row_l + 2l + 1) mod p (incremental l^2).

Each key's walk starts at ``h1(k) mod p`` and runs at most ``max_probes``
attempts (default p: DH/LP visit every row exactly once, the paper's abort
criterion "all slots visited").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

_U = jnp.uint32

SCHEMES = ("cops", "linear", "quadratic")


def initial_row(key_word: jax.Array, num_rows: int, seed: int) -> jax.Array:
    return hashing.hash_rows(key_word, num_rows, seed)


def row_step(scheme: str, key_word: jax.Array, num_rows: int, seed: int) -> jax.Array:
    """Per-key row increment (constant across attempts for cops/linear)."""
    if scheme == "cops":
        return hashing.hash_step(key_word, num_rows, seed)
    if scheme == "linear":
        return jnp.ones_like(key_word)
    if scheme == "quadratic":
        # placeholder; quadratic uses the attempt counter, see advance_row
        return jnp.ones_like(key_word)
    raise ValueError(f"unknown scheme {scheme!r}")


def advance_row(scheme: str, row: jax.Array, step: jax.Array, attempt: jax.Array,
                num_rows: int) -> jax.Array:
    """Next row after ``attempt`` completed probes (attempt counts from 0)."""
    p = _U(num_rows)
    if scheme == "quadratic":
        # (l+1)^2 - l^2 = 2l + 1
        inc = (_U(2) * attempt.astype(_U) + _U(1)) % p
    else:
        inc = step
    return (row + inc) % p


# ---------------------------------------------------------------------------
# In-window votes — the vector analogue of __ballot_sync + __ffs (paper step 3/4)
# ---------------------------------------------------------------------------

def vote_lowest(mask: jax.Array) -> jax.Array:
    """Index of the lowest set lane, or W if none.  mask: (..., W) bool."""
    w = mask.shape[-1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, mask.shape, mask.ndim - 1)
    return jnp.min(jnp.where(mask, lanes, jnp.int32(w)), axis=-1)


def vote_any(mask: jax.Array) -> jax.Array:
    """Group-any over the window lanes."""
    return jnp.any(mask, axis=-1)


def vote_count(mask: jax.Array) -> jax.Array:
    """Population count over the window lanes (multi-value counting pass)."""
    return jnp.sum(mask.astype(jnp.int32), axis=-1)
