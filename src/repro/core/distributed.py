"""Distributed hash tables over a device mesh (paper §IV-E).

Two modes, exactly as in the paper:

- **distributed** — every key is owned by exactly one shard (device).  An
  insert/query batch is partitioned by owner with a *multisplit*, exchanged
  with an all-to-all, and applied to the local shard.  Retrieval needs no
  result merging (single owner per key).  On the GPU the multisplit is
  Ashkiani et al.'s warp-level primitive [16]; on TPU the idiomatic
  equivalent is a stable sort by owner (no scatter hardware, sorts are
  fast), and the NVLink all-to-all becomes ``jax.lax.all_to_all`` over an
  ICI mesh axis.

- **independent** — one autonomous table per device; inserts apply locally,
  queries are answered by every shard (caller merges).

The owner-routing block itself (owner_of -> make_plan -> scatter ->
all_to_all) lives in ``repro.core.exchange`` — one implementation shared
with the relational operators via ``repro.distributed.sharding`` — and the
ops here are thin compositions of ``ownership_exchange`` /
``ownership_return`` with the local table ops.

All functions here run *inside* shard_map (they use axis names); the
``shard_*`` wrappers at the bottom build it via
``repro.core.compat.shard_map_compat``, which bridges jax versions.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import single_value as sv
from repro.core.compat import axis_size_compat, shard_map_compat
from repro.core.exchange import (
    ExchangePlan,
    exchange,
    gather_from_buffer,
    make_plan,
    multisplit,
    owner_of,
    ownership_exchange,
    ownership_return,
    scatter_to_buffer,
)

_U = jnp.uint32
_I = jnp.int32


# ---------------------------------------------------------------------------
# distributed-mode ops (call inside shard_map over ``axis``)
# ---------------------------------------------------------------------------

def insert_distributed(table: sv.SingleValueHashTable, keys, values, axis: str,
                       slack: float = 2.0, insert_fn: Callable | None = None):
    """Route (key, value) pairs to their owner shard and insert locally.

    Returns (table, status_of_received, overflow).  ``insert_fn`` lets the
    multi-value / counting variants reuse the same routing.
    """
    values = sv.normalize_words(values, table.value_words, "values")
    recv_keys, recv_values, recv_mask, plan = ownership_exchange(
        keys, values, axis, key_words=table.key_words, slack=slack)
    fn = insert_fn or sv.insert
    table, status = fn(table, recv_keys, recv_values, mask=recv_mask)
    return table, status, plan.overflow


def retrieve_distributed(table: sv.SingleValueHashTable, keys, axis: str,
                         slack: float = 2.0):
    """Route queries to owners, look up locally, route answers back.

    Returns (values, found, overflow) aligned with the local query batch.
    No merge step is needed — single-owner keys (paper §IV-E).
    """
    recv_keys, _, _, plan = ownership_exchange(
        keys, (), axis, key_words=table.key_words, slack=slack)
    vals, found = sv.retrieve(table, recv_keys)
    vals = sv.normalize_words(vals, table.value_words, "values")
    # answers travel the reverse path: all_to_all is its own inverse here
    out_vals = ownership_return(plan, vals, axis)
    out_found = ownership_return(plan, found, axis, fill=False)
    if table.value_words == 1:
        out_vals = out_vals[:, 0]
    return out_vals, out_found, plan.overflow


def retrieve_distributed_filtered(table: sv.SingleValueHashTable, filt,
                                  keys, axis: str, slack: float = 2.0):
    """Bloom-filtered distributed retrieve: absent keys die locally.

    ``filt`` is this shard's :class:`~repro.core.bloom.BloomFilter` over
    its table's live keys (folded key word — see ``bloom.rebuild_from_
    table``).  The filter planes are all-gathered once (they are tiny
    next to the table), each query is admission-tested against its
    *owner's* plane, and only admitted keys enter the all_to_all —
    masked-out keys answer ``found=False`` locally, which is exact
    because a bloom miss is proof of absence.  Returns ``(values, found,
    skips, overflow)`` aligned with the local query batch; ``skips``
    counts the queries this shard never sent (the saved traffic).
    """
    from repro.core import bloom
    num = axis_size_compat(axis)
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    owners = owner_of(keys, num, table.key_words)
    words = sv.key_hash_word(keys)
    bits_all = jax.lax.all_gather(filt.bits, axis)   # (P, blocks, block_bits)
    admit = bloom.contains_stack(filt, bits_all, owners, words)
    recv_keys, _, _, plan = ownership_exchange(
        keys, (), axis, key_words=table.key_words, slack=slack, mask=admit)
    vals, found = sv.retrieve(table, recv_keys)
    vals = sv.normalize_words(vals, table.value_words, "values")
    out_vals = ownership_return(plan, vals, axis)
    out_found = ownership_return(plan, found, axis, fill=False)
    if table.value_words == 1:
        out_vals = out_vals[:, 0]
    return out_vals, out_found, jnp.sum(~admit, dtype=_I), plan.overflow


def erase_distributed(table: sv.SingleValueHashTable, keys, axis: str,
                      slack: float = 2.0):
    recv_keys, _, recv_mask, plan = ownership_exchange(
        keys, (), axis, key_words=table.key_words, slack=slack)
    table, erased = sv.erase(table, recv_keys, mask=recv_mask)
    return table, ownership_return(plan, erased, axis, fill=False), \
        plan.overflow


# ---------------------------------------------------------------------------
# independent-mode ops (paper §IV-E second mode)
# ---------------------------------------------------------------------------

def insert_independent(table: sv.SingleValueHashTable, keys, values,
                       insert_fn: Callable | None = None):
    """Insert the local batch into the local table (data already scattered)."""
    fn = insert_fn or sv.insert
    table, status = fn(table, keys, values)
    return table, status


def retrieve_independent(table: sv.SingleValueHashTable, keys, axis: str):
    """Broadcast queries to every shard; each answers from its own table.

    Returns (values, found) where found is True iff ANY shard holds the key
    and values comes from the lowest-indexed shard that holds it (merge rule).
    """
    all_keys = jax.lax.all_gather(keys, axis, tiled=True)     # (P*n, kw?) queries
    vals, found = sv.retrieve(table, all_keys)
    vals = sv.normalize_words(vals, table.value_words, "values")
    # merge: each shard contributes only where it found the key; lowest shard wins
    idx = jax.lax.axis_index(axis)
    rank = jnp.where(found, idx, axis_size_compat(axis))
    best = jax.lax.pmin(rank, axis)
    mine = rank == best
    contrib = jnp.where(mine[:, None] & found[:, None], vals, 0)
    merged_vals = jax.lax.psum(contrib, axis)
    merged_found = jax.lax.psum(found.astype(_I), axis) > 0
    # return this shard's slice of the gathered batch
    n = keys.shape[0]
    lo = idx * n
    out_v = jax.lax.dynamic_slice_in_dim(merged_vals, lo, n, 0)
    out_f = jax.lax.dynamic_slice_in_dim(merged_found, lo, n, 0)
    if table.value_words == 1:
        out_v = out_v[:, 0]
    return out_v, out_f


# ---------------------------------------------------------------------------
# host-level wrappers: build the shard_map for a mesh axis
# ---------------------------------------------------------------------------

def create_sharded(mesh: Mesh, axis: str, capacity_per_shard: int, **kwargs
                   ) -> sv.SingleValueHashTable:
    """A table whose arrays have a leading shard dim sharded over ``axis``."""
    num = int(mesh.shape[axis])

    def mk():
        t = sv.create(capacity_per_shard, **kwargs)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (num,) + x.shape), t)

    template = jax.eval_shape(mk)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, P(axis, *([None] * (len(s.shape) - 1)))),
        template)
    return jax.jit(mk, out_shardings=shardings)()


def _local(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _relift(tree):
    return jax.tree.map(lambda x: x[None], tree)


def shard_insert(mesh: Mesh, axis: str, table, keys, values, slack: float = 2.0,
                 insert_fn: Callable | None = None):
    """Host-level distributed-mode insert: keys/values sharded over ``axis``."""
    spec = jax.tree.map(lambda _: P(axis), table)

    def body(t, k, v):
        t_loc, s, ov = insert_distributed(_local(t), k, v, axis, slack, insert_fn)
        return _relift(t_loc), s, ov[None]

    f = shard_map_compat(body, mesh, in_specs=(spec, P(axis), P(axis)),
                         out_specs=(spec, P(axis), P(axis)))
    return f(table, keys, values)


def shard_retrieve(mesh: Mesh, axis: str, table, keys, slack: float = 2.0):
    spec = jax.tree.map(lambda _: P(axis), table)

    def body(t, k):
        v, fnd, ov = retrieve_distributed(_local(t), k, axis, slack)
        return v, fnd, ov[None]

    f = shard_map_compat(body, mesh, in_specs=(spec, P(axis)),
                         out_specs=(P(axis), P(axis), P(axis)))
    return f(table, keys)
