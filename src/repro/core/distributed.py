"""Distributed hash tables over a device mesh (paper §IV-E).

Two modes, exactly as in the paper:

- **distributed** — every key is owned by exactly one shard (device).  An
  insert/query batch is partitioned by owner with a *multisplit*, exchanged
  with an all-to-all, and applied to the local shard.  Retrieval needs no
  result merging (single owner per key).  On the GPU the multisplit is
  Ashkiani et al.'s warp-level primitive [16]; on TPU the idiomatic
  equivalent is a stable sort by owner (no scatter hardware, sorts are
  fast), and the NVLink all-to-all becomes ``jax.lax.all_to_all`` over an
  ICI mesh axis.

- **independent** — one autonomous table per device; inserts apply locally,
  queries are answered by every shard (caller merges).

The exchange is *padded*: each (src, dst) segment gets ``cap`` slots
(MoE-capacity-factor style), because fixed shapes are what TPU collectives
want.  Overflow is counted and returned — callers size ``slack`` so it is
zero (tests assert this), mirroring how MoE capacity factors are tuned.
A uniform hash (``hash_owner``) keeps segment sizes balanced, so modest
slack suffices; ``jax.lax.ragged_all_to_all`` is a drop-in upgrade on
runtimes that support it (see ``exchange_ragged``).

All functions here run *inside* ``jax.shard_map`` (they use axis names);
the ``shard_*`` wrappers at the bottom build the shard_map for you.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hashing
from repro.core import single_value as sv
from repro.core.common import EMPTY_KEY

_U = jnp.uint32
_I = jnp.int32


# ---------------------------------------------------------------------------
# multisplit (paper [16] — TPU rendering: stable sort by owner)
# ---------------------------------------------------------------------------

def multisplit(owners: jax.Array, num_parts: int, *arrays: jax.Array):
    """Partition arrays by ``owners`` (values in [0, num_parts)).

    Returns (sorted_owners, counts, order, *sorted_arrays) where ``order`` is
    the stable permutation (argsort by owner).
    """
    order = jnp.argsort(owners, stable=True)
    sorted_owners = owners[order]
    counts = jnp.bincount(owners, length=num_parts)
    return sorted_owners, counts, order, *[a[order] for a in arrays]


def owner_of(keys: jax.Array, num_owners: int, key_words: int) -> jax.Array:
    """Shard owner per key (independent mixer from probing — DESIGN.md §2)."""
    word = sv.key_hash_word(sv.normalize_words(keys, key_words, "keys"))
    return hashing.hash_owner(word, num_owners)


# ---------------------------------------------------------------------------
# padded send-buffer construction + all-to-all exchange
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExchangePlan:
    """Bookkeeping to route a batch to owners and the results back."""
    slot: jax.Array        # (n,) destination slot in the send buffer (or OOR)
    valid_send: jax.Array  # (P*cap,) which send slots are populated
    overflow: jax.Array    # scalar: elements dropped because a segment overflowed
    cap: int


def make_plan(owners: jax.Array, num_parts: int, cap: int) -> ExchangePlan:
    n = owners.shape[0]
    counts = jnp.bincount(owners, length=num_parts)
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])[:-1]
    # stable rank of each element within its segment
    order = jnp.argsort(owners, stable=True)
    rank_sorted = jnp.arange(n) - start[owners[order]]
    rank = jnp.zeros((n,), rank_sorted.dtype).at[order].set(rank_sorted)
    ok = rank < cap
    slot = jnp.where(ok, owners.astype(_I) * cap + rank.astype(_I), num_parts * cap)
    valid = jnp.zeros((num_parts * cap,), bool).at[slot].set(True, mode="drop")
    return ExchangePlan(slot=slot, valid_send=valid,
                        overflow=jnp.sum(~ok, dtype=_I), cap=cap)


def scatter_to_buffer(plan: ExchangePlan, x: jax.Array, num_parts: int,
                      fill=0) -> jax.Array:
    buf_shape = (num_parts * plan.cap,) + x.shape[1:]
    buf = jnp.full(buf_shape, fill, dtype=x.dtype)
    return buf.at[plan.slot].set(x, mode="drop")


def gather_from_buffer(plan: ExchangePlan, buf: jax.Array, fill=0) -> jax.Array:
    slot = jnp.minimum(plan.slot, buf.shape[0] - 1)
    out = buf[slot]
    ok = plan.slot < buf.shape[0]
    return jnp.where(ok.reshape((-1,) + (1,) * (out.ndim - 1)), out, fill)


def exchange(buf: jax.Array, axis: str) -> jax.Array:
    """All-to-all a (P*cap, ...) buffer over mesh axis ``axis``."""
    return jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=True)


# ---------------------------------------------------------------------------
# distributed-mode ops (call inside shard_map over ``axis``)
# ---------------------------------------------------------------------------

def insert_distributed(table: sv.SingleValueHashTable, keys, values, axis: str,
                       slack: float = 2.0, insert_fn: Callable | None = None):
    """Route (key, value) pairs to their owner shard and insert locally.

    Returns (table, status_of_received, overflow).  ``insert_fn`` lets the
    multi-value / counting variants reuse the same routing.
    """
    num_parts = jax.lax.axis_size(axis)
    keys = sv.normalize_words(keys, table.key_words, "keys")
    values = sv.normalize_words(values, table.value_words, "values")
    n = keys.shape[0]
    cap = int(np.ceil(n / num_parts * slack))
    owners = owner_of(keys, num_parts, table.key_words)
    plan = make_plan(owners, num_parts, cap)
    kbuf = scatter_to_buffer(plan, keys, num_parts, fill=EMPTY_KEY)
    vbuf = scatter_to_buffer(plan, values, num_parts)
    mbuf = scatter_to_buffer(plan, jnp.ones((n,), bool), num_parts, fill=False)
    rk, rv, rm = exchange(kbuf, axis), exchange(vbuf, axis), exchange(mbuf, axis)
    fn = insert_fn or sv.insert
    table, status = fn(table, rk, rv, mask=rm)
    return table, status, plan.overflow


def retrieve_distributed(table: sv.SingleValueHashTable, keys, axis: str,
                         slack: float = 2.0):
    """Route queries to owners, look up locally, route answers back.

    Returns (values, found, overflow) aligned with the local query batch.
    No merge step is needed — single-owner keys (paper §IV-E).
    """
    num_parts = jax.lax.axis_size(axis)
    keys = sv.normalize_words(keys, table.key_words, "keys")
    n = keys.shape[0]
    cap = int(np.ceil(n / num_parts * slack))
    owners = owner_of(keys, num_parts, table.key_words)
    plan = make_plan(owners, num_parts, cap)
    kbuf = scatter_to_buffer(plan, keys, num_parts, fill=EMPTY_KEY)
    rk = exchange(kbuf, axis)
    vals, found = sv.retrieve(table, rk)
    vals = sv.normalize_words(vals, table.value_words, "values")
    # answers travel the reverse path: all_to_all is its own inverse here
    vback = exchange(vals, axis)
    fback = exchange(found, axis)
    out_vals = gather_from_buffer(plan, vback)
    out_found = gather_from_buffer(plan, fback, fill=False)
    if table.value_words == 1:
        out_vals = out_vals[:, 0]
    return out_vals, out_found, plan.overflow


def erase_distributed(table: sv.SingleValueHashTable, keys, axis: str,
                      slack: float = 2.0):
    num_parts = jax.lax.axis_size(axis)
    keys = sv.normalize_words(keys, table.key_words, "keys")
    n = keys.shape[0]
    cap = int(np.ceil(n / num_parts * slack))
    owners = owner_of(keys, num_parts, table.key_words)
    plan = make_plan(owners, num_parts, cap)
    kbuf = scatter_to_buffer(plan, keys, num_parts, fill=EMPTY_KEY)
    mbuf = scatter_to_buffer(plan, jnp.ones((n,), bool), num_parts, fill=False)
    rk, rm = exchange(kbuf, axis), exchange(mbuf, axis)
    table, erased = sv.erase(table, rk, mask=rm)
    eback = exchange(erased, axis)
    return table, gather_from_buffer(plan, eback, fill=False), plan.overflow


# ---------------------------------------------------------------------------
# independent-mode ops (paper §IV-E second mode)
# ---------------------------------------------------------------------------

def insert_independent(table: sv.SingleValueHashTable, keys, values,
                       insert_fn: Callable | None = None):
    """Insert the local batch into the local table (data already scattered)."""
    fn = insert_fn or sv.insert
    table, status = fn(table, keys, values)
    return table, status


def retrieve_independent(table: sv.SingleValueHashTable, keys, axis: str):
    """Broadcast queries to every shard; each answers from its own table.

    Returns (values, found) where found is True iff ANY shard holds the key
    and values comes from the lowest-indexed shard that holds it (merge rule).
    """
    all_keys = jax.lax.all_gather(keys, axis, tiled=True)     # (P*n, kw?) queries
    vals, found = sv.retrieve(table, all_keys)
    vals = sv.normalize_words(vals, table.value_words, "values")
    # merge: each shard contributes only where it found the key; lowest shard wins
    idx = jax.lax.axis_index(axis)
    rank = jnp.where(found, idx, jax.lax.axis_size(axis))
    best = jax.lax.pmin(rank, axis)
    mine = rank == best
    contrib = jnp.where(mine[:, None] & found[:, None], vals, 0)
    merged_vals = jax.lax.psum(contrib, axis)
    merged_found = jax.lax.psum(found.astype(_I), axis) > 0
    # return this shard's slice of the gathered batch
    n = keys.shape[0]
    lo = idx * n
    out_v = jax.lax.dynamic_slice_in_dim(merged_vals, lo, n, 0)
    out_f = jax.lax.dynamic_slice_in_dim(merged_found, lo, n, 0)
    if table.value_words == 1:
        out_v = out_v[:, 0]
    return out_v, out_f


# ---------------------------------------------------------------------------
# host-level wrappers: build the shard_map for a mesh axis
# ---------------------------------------------------------------------------

def create_sharded(mesh: Mesh, axis: str, capacity_per_shard: int, **kwargs
                   ) -> sv.SingleValueHashTable:
    """A table whose arrays have a leading shard dim sharded over ``axis``."""
    num = int(mesh.shape[axis])

    def mk():
        t = sv.create(capacity_per_shard, **kwargs)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (num,) + x.shape), t)

    template = jax.eval_shape(mk)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, P(axis, *([None] * (len(s.shape) - 1)))),
        template)
    return jax.jit(mk, out_shardings=shardings)()


def _local(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _relift(tree):
    return jax.tree.map(lambda x: x[None], tree)


def shard_insert(mesh: Mesh, axis: str, table, keys, values, slack: float = 2.0,
                 insert_fn: Callable | None = None):
    """Host-level distributed-mode insert: keys/values sharded over ``axis``."""
    spec = jax.tree.map(lambda _: P(axis), table)

    def body(t, k, v):
        t_loc, s, ov = insert_distributed(_local(t), k, v, axis, slack, insert_fn)
        return _relift(t_loc), s, ov[None]

    f = jax.shard_map(body, mesh=mesh, in_specs=(spec, P(axis), P(axis)),
                      out_specs=(spec, P(axis), P(axis)), check_vma=False)
    return f(table, keys, values)


def shard_retrieve(mesh: Mesh, axis: str, table, keys, slack: float = 2.0):
    spec = jax.tree.map(lambda _: P(axis), table)

    def body(t, k):
        v, fnd, ov = retrieve_distributed(_local(t), k, axis, slack)
        return v, fnd, ov[None]

    f = jax.shard_map(body, mesh=mesh, in_specs=(spec, P(axis)),
                      out_specs=(P(axis), P(axis), P(axis)), check_vma=False)
    return f(table, keys)
