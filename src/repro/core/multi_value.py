"""MultiValueHashTable — same key may occur multiple times (paper §IV-B, V-B).

Every (key, value) pair occupies its own slot: insertion claims the lowest
EMPTY/TOMBSTONE slot in COPS probe order without checking for existing
matches.  Retrieval of *all* values for a key therefore walks the probe
sequence collecting every matching lane until it reaches a window that
contains an EMPTY slot (the absence frontier — tombstones do not stop the
walk).

As in the paper, ``retrieve_all`` needs the output size up front: a separate
vectorized *counting pass* produces per-key counts, the caller prefix-sums
them into offsets and supplies a static output capacity (§IV-B.4: "the size
of the output array has to be determined in a separate counting pass").

Keys may be ``key_words >= 2`` composite/u64 keys: every entry point
normalizes through ``single_value.normalize_key_batch``, so batches can
be passed as (n, kw) plane arrays, tuples of u32 columns, or numpy
uint64.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layouts, probing
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    TOMBSTONE_KEY,
    register_struct,
    static_field,
    table_geometry,
)
from repro.core.single_value import (
    key_hash_word,
    normalize_key_batch,
    normalize_words,
)

_U = jnp.uint32
_I = jnp.int32


@register_struct
@dataclasses.dataclass
class MultiValueHashTable:
    store: dict
    count: jax.Array                      # live (key, value) pairs
    num_rows: int = static_field()
    window: int = static_field()
    key_words: int = static_field()
    value_words: int = static_field()
    scheme: str = static_field()
    layout: str = static_field()
    seed: int = static_field()
    max_probes: int = static_field()
    backend: str = static_field()

    @property
    def capacity(self) -> int:
        return self.num_rows * self.window

    @property
    def ops(self) -> layouts.StoreOps:
        """The table's store protocol (cached geometry-bound layout ops)."""
        return layouts.make_ops(self.layout, self.num_rows, self.window,
                                self.key_words, self.value_words)

    def load_factor(self) -> jax.Array:
        return self.count.astype(jnp.float32) / jnp.float32(self.capacity)

    def key_planes(self) -> jax.Array:
        return self.ops.key_planes(self.store)

    def value_planes(self) -> jax.Array:
        return self.ops.value_planes(self.store)


def create(min_capacity: int, *, key_words: int = 1, value_words: int = 1,
           window: int = DEFAULT_WINDOW, scheme: str = "cops",
           layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax",
           kind: str | None = None,
           quotient: bool = False) -> MultiValueHashTable:
    """Create an empty multi-value table (capacity rounds to p*W, p prime).

    ``kind="bucketed"`` selects the two-choice bucketed lane (scheme
    ``"bucketed"`` over the bucketed store geometry), as in
    ``single_value.create``.  Quotient storage is single-value-only: a
    multi-value slot's identity is the (key, value) PAIR, and the rescue
    pass could not tell which of several same-key slots a claimer
    displaced — so ``quotient=True`` is rejected here.
    """
    if quotient:
        raise ValueError("multi-value tables do not support quotient "
                         "storage (single_value-only)")
    if kind is not None:
        if kind != "bucketed":
            raise ValueError(f"unknown table kind {kind!r}")
        scheme = "bucketed"
    if scheme == "bucketed" and layout == "soa":
        layout = "bucketed"
    if scheme not in probing.SCHEMES:
        raise ValueError(f"scheme {scheme!r} not in {probing.SCHEMES}")
    num_rows, _ = table_geometry(min_capacity, window)
    store = layouts.create(layout, num_rows, window, key_words, value_words)
    return MultiValueHashTable(
        store=store, count=jnp.zeros((), _I), num_rows=num_rows, window=window,
        key_words=key_words, value_words=value_words, scheme=scheme, layout=layout,
        seed=seed, max_probes=int(max_probes or num_rows), backend=backend)


# ---------------------------------------------------------------------------
# insertion — bulk scatter-arbitration engine by default (repro.core.bulk);
# backend="scan" keeps the sequential single-writer reference
# ---------------------------------------------------------------------------

def _probe_for_slot(tstatic, store, key_vec, word):
    """Lowest EMPTY/TOMBSTONE slot in probe order. Returns (ok, row, lane)."""
    ops, scheme, seed, max_probes = tstatic
    num_rows, w = ops.num_rows, ops.window
    row0 = probing.initial_row(word, num_rows, seed)
    step = probing.row_step(scheme, word, num_rows, seed)

    def cond(st):
        attempt, row, done, *_ = st
        return jnp.logical_and(attempt < max_probes, ~done)

    def body(st):
        attempt, row, done, crow, clane, ok = st
        win = ops.key_windows(store, row[None])[0]
        cand = (win[0] == EMPTY_KEY) | (win[0] == TOMBSTONE_KEY)
        c_lane = probing.vote_lowest(cand[None])[0]
        hit = c_lane < w
        crow = jnp.where(hit, row, crow)
        clane = jnp.where(hit, c_lane.astype(_U), clane)
        ok = ok | hit
        nrow = probing.advance_row(scheme, row, step, attempt, num_rows)
        return attempt + 1, jnp.where(hit, row, nrow), hit, crow, clane, ok

    z = jnp.zeros((), _U)
    st = (jnp.zeros((), _I), row0, jnp.zeros((), bool), z, z, jnp.zeros((), bool))
    _, _, _, crow, clane, ok = jax.lax.while_loop(cond, body, st)
    return ok, crow, clane


def insert(table: MultiValueHashTable, keys, values, mask=None,
           stats: bool = False):
    """Append (key, value) pairs; duplicates of a key occupy distinct slots.

    Dispatches on ``table.backend`` like ``single_value.insert``: the
    default ``"jax"`` path is the vectorized bulk engine (duplicates of a
    key contend for slots via scatter-min arbitration and resolve over
    rounds in batch order), ``"scan"`` the sequential reference, and
    ``"pallas"`` the COPS kernel — all bit-identical.  ``stats`` (static)
    appends an in-graph ``obs.metrics.TableStats`` to the return.
    """
    if table.scheme == "bucketed":
        return _insert_bucketed(table, keys, values, mask, stats)
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        ntable, status = cops_ops.insert_multi(table, keys, values, mask)
    elif table.backend != "scan":
        from repro.core import bulk
        return bulk.insert_multi(table, keys, values, mask, stats=stats)
    else:
        ntable, status = insert_scan(table, keys, values, mask)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(ntable, keys,
                                                     status=status, mask=mask)
    return ntable, status


def _core_insert(table: MultiValueHashTable, keys_n, values_n, mask):
    """Backend dispatch on pre-normalized batches, WITHOUT the bucketed
    rescue (what ``core.cuckoo`` composes over and re-enters)."""
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        return cops_ops.insert_multi(table, keys_n, values_n, mask)
    if table.backend != "scan":
        from repro.core import bulk
        return bulk.insert_multi(table, keys_n, values_n, mask)
    return insert_scan(table, keys_n, values_n, mask)


def _insert_bucketed(table: MultiValueHashTable, keys, values, mask,
                     stats: bool):
    """Bucketed-lane append: two-choice placement + bounded cuckoo rescue
    (``core.cuckoo``), shared bit-exactly across backends."""
    keys_n = normalize_key_batch(keys, table.key_words, "keys")
    values_n = normalize_words(values, table.value_words, "values")
    ntable, status = _core_insert(table, keys_n, values_n, mask)
    from repro.core import cuckoo
    ntable, status = cuckoo.rescue(ntable, keys_n, values_n, mask, status,
                                   _core_insert)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(ntable, keys_n,
                                                     status=status, mask=mask)
    return ntable, status


def insert_scan(table: MultiValueHashTable, keys, values, mask=None,
                ) -> tuple[MultiValueHashTable, jax.Array]:
    """Sequential-scan reference append (the bulk engine's parity oracle)."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    values = normalize_words(values, table.value_words, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    words = key_hash_word(keys)
    # budget clamped to the scheme's distinct-row coverage (the
    # coverage-clamp bugfix — see probing.effective_probes)
    tstatic = (table.ops, table.scheme, table.seed,
               probing.effective_probes(table.scheme, table.max_probes,
                                        table.num_rows))

    def step(carry, inp):
        store, count = carry
        k, v, word, m = inp
        ok, row, lane = _probe_for_slot(tstatic, store, k, word)
        do_write = m & ok
        # masked write via OOR-drop scatter (see single_value.insert)
        wrow = jnp.where(do_write, row, _U(table.num_rows))
        store = table.ops.scatter_keys(store, wrow[None], lane[None], k[None])
        store = table.ops.scatter_values(store, wrow[None], lane[None],
                                         v[None])
        count = count + do_write.astype(_I)
        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(ok, _I(STATUS_INSERTED), _I(STATUS_FULL)))
        return (store, count), status

    (store, count), status = jax.lax.scan(step, (table.store, table.count),
                                          (keys, values, words, mask))
    return dataclasses.replace(table, store=store, count=count), status


def insert_or_grow(table: MultiValueHashTable, keys, values, mask=None, *,
                   policy=None, max_attempts: int = 4):
    """``insert`` under the auto-growth policy: migrates (grow/compact)
    instead of ever returning ``STATUS_FULL`` while capacity headroom
    remains.  Host-side wrapper — see ``repro.core.migrate``."""
    from repro.core import migrate
    return migrate.insert_or_grow(
        table, keys, values, mask,
        policy=migrate.DEFAULT_POLICY if policy is None else policy,
        max_attempts=max_attempts)


# ---------------------------------------------------------------------------
# retrieval — fused single-walk engine by default (repro.core.bulk_retrieve);
# backend="scan" keeps the two-walk count+gather reference
# ---------------------------------------------------------------------------

def count_values(table: MultiValueHashTable, keys, mask=None,
                 stats: bool = False):
    """Number of stored values per queried key (the paper's counting pass).

    ``mask`` drops query elements entirely (count 0, no probe walk) — used by
    the relational probe path where padded exchange slots carry sentinels.
    Dispatches on ``table.backend``: the default runs the fused
    bulk-retrieval engine (duplicate probe keys walk once), ``"pallas"``
    the fused COPS walk tile, ``"scan"`` the direct reference walk.
    ``stats`` (static) appends an in-graph ``obs.metrics.TableStats``.
    """
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        cnt = cops_ops.count_multi(table, keys, mask)
    elif table.backend != "scan":
        from repro.core import bulk_retrieve
        return bulk_retrieve.count_multi(table, keys, mask, stats=stats)
    else:
        cnt = count_values_scan(table, keys, mask)
    if stats:
        from repro.obs import metrics
        return cnt, metrics.bolt_on_stats(table, keys, mask=mask)
    return cnt


def count_values_scan(table: MultiValueHashTable, keys, mask=None) -> jax.Array:
    """Reference counting pass: one dedicated probe walk for the counts."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    word = key_hash_word(keys)
    row0 = probing.initial_row(word, table.num_rows, table.seed)
    step = probing.row_step(table.scheme, word, table.num_rows, table.seed)
    max_probes = probing.effective_probes(table.scheme, table.max_probes,
                                          table.num_rows)
    done0 = jnp.zeros((n,), bool) if mask is None else ~mask

    def cond(st):
        attempt, row, done, cnt = st
        return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

    def body(st):
        attempt, row, done, cnt = st
        win = table.ops.key_windows(table.store, row)
        match = jnp.all(win == keys[:, :, None], axis=1)
        has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)
        cnt = cnt + jnp.where(done, 0, probing.vote_count(match))
        done = done | has_empty
        nrow = probing.advance_row(table.scheme, row, step, attempt, table.num_rows)
        return attempt + 1, jnp.where(done, row, nrow), done, cnt

    st = (jnp.zeros((), _I), row0, done0, jnp.zeros((n,), _I))
    _, _, _, cnt = jax.lax.while_loop(cond, body, st)
    return cnt


def retrieve_all(table: MultiValueHashTable, keys, out_capacity: int,
                 mask=None, stats: bool = False):
    """Gather every value for each queried key.

    Returns (values, offsets, counts): ``values`` is (out_capacity, value_words)
    [or (out_capacity,) for 1-word values] with the values for query i in
    ``values[offsets[i] : offsets[i] + counts[i]]``; ``offsets`` is the (n+1,)
    exclusive prefix sum.  ``out_capacity`` is static (jit shape); entries past
    the true total are zero.  Overflow beyond out_capacity is dropped —
    callers size via ``count_values`` exactly as in the paper.

    The default backend runs the fused bulk-retrieval engine: ONE probe
    walk emits counts and gathered values together (half the store
    traffic of the paper's count-then-gather pattern).  ``"scan"`` keeps
    that two-walk shape as the bit-exact reference; ``"pallas"`` drives
    the same compaction from the fused COPS walk tile.  Walks that may
    revisit probe rows (see ``bulk_retrieve.fused_ok``) always take the
    reference path — only it can re-emit a slot per visit.
    """
    from repro.core import bulk_retrieve
    if table.backend != "scan" and bulk_retrieve.fused_ok(table):
        if table.backend == "pallas":
            from repro.kernels.cops import ops as cops_ops
            res = cops_ops.retrieve_all_multi(table, keys, out_capacity, mask)
        else:
            return bulk_retrieve.retrieve_all_multi(table, keys, out_capacity,
                                                    mask, stats=stats)
    else:
        res = retrieve_all_scan(table, keys, out_capacity, mask)
    if stats:
        from repro.obs import metrics
        return res + (metrics.bolt_on_stats(table, keys, mask=mask),)
    return res


def retrieve_all_scan(table: MultiValueHashTable, keys, out_capacity: int,
                      mask=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference two-walk retrieval: counting pass, then a gather re-probe."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    counts = count_values_scan(table, keys, mask)
    offsets = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts)])
    word = key_hash_word(keys)
    row0 = probing.initial_row(word, table.num_rows, table.seed)
    step = probing.row_step(table.scheme, word, table.num_rows, table.seed)
    out = jnp.zeros((out_capacity, table.value_words), _U)
    max_probes = probing.effective_probes(table.scheme, table.max_probes,
                                          table.num_rows)
    done0 = jnp.zeros((n,), bool) if mask is None else ~mask

    def cond(st):
        attempt, row, done, seen, out = st
        return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

    def body(st):
        attempt, row, done, seen, out = st
        win = table.ops.key_windows(table.store, row)
        vwin = table.ops.value_windows(table.store, row)
        match = jnp.all(win == keys[:, :, None], axis=1) & ~done[:, None]   # (n, W)
        has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)
        # within-window rank of each matching lane
        rank = jnp.cumsum(match.astype(_I), axis=1) - 1                     # (n, W)
        pos = offsets[:n, None] + seen[:, None] + rank                      # (n, W)
        pos = jnp.where(match, pos, out_capacity)                           # OOR drop
        flat_pos = pos.reshape(-1)
        flat_vals = jnp.moveaxis(vwin, 1, 2).reshape(-1, table.value_words)
        out = out.at[flat_pos].set(flat_vals, mode="drop")
        seen = seen + probing.vote_count(match)
        done = done | has_empty
        nrow = probing.advance_row(table.scheme, row, step, attempt, table.num_rows)
        return attempt + 1, jnp.where(done, row, nrow), done, seen, out

    st = (jnp.zeros((), _I), row0, done0, jnp.zeros((n,), _I), out)
    _, _, _, _, out = jax.lax.while_loop(cond, body, st)
    if table.value_words == 1:
        return out[:, 0], offsets, counts
    return out, offsets, counts


def erase(table: MultiValueHashTable, keys) -> tuple[MultiValueHashTable, jax.Array]:
    """Tombstone every pair whose key matches. Returns (table, erased_counts).

    The default path reuses the fused retrieval walk: its match arena is
    the exact slot set to delete, applied as one batched tombstone write.
    ``backend="scan"`` keeps the scatter-per-window reference walk, and
    possibly-revisiting walks (``bulk_retrieve.fused_ok``) fall back to
    it — in-walk tombstoning is what stops a revisit from re-counting.
    """
    from repro.core import bulk_retrieve
    if table.backend != "scan" and bulk_retrieve.fused_ok(table):
        return bulk_retrieve.erase_multi(table, keys)
    return erase_scan(table, keys)


def erase_scan(table: MultiValueHashTable, keys) -> tuple[MultiValueHashTable, jax.Array]:
    """Reference erase: in-walk tombstone scatters + full live recount."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    word = key_hash_word(keys)
    row0 = probing.initial_row(word, table.num_rows, table.seed)
    step = probing.row_step(table.scheme, word, table.num_rows, table.seed)
    max_probes = probing.effective_probes(table.scheme, table.max_probes,
                                          table.num_rows)
    store = table.store

    def cond(st):
        attempt, row, done, cnt, store = st
        return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

    def body(st):
        attempt, row, done, cnt, store = st
        win = table.ops.key_windows(store, row)
        match = jnp.all(win == keys[:, :, None], axis=1) & ~done[:, None]
        has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)
        # scatter tombstones at every matching lane of every queried row
        rows_b = jnp.broadcast_to(row[:, None], match.shape)
        lanes_b = jax.lax.broadcasted_iota(_U, match.shape, 1)
        srows = jnp.where(match, rows_b, _U(table.num_rows)).reshape(-1)
        slanes = lanes_b.reshape(-1)
        store = table.ops.scatter_key_word(store, srows, slanes,
                                           TOMBSTONE_KEY)
        cnt = cnt + probing.vote_count(match)
        done = done | has_empty
        nrow = probing.advance_row(table.scheme, row, step, attempt, table.num_rows)
        return attempt + 1, jnp.where(done, row, nrow), done, cnt, store

    st = (jnp.zeros((), _I), row0, jnp.zeros((n,), bool), jnp.zeros((n,), _I), store)
    _, _, _, cnt, store = jax.lax.while_loop(cond, body, st)
    kp = table.ops.key_planes(store)[0]
    count = jnp.sum((kp != EMPTY_KEY) & (kp != TOMBSTONE_KEY), dtype=_I)
    return dataclasses.replace(table, store=store, count=count), cnt


def for_each(table: MultiValueHashTable, keys, fn: Callable, max_values: int):
    """Apply ``fn(key, value, valid)`` to every (query, stored-value) pair.

    ``max_values`` bounds values per key (static).  Device-sided callback
    analogue of §IV-B.4 for the multi-value case.
    """
    keys_n = normalize_key_batch(keys, table.key_words, "keys")
    n = keys_n.shape[0]
    vals, offsets, counts = retrieve_all(table, keys_n, n * max_values)
    vals = normalize_words(vals, table.value_words, "values")
    idx = offsets[:n, None] + jnp.arange(max_values)[None, :]
    valid = jnp.arange(max_values)[None, :] < counts[:, None]
    idx = jnp.where(valid, idx, 0)
    per_key_vals = vals[idx]                                      # (n, max_values, vw)
    return jax.vmap(lambda k, vs, ms: jax.vmap(lambda v, m: fn(k, v, m))(vs, ms))(
        keys_n, per_key_vals, valid)


# ---------------------------------------------------------------------------
# donation-safe jitted entry point (streaming/serving hot paths)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def insert_donated(table: MultiValueHashTable, keys, values, mask=None):
    """``insert`` jitted with the table argument DONATED (buffers aliased
    input->output, no per-call arena copy).  The caller's table is
    consumed — rebind the result.  See
    ``single_value.insert_donated``."""
    return insert(table, keys, values, mask)
