"""BucketListHashTable — memory-compact multi-value table (paper §IV-C, Fig. 3).

Keys live once in a SingleValueHashTable whose value is a packed 64-bit
*list handle* (two u32 words):

  word0: pointer to the tail bucket (slot index into the value pool)
  word1: [ count : 22 | bucket_idx : 8 | state : 2 ]

Values live in linked lists of contiguous *buckets* drawn from a
pre-allocated pool (global allocations would be a device-wide barrier —
paper §IV-C; we bump-allocate from one array).  Bucket sizes follow the
paper's growth schedule s_i = ceil(lambda * s_{i-1}).  The leading slot of
every bucket except the first stores the pointer to the *previous* bucket
(the list is walked tail -> head, exactly as in Fig. 4).

The 4-state handle machine (uninitialized/blocked/ready/full) guards
concurrent list growth on the GPU; under ownership partitioning there is a
single writer per shard, so BLOCKED is never observable — we keep the
encoding for layout fidelity and cheap invariant checks.

Because the handle carries the count, ``count_values`` is O(1) per key (no
probe walk) — one of the structure's practical wins over the pure OA
multi-value table.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layouts, probing
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_POOL_FULL,
    register_struct,
    static_field,
)
from repro.core import single_value as sv

_U = jnp.uint32
_I = jnp.int32

# handle word1 bit layout
_COUNT_SHIFT = 10
_BUCKET_SHIFT = 2
_BUCKET_MASK = 0xFF
_STATE_MASK = 0x3
STATE_UNINIT, STATE_BLOCKED, STATE_READY, STATE_FULL = 0, 1, 2, 3
MAX_COUNT = (1 << 22) - 1


def pack_handle(ptr, count, bucket_idx, state):
    w1 = ((count.astype(_U) << _U(_COUNT_SHIFT))
          | (bucket_idx.astype(_U) << _U(_BUCKET_SHIFT))
          | state.astype(_U))
    return jnp.stack([ptr.astype(_U), w1], axis=-1)


def unpack_handle(handle):
    ptr = handle[..., 0]
    w1 = handle[..., 1]
    count = (w1 >> _U(_COUNT_SHIFT)).astype(_I)
    bucket_idx = ((w1 >> _U(_BUCKET_SHIFT)) & _U(_BUCKET_MASK)).astype(_I)
    state = (w1 & _U(_STATE_MASK)).astype(_I)
    return ptr, count, bucket_idx, state


def growth_schedule(s0: int, growth: float, pool_capacity: int,
                    max_buckets: int = 64) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Bucket sizes s_i = ceil(growth * s_{i-1}) and exclusive cumulative value
    capacity C_i (values held in buckets 0..i-1).  Truncated once C covers the
    pool (no key can ever need more buckets)."""
    sizes, cum = [], [0]
    s = int(s0)
    while len(sizes) < max_buckets and cum[-1] < pool_capacity:
        sizes.append(s)
        cum.append(cum[-1] + s)
        s = int(math.ceil(growth * s))
    return tuple(sizes), tuple(cum)


@register_struct
@dataclasses.dataclass
class BucketListHashTable:
    key_store: sv.SingleValueHashTable
    pool: jax.Array                       # (pool_capacity,) u32 value+link slots
    alloc_top: jax.Array                  # i32 bump allocator
    pool_capacity: int = static_field()
    sizes: tuple = static_field()         # bucket value-capacities per index
    cum: tuple = static_field()           # exclusive cumulative value capacity
    s0: int = static_field()
    growth: float = static_field()

    @property
    def key_capacity(self) -> int:
        return self.key_store.capacity

    def num_keys(self) -> jax.Array:
        return self.key_store.count

    def storage_density(self) -> jax.Array:
        """Stored information bits / allocated bits (paper's rho, §IV-C)."""
        stored = (self.key_store.count * (1 + 1)          # key + one handle word of info
                  + jnp.sum(self._counts_all()))
        allocated = self.key_store.capacity * 3 + self.pool_capacity
        return stored.astype(jnp.float32) / jnp.float32(allocated)

    def _counts_all(self) -> jax.Array:
        vp = self.key_store.value_planes()                # (2, p, W)
        w1 = vp[1].reshape(-1)
        kp = self.key_store.key_planes()[0].reshape(-1)
        from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY
        live = (kp != EMPTY_KEY) & (kp != TOMBSTONE_KEY)
        return jnp.where(live, (w1 >> _U(_COUNT_SHIFT)).astype(_I), 0)


def create(key_capacity: int, pool_capacity: int, *, s0: int = 1,
           growth: float = 1.1, window: int = DEFAULT_WINDOW,
           scheme: str = "cops", seed: int = DEFAULT_SEED,
           key_words: int = 1, backend: str = "jax") -> BucketListHashTable:
    key_store = sv.create(key_capacity, key_words=key_words, value_words=2,
                          window=window, scheme=scheme, seed=seed, backend=backend)
    sizes, cum = growth_schedule(s0, growth, pool_capacity)
    return BucketListHashTable(
        key_store=key_store,
        pool=jnp.zeros((pool_capacity,), _U),
        alloc_top=jnp.zeros((), _I),
        pool_capacity=pool_capacity, sizes=sizes, cum=cum, s0=s0, growth=growth)


# ---------------------------------------------------------------------------
# insertion — sequential over the batch
# ---------------------------------------------------------------------------

def insert(table: BucketListHashTable, keys, values, mask=None,
           ) -> tuple[BucketListHashTable, jax.Array]:
    """Insert (key, value): new keys allocate their first bucket; existing keys
    append to the tail bucket, growing the list when the tail is full."""
    ks = table.key_store
    keys = sv.normalize_words(keys, ks.key_words, "keys")
    values = sv.normalize_words(values, 1, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    words = sv.key_hash_word(keys)
    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)
    tstatic = (ks.layout, ks.key_words, ks.num_rows, ks.window,
               ks.scheme, ks.seed, ks.max_probes)
    pool_cap = table.pool_capacity

    def step(carry, inp):
        store, kcount, pool, top = carry
        k, v, word, m = inp
        mode, row, lane = sv._probe_for_insert(tstatic, store, k, word)
        # current handle (valid when mode == 0)
        old_handle = layouts.value_windows(ks.layout, store, row[None],
                                           ks.key_words, 2)[0, :, lane]
        ptr, count, bidx, state = unpack_handle(old_handle)

        is_new = (mode == 1)
        exists = (mode == 0)
        # --- existing key: does the tail bucket have room?
        tail_cap = sizes[jnp.clip(bidx, 0, sizes.shape[0] - 1)]
        fill = count - cum[jnp.clip(bidx, 0, cum.shape[0] - 1)]
        tail_has_room = exists & (fill < tail_cap) & (count < MAX_COUNT)
        # value position inside current tail (skip the prev-link slot of j>0)
        tail_data = ptr.astype(_I) + jnp.where(bidx > 0, 1, 0)
        append_pos = tail_data + fill

        # --- need a new bucket (new key, or tail full)
        nbidx = jnp.where(is_new, 0, bidx + 1)
        nbidx_c = jnp.clip(nbidx, 0, sizes.shape[0] - 1)
        nsize = sizes[nbidx_c]
        alloc_slots = nsize + jnp.where(nbidx > 0, 1, 0)      # + prev-link slot
        need_alloc = (is_new | (exists & ~tail_has_room)) & m
        fits = (top + alloc_slots <= pool_cap) & (nbidx < sizes.shape[0])
        do_alloc = need_alloc & fits
        new_ptr = top

        # position of the value we write this step
        vpos = jnp.where(tail_has_room, append_pos,
                         new_ptr + jnp.where(nbidx > 0, 1, 0))
        do_write = m & (tail_has_room | do_alloc)
        # write the value (OOR-drop when masked out)
        pool = pool.at[jnp.where(do_write, vpos, pool_cap)].set(v[0], mode="drop")
        # link new bucket to previous tail
        link_pos = jnp.where(do_alloc & (nbidx > 0), new_ptr, pool_cap)
        pool = pool.at[link_pos].set(ptr, mode="drop")
        top = top + jnp.where(do_alloc, alloc_slots, 0)

        # --- updated handle
        new_count = count + do_write.astype(_I)
        h_ptr = jnp.where(do_alloc, new_ptr.astype(_U), ptr)
        h_bidx = jnp.where(do_alloc, nbidx, bidx)
        h_count = jnp.where(is_new & do_alloc, _I(1), new_count)
        handle = pack_handle(h_ptr, h_count, h_bidx,
                             jnp.full((), STATE_READY, _I))

        # write handle into the key store:
        #   new key + alloc ok  -> claim slot with (k, handle)
        #   existing key        -> update handle value in place
        # masked OOR-drop scatters instead of lax.switch (in-place updates)
        case = jnp.where(~m, _I(0),
                         jnp.where(exists & do_write, _I(1),
                                   jnp.where(is_new & do_alloc, _I(2), _I(0))))
        oor = _U(ks.num_rows)
        hrow = jnp.where(case >= 1, row, oor)
        store = layouts.scatter_values(ks.layout, store, hrow[None],
                                       lane[None], handle[None], ks.key_words)
        krow = jnp.where(case == 2, row, oor)
        store = layouts.scatter_keys(ks.layout, store, krow[None],
                                     lane[None], k[None])
        kcount = kcount + jnp.where(case == 2, _I(1), _I(0))

        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(do_write, _I(STATUS_INSERTED),
                                     jnp.where(mode == 2, _I(STATUS_FULL),
                                               _I(STATUS_POOL_FULL))))
        return (store, kcount, pool, top), status

    (store, kcount, pool, top), status = jax.lax.scan(
        step, (ks.store, ks.count, table.pool, table.alloc_top),
        (keys, values, words, mask))
    new_ks = dataclasses.replace(ks, store=store, count=kcount)
    return dataclasses.replace(table, key_store=new_ks, pool=pool,
                               alloc_top=top), status


# ---------------------------------------------------------------------------
# retrieval — O(1) counts from handles; vectorized lockstep bucket walk
# ---------------------------------------------------------------------------

def count_values(table: BucketListHashTable, keys) -> jax.Array:
    """Per-key value count, read straight off the handle (no probe walk)."""
    handles, found = sv.retrieve(table.key_store, keys)
    _, count, _, _ = unpack_handle(handles)
    return jnp.where(found, count, 0)


def retrieve_all(table: BucketListHashTable, keys, out_capacity: int,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gather every value for each key by walking its bucket list tail->head
    (Fig. 4).  All queried lists are walked in lockstep, one bucket per round,
    with the full bucket read as one vector gather — the CG-cooperative
    coalesced read adapted to the VPU."""
    ks = table.key_store
    keys = sv.normalize_words(keys, ks.key_words, "keys")
    n = keys.shape[0]
    handles, found = sv.retrieve(ks, keys)
    ptr, count, bidx, _ = unpack_handle(handles)
    counts = jnp.where(found, count, 0)
    offsets = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts)])
    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)
    s_max = int(max(table.sizes))
    max_rounds = len(table.sizes)
    out = jnp.zeros((out_capacity,), _U)
    # buckets are read in fixed-width chunks with a data-dependent inner
    # loop: rounds where every active bucket is small never pay for s_max
    # (growth=1.1 schedules reach s_max in the hundreds, but r=1 workloads
    # only ever touch size-1 buckets)
    chunk = int(min(s_max, 128))
    lanes_c = jnp.arange(chunk, dtype=_I)

    def cond(st):
        r, j, ptr, out = st
        return jnp.logical_and(r < max_rounds, jnp.any(j >= 0))

    def body(st):
        r, j, ptr, out = st
        active = j >= 0
        jc = jnp.clip(j, 0, sizes.shape[0] - 1)
        bsize = sizes[jc]
        base = cum[jc]                                        # values before bucket j
        has_link = (j > 0)
        data_start = ptr.astype(_I) + has_link.astype(_I)
        # tail bucket may be partially filled
        valid_in_bucket = jnp.minimum(counts - base, bsize)
        max_valid = jnp.max(jnp.where(active, valid_in_bucket, 0))

        def chunk_cond(cst):
            c, out = cst
            return c * chunk < max_valid

        def chunk_body(cst):
            c, out = cst
            lanes = c * chunk + lanes_c                       # (chunk,)
            gidx = data_start[:, None] + lanes[None, :]       # (n, chunk)
            vals = table.pool[jnp.clip(gidx, 0, table.pool_capacity - 1)]
            lane_ok = ((lanes[None, :] < valid_in_bucket[:, None])
                       & active[:, None])
            pos = offsets[:n, None] + base[:, None] + lanes[None, :]
            pos = jnp.where(lane_ok, pos, out_capacity)
            out = out.at[pos.reshape(-1)].set(vals.reshape(-1), mode="drop")
            return c + 1, out

        _, out = jax.lax.while_loop(chunk_cond, chunk_body,
                                    (jnp.zeros((), _I), out))
        # follow the prev link
        link = table.pool[jnp.clip(ptr.astype(_I), 0, table.pool_capacity - 1)]
        ptr = jnp.where(active & has_link, link, ptr)
        j = jnp.where(active, j - 1, j)
        return r + 1, j, ptr, out

    j0 = jnp.where(found, bidx, -1)
    _, _, _, out = jax.lax.while_loop(cond, body,
                                      (jnp.zeros((), _I), j0, ptr, out))
    return out, offsets, counts


def for_each(table: BucketListHashTable, keys, fn: Callable, max_values: int):
    """Apply ``fn(key, value, valid)`` per (query, value) pair (cf. §IV-B.4)."""
    ks = table.key_store
    keys_n = sv.normalize_words(keys, ks.key_words, "keys")
    n = keys_n.shape[0]
    vals, offsets, counts = retrieve_all(table, keys_n, n * max_values)
    idx = offsets[:n, None] + jnp.arange(max_values)[None, :]
    valid = jnp.arange(max_values)[None, :] < counts[:, None]
    per_key = vals[jnp.where(valid, idx, 0)]
    return jax.vmap(lambda k, vs, ms: jax.vmap(lambda v, m: fn(k, v, m))(vs, ms))(
        keys_n, per_key, valid)
