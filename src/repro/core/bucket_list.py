"""BucketListHashTable — memory-compact multi-value table (paper §IV-C, Fig. 3).

Keys live once in a SingleValueHashTable whose value is a packed 64-bit
*list handle* (two u32 words):

  word0: pointer to the tail bucket (slot index into the value pool)
  word1: [ count : 22 | bucket_idx : 8 | state : 2 ]

Values live in linked lists of contiguous *buckets* drawn from a
pre-allocated pool (global allocations would be a device-wide barrier —
paper §IV-C; we bump-allocate from one array).  Bucket sizes follow the
paper's growth schedule s_i = ceil(lambda * s_{i-1}).  The leading slot of
every bucket except the first stores the pointer to the *previous* bucket
(the list is walked tail -> head, exactly as in Fig. 4).

The 4-state handle machine (uninitialized/blocked/ready/full) guards
concurrent list growth on the GPU; under ownership partitioning there is a
single writer per shard, so BLOCKED is never observable — we keep the
encoding for layout fidelity and cheap invariant checks.

Because the handle carries the count, ``count_values`` is O(1) per key (no
probe walk) — one of the structure's practical wins over the pure OA
multi-value table.

**Engines.**  Like every other table in the library, the bucket store now
rides the shared bulk engines instead of private walks:

- ``insert`` (default ``backend="jax"``) is the **batched build**: the
  bulk engine's sort/segment dedup groups the batch per key, a
  *prefix-sum bucket allocator* turns per-key demand into one
  bump-allocation sweep over the pool (each bucket-opening element reads
  its bucket's base address straight off an exclusive prefix sum over the
  batch — exactly the addresses the sequential bump allocator hands out),
  and new keys claim their key-store slot through the engine's
  window-level scatter arbitration (``bulk.place_claims``).  Pool
  exhaustion and key-store overflow are resolved by a monotone fixpoint
  that reproduces the sequential element order (see ``_insert_bulk``).
- ``count_values``/``retrieve_all`` ride the **fused retrieval engine**:
  the bucket chain is exposed as a *slot arena* over the value pool
  (``layouts.StoreOps`` arena hook) — one chain walk stamps (query, rank)
  per pool slot and ``bulk_retrieve._emit`` compacts it into the paper's
  (values, offsets, counts) layout, duplicate queries walking once.
- ``backend="scan"`` keeps the sequential ``lax.scan`` insert and the
  private two-pass retrieval as the bit-exact parity reference;
  ``backend="pallas"`` runs the chain walk as a COPS bucket-walk tile
  (``repro.kernels.cops.bucket_walk_call``) with the compaction shared.

Parity: ``backend="jax"`` matches ``backend="scan"`` bit for bit on
handles, key-store planes, pool planes, alloc_top, statuses and retrieval
outputs across duplicates, masks, growth schedules and pool exhaustion.
One documented corner: a *new* key that simultaneously fails its first
bucket allocation (pool exhausted) AND would find the key store full
reports ``STATUS_POOL_FULL`` here but ``STATUS_FULL`` from the scan (the
scan checks the probe first); state is identical either way — neither
path writes anything.  (The count-field saturation regime at 2^22 values
per key is likewise not bit-reproduced; the packed handle overflows in
the reference as well.)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bulk, bulk_retrieve
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_POOL_FULL,
    register_struct,
    static_field,
)
from repro.core import single_value as sv

_U = jnp.uint32
_I = jnp.int32

# handle word1 bit layout
_COUNT_SHIFT = 10
_BUCKET_SHIFT = 2
_BUCKET_MASK = 0xFF
_STATE_MASK = 0x3
STATE_UNINIT, STATE_BLOCKED, STATE_READY, STATE_FULL = 0, 1, 2, 3
MAX_COUNT = (1 << 22) - 1


def pack_handle(ptr, count, bucket_idx, state):
    w1 = ((count.astype(_U) << _U(_COUNT_SHIFT))
          | (bucket_idx.astype(_U) << _U(_BUCKET_SHIFT))
          | state.astype(_U))
    return jnp.stack([ptr.astype(_U), w1], axis=-1)


def unpack_handle(handle):
    ptr = handle[..., 0]
    w1 = handle[..., 1]
    count = (w1 >> _U(_COUNT_SHIFT)).astype(_I)
    bucket_idx = ((w1 >> _U(_BUCKET_SHIFT)) & _U(_BUCKET_MASK)).astype(_I)
    state = (w1 & _U(_STATE_MASK)).astype(_I)
    return ptr, count, bucket_idx, state


def growth_schedule(s0: int, growth: float, pool_capacity: int,
                    max_buckets: int = 64) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Bucket sizes s_i = ceil(growth * s_{i-1}) and exclusive cumulative value
    capacity C_i (values held in buckets 0..i-1).  Truncated once C covers the
    pool (no key can ever need more buckets)."""
    sizes, cum = [], [0]
    s = int(s0)
    while len(sizes) < max_buckets and cum[-1] < pool_capacity:
        sizes.append(s)
        cum.append(cum[-1] + s)
        s = int(math.ceil(growth * s))
    return tuple(sizes), tuple(cum)


@register_struct
@dataclasses.dataclass
class BucketListHashTable:
    key_store: sv.SingleValueHashTable
    pool: jax.Array                       # (pool_capacity,) u32 value+link slots
    alloc_top: jax.Array                  # i32 bump allocator
    pool_capacity: int = static_field()
    sizes: tuple = static_field()         # bucket value-capacities per index
    cum: tuple = static_field()           # exclusive cumulative value capacity
    s0: int = static_field()
    growth: float = static_field()

    @property
    def key_capacity(self) -> int:
        return self.key_store.capacity

    @property
    def backend(self) -> str:
        return self.key_store.backend

    def num_keys(self) -> jax.Array:
        return self.key_store.count

    def storage_density(self) -> jax.Array:
        """Stored information bits / allocated bits (paper's rho, §IV-C)."""
        stored = (self.key_store.count * (1 + 1)          # key + one handle word of info
                  + jnp.sum(self._counts_all()))
        allocated = self.key_store.capacity * 3 + self.pool_capacity
        return stored.astype(jnp.float32) / jnp.float32(allocated)

    def _counts_all(self) -> jax.Array:
        vp = self.key_store.value_planes()                # (2, p, W)
        w1 = vp[1].reshape(-1)
        kp = self.key_store.key_planes()[0].reshape(-1)
        from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY
        live = (kp != EMPTY_KEY) & (kp != TOMBSTONE_KEY)
        return jnp.where(live, (w1 >> _U(_COUNT_SHIFT)).astype(_I), 0)


def create(key_capacity: int, pool_capacity: int, *, s0: int = 1,
           growth: float = 1.1, window: int = DEFAULT_WINDOW,
           scheme: str = "cops", seed: int = DEFAULT_SEED,
           key_words: int = 1, backend: str = "jax") -> BucketListHashTable:
    key_store = sv.create(key_capacity, key_words=key_words, value_words=2,
                          window=window, scheme=scheme, seed=seed, backend=backend)
    sizes, cum = growth_schedule(s0, growth, pool_capacity)
    return BucketListHashTable(
        key_store=key_store,
        pool=jnp.zeros((pool_capacity,), _U),
        alloc_top=jnp.zeros((), _I),
        pool_capacity=pool_capacity, sizes=sizes, cum=cum, s0=s0, growth=growth)


# ---------------------------------------------------------------------------
# insertion — batched engine build by default; backend="scan" keeps the
# sequential reference
# ---------------------------------------------------------------------------

def insert(table: BucketListHashTable, keys, values, mask=None,
           stats: bool = False):
    """Insert (key, value): new keys allocate their first bucket; existing keys
    append to the tail bucket, growing the list when the tail is full.

    Dispatches on the table's backend like every other table:
    ``"jax"``/``"pallas"`` run the batched engine build (sort/segment
    dedup + prefix-sum bucket allocator + scatter-arbitration handle
    claims), ``"scan"`` the sequential reference — bit-identical state.
    ``stats`` (static) appends an in-graph ``obs.metrics.TableStats``
    (probe lengths measured over the key store; pool occupancy is the
    caller's ``alloc_top``).
    """
    if table.backend != "scan":
        ntable, status = _insert_bulk(table, keys, values, mask)
    else:
        ntable, status = insert_scan(table, keys, values, mask)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(
            ntable.key_store, keys, status=status, mask=mask)
    return ntable, status


def insert_scan(table: BucketListHashTable, keys, values, mask=None,
                ) -> tuple[BucketListHashTable, jax.Array]:
    """Sequential-scan reference insert: one probe + alloc step per element
    (the batched build's parity oracle)."""
    ks = table.key_store
    keys = sv.normalize_key_batch(keys, ks.key_words, "keys")
    values = sv.normalize_words(values, 1, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    words = sv.key_hash_word(keys)
    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)
    tstatic = (ks.ops, ks.scheme, ks.seed, ks.max_probes)
    pool_cap = table.pool_capacity

    def step(carry, inp):
        store, kcount, pool, top = carry
        k, v, word, m = inp
        mode, row, lane = sv._probe_for_insert(tstatic, store, k, word)
        # current handle (valid when mode == 0)
        old_handle = ks.ops.value_windows(store, row[None])[0, :, lane]
        ptr, count, bidx, state = unpack_handle(old_handle)

        is_new = (mode == 1)
        exists = (mode == 0)
        # --- existing key: does the tail bucket have room?
        tail_cap = sizes[jnp.clip(bidx, 0, sizes.shape[0] - 1)]
        fill = count - cum[jnp.clip(bidx, 0, cum.shape[0] - 1)]
        tail_has_room = exists & (fill < tail_cap) & (count < MAX_COUNT)
        # value position inside current tail (skip the prev-link slot of j>0)
        tail_data = ptr.astype(_I) + jnp.where(bidx > 0, 1, 0)
        append_pos = tail_data + fill

        # --- need a new bucket (new key, or tail full)
        nbidx = jnp.where(is_new, 0, bidx + 1)
        nbidx_c = jnp.clip(nbidx, 0, sizes.shape[0] - 1)
        nsize = sizes[nbidx_c]
        alloc_slots = nsize + jnp.where(nbidx > 0, 1, 0)      # + prev-link slot
        need_alloc = (is_new | (exists & ~tail_has_room)) & m
        fits = (top + alloc_slots <= pool_cap) & (nbidx < sizes.shape[0])
        do_alloc = need_alloc & fits
        new_ptr = top

        # position of the value we write this step
        vpos = jnp.where(tail_has_room, append_pos,
                         new_ptr + jnp.where(nbidx > 0, 1, 0))
        do_write = m & (tail_has_room | do_alloc)
        # write the value (OOR-drop when masked out)
        pool = pool.at[jnp.where(do_write, vpos, pool_cap)].set(v[0], mode="drop")
        # link new bucket to previous tail
        link_pos = jnp.where(do_alloc & (nbidx > 0), new_ptr, pool_cap)
        pool = pool.at[link_pos].set(ptr, mode="drop")
        top = top + jnp.where(do_alloc, alloc_slots, 0)

        # --- updated handle
        new_count = count + do_write.astype(_I)
        h_ptr = jnp.where(do_alloc, new_ptr.astype(_U), ptr)
        h_bidx = jnp.where(do_alloc, nbidx, bidx)
        h_count = jnp.where(is_new & do_alloc, _I(1), new_count)
        handle = pack_handle(h_ptr, h_count, h_bidx,
                             jnp.full((), STATE_READY, _I))

        # write handle into the key store:
        #   new key + alloc ok  -> claim slot with (k, handle)
        #   existing key        -> update handle value in place
        # masked OOR-drop scatters instead of lax.switch (in-place updates)
        case = jnp.where(~m, _I(0),
                         jnp.where(exists & do_write, _I(1),
                                   jnp.where(is_new & do_alloc, _I(2), _I(0))))
        oor = _U(ks.num_rows)
        hrow = jnp.where(case >= 1, row, oor)
        store = ks.ops.scatter_values(store, hrow[None], lane[None],
                                      handle[None])
        krow = jnp.where(case == 2, row, oor)
        store = ks.ops.scatter_keys(store, krow[None], lane[None], k[None])
        kcount = kcount + jnp.where(case == 2, _I(1), _I(0))

        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(do_write, _I(STATUS_INSERTED),
                                     jnp.where(mode == 2, _I(STATUS_FULL),
                                               _I(STATUS_POOL_FULL))))
        return (store, kcount, pool, top), status

    (store, kcount, pool, top), status = jax.lax.scan(
        step, (ks.store, ks.count, table.pool, table.alloc_top),
        (keys, values, words, mask))
    new_ks = dataclasses.replace(ks, store=store, count=kcount)
    return dataclasses.replace(table, key_store=new_ks, pool=pool,
                               alloc_top=top), status


def _insert_bulk(table: BucketListHashTable, keys, values, mask,
                 ) -> tuple[BucketListHashTable, jax.Array]:
    """Batched build: dedup + prefix-sum bucket allocator + scatter claims.

    Whole-batch rendering of the sequential insert, bit-exact against it:

    1. **Group** — the bulk engine's stable (masked, key, index) sort makes
       each key's live elements contiguous in batch order; element ``t`` of
       a key carries running count ``c = count0 + t`` (``count0`` from the
       pre-batch handle, 0 for new keys).  A value *opens* bucket ``j``
       exactly when ``c == cum[j]`` — pure static arithmetic per element.
    2. **Allocate** — bucket-opening elements draw their bucket's base
       address from an exclusive prefix sum of allocation sizes in batch
       order over the pool: precisely the addresses the sequential bump
       allocator hands out.  Pool exhaustion is resolved by a refinement
       loop: the earliest failing allocation in batch order is exact (its
       prefix only involves earlier, consistent allocations), the failing
       key is frozen from that element on (the sequential path retries the
       same-size bucket against a non-decreasing top, so one failure is
       terminal for the key), and the sweep repeats — one round per failing
       key, none in the common no-overflow case.
    3. **Claim** — new keys whose first allocation succeeded claim their
       key-store slot through ``bulk.place_claims`` (window-level
       scatter-min arbitration, priority = batch position).  Keys the
       arbitration reports FULL never demanded pool, which feeds back into
       step 2: the outer fixpoint alternates allocate/claim until stable
       (one extra round at most unless overflow and fullness interact).
    4. **Apply** — one pool scatter writes every value, one writes the
       bucket links, one batched store scatter writes claimed keys and
       final handles (count/bucket/tail-ptr read off the same arithmetic).
    """
    ks = table.key_store
    keys = sv.normalize_key_batch(keys, ks.key_words, "keys")
    values = sv.normalize_words(values, 1, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    if n == 0:
        return table, jnp.zeros((0,), _I)

    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)                    # (n_sizes + 1,)
    n_sizes = len(table.sizes)
    pool_cap = table.pool_capacity
    top0 = table.alloc_top
    tstat = (ks.ops, ks.scheme, ks.seed, ks.max_probes)

    # ---- 1. group structure in the sorted domain ---------------------------
    flag, skeys, sidx, vcols = bulk._sort_batch(keys, mask, [values[:, 0]])
    svals = vcols[0]
    live, is_rep, first_pos, last_pos = bulk._group_structure(flag, skeys)
    pos = jnp.arange(n, dtype=_I)
    t = pos - first_pos                                 # local rank in group
    lsize = last_pos - first_pos + 1                    # live group size

    swords = sv.key_hash_word(skeys)
    matched, mrow, mlane = bulk.probe_matches(tstat, ks.store, skeys, swords,
                                              is_rep, ks.count)
    hwin = ks.ops.value_windows(ks.store, mrow)         # (n, 2, W)
    handles = jnp.take_along_axis(
        hwin, mlane.astype(_I)[:, None, None], axis=2)[:, :, 0]
    ptr0_r, count0_r, bidx0_r, _ = unpack_handle(handles)
    exists = matched[first_pos]                         # per element, via rep
    ptr0 = jnp.where(exists, ptr0_r[first_pos].astype(_I), 0)
    count0 = jnp.where(exists, count0_r[first_pos], 0)
    bidx0 = jnp.where(exists, bidx0_r[first_pos], -1)

    # ---- static bucket arithmetic per element ------------------------------
    c = count0 + t                                      # running count pre-write
    jr = jnp.searchsorted(cum, c).astype(_I)            # first j with cum[j] >= c
    boundary = cum[jnp.clip(jr, 0, n_sizes)] == c       # opens bucket jr
    sched_ok = jr < n_sizes
    jv = jnp.clip(jr, 0, n_sizes - 1)
    alloc_sz = sizes[jv] + (jv > 0).astype(_I)          # data + prev-link slot
    jbkt = jnp.clip(jnp.searchsorted(cum, c, side="right").astype(_I) - 1,
                    0, n_sizes - 1)                     # bucket holding value c
    inf = _I(n + 1)

    def _alloc_prefix(gdead, fullk):
        """Admitted allocations + their bump addresses for the current
        freeze/full assumption.  Returns (admit, trig, start_s)."""
        admit = live & ~fullk[first_pos] & (t < gdead[first_pos])
        trig = admit & boundary
        size_eff = jnp.where(trig & sched_ok, alloc_sz, 0)
        size_b = jnp.zeros((n,), _I).at[sidx].set(size_eff)     # batch order
        start_b = top0 + jnp.cumsum(size_b) - size_b
        return admit, trig, start_b[sidx]

    def _alloc_fixpoint(fullk):
        """Freeze keys at their first failing allocation (exact in batch
        order; see step 2 of the module docstring)."""
        def cond(st):
            _, changed = st
            return changed

        def body(st):
            gdead, _ = st
            _, trig, start_s = _alloc_prefix(gdead, fullk)
            fail = trig & (~sched_ok | (start_s + alloc_sz > pool_cap))
            fpos = jnp.where(fail, sidx.astype(_I), n)
            k = jnp.argmin(fpos)                        # earliest batch failure
            found = fpos[k] < n
            rp = first_pos[k]
            gdead = gdead.at[jnp.where(found, rp, n)].min(t[k], mode="drop")
            return gdead, found

        gdead, _ = jax.lax.while_loop(
            cond, body, (jnp.full((n,), inf, _I), jnp.ones((), bool)))
        return gdead

    # ---- 2+3. outer fixpoint: pool allocation <-> key-store arbitration ----
    def ocond(st):
        changed, *_ = st
        return changed

    def obody(st):
        _, fullk, *_ = st
        gdead = _alloc_fixpoint(fullk)
        claim = is_rep & ~matched & (gdead > 0)         # first alloc succeeded
        placed, crow, clane, full = bulk.place_claims(tstat, ks.store, swords,
                                                      claim, sidx)
        changed = jnp.any(full != fullk)
        return changed, full, gdead, placed, crow, clane

    z = jnp.zeros((n,), bool)
    zu = jnp.zeros((n,), _U)
    st0 = (jnp.ones((), bool), z, jnp.full((n,), inf, _I), z, zu, zu)
    _, fullk, gdead, placed, crow, clane = jax.lax.while_loop(
        ocond, obody, st0)

    # ---- 4. apply ----------------------------------------------------------
    admit, trig, start_s = _alloc_prefix(gdead, fullk)
    size_eff = jnp.where(trig, alloc_sz, 0)             # all admitted trigs fit
    new_top = top0 + jnp.sum(size_eff, dtype=_I)

    # base address of each element's bucket: pre-existing tail keeps ptr0,
    # in-batch buckets read the prefix-sum address off their opening element
    # (sorted position first_pos + (cum[j] - count0) — directly addressable)
    def bucket_start(j):
        tpos = jnp.clip(first_pos + cum[jnp.clip(j, 0, n_sizes - 1)] - count0,
                        0, n - 1)
        inbatch = ~exists | (j != bidx0)
        return jnp.where(inbatch, start_s[tpos], ptr0)

    bstart = bucket_start(jbkt)
    vpos = bstart + (jbkt > 0) + (c - cum[jbkt])
    pool = table.pool
    pool = pool.at[jnp.where(admit, vpos, pool_cap)].set(svals, mode="drop")
    # prev-link writes of in-batch buckets j > 0
    link = admit & trig & (jbkt > 0)
    prev_ptr = bucket_start(jbkt - 1)
    pool = pool.at[jnp.where(link, bstart, pool_cap)].set(
        prev_ptr.astype(_U), mode="drop")

    # final handle per group (valid at rep positions)
    nwrit = jnp.where(fullk, 0, jnp.minimum(gdead, lsize))
    wrote = is_rep & (nwrit > 0)
    fcount = count0 + nwrit
    fj = jnp.clip(jnp.searchsorted(cum, jnp.maximum(fcount - 1, 0),
                                   side="right").astype(_I) - 1,
                  0, n_sizes - 1)
    fptr = bucket_start(fj)
    fhandle = pack_handle(fptr.astype(_U), fcount, fj,
                          jnp.full((n,), STATE_READY, _I))

    oor = _U(ks.num_rows)
    upd = matched & wrote                               # in-place handle update
    row = jnp.where(matched, mrow, crow)
    lane = jnp.where(matched, mlane, clane)
    vrow = jnp.where(upd | placed, row, oor)
    store = ks.ops.scatter_batch(ks.store, vrow, lane, skeys, fhandle, placed)
    kcount = ks.count + jnp.sum(placed, dtype=_I)

    status_s = jnp.where(~live, _I(STATUS_MASKED),
                         jnp.where(admit, _I(STATUS_INSERTED),
                                   jnp.where(fullk[first_pos], _I(STATUS_FULL),
                                             _I(STATUS_POOL_FULL))))
    status = jnp.zeros((n,), _I).at[sidx].set(status_s)

    new_ks = dataclasses.replace(ks, store=store, count=kcount)
    return dataclasses.replace(table, key_store=new_ks, pool=pool,
                               alloc_top=new_top), status


def insert_or_grow(table: BucketListHashTable, keys, values, mask=None, *,
                   policy=None, max_attempts: int = 4):
    """``insert`` under the auto-growth policy: migrates (key store and/or
    value pool) instead of ever returning ``STATUS_FULL`` /
    ``STATUS_POOL_FULL`` while capacity headroom remains.  Host-side
    wrapper — see ``repro.core.migrate``."""
    from repro.core import migrate
    return migrate.insert_or_grow(
        table, keys, values, mask,
        policy=migrate.DEFAULT_POLICY if policy is None else policy,
        max_attempts=max_attempts)


# ---------------------------------------------------------------------------
# retrieval — O(1) counts from handles; fused chain walk over the pool arena
# ---------------------------------------------------------------------------

def count_values(table: BucketListHashTable, keys, stats: bool = False):
    """Per-key value count, read straight off the handle (no probe walk)."""
    handles, found = sv.retrieve(table.key_store, keys)
    _, count, _, _ = unpack_handle(handles)
    cnt = jnp.where(found, count, 0)
    if stats:
        from repro.obs import metrics
        return cnt, metrics.bolt_on_stats(table.key_store, keys)
    return cnt


def _handle_probe(table: BucketListHashTable, keys_n):
    """Dedup + one representative probe: the fused retrieval front-end.

    Returns (is_rep, rep_of, found, ptr, rcnt, bidx, counts) — handle
    fields are valid where ``found`` (matched representatives); ``counts``
    is already fanned out to every duplicate query.
    """
    ks = table.key_store
    n = keys_n.shape[0]
    live = jnp.ones((n,), bool)
    is_rep, rep_of = bulk_retrieve.group_queries(keys_n, live)
    words = sv.key_hash_word(keys_n)
    tstat = (ks.ops, ks.scheme, ks.seed, ks.max_probes)
    matched, mrow, mlane = bulk.probe_matches(tstat, ks.store, keys_n, words,
                                              is_rep, ks.count)
    hwin = ks.ops.value_windows(ks.store, mrow)
    handles = jnp.take_along_axis(
        hwin, mlane.astype(_I)[:, None, None], axis=2)[:, :, 0]
    ptr, cnt, bidx, _ = unpack_handle(handles)
    found = is_rep & matched
    rcnt = jnp.where(found, cnt, 0)
    counts = bulk_retrieve._fan_out(rcnt, rep_of, live, n)
    return is_rep, rep_of, found, ptr, rcnt, bidx, counts


def chain_arena(table: BucketListHashTable, active, ptr, counts, bidx,
                rep_base=None, dense_cap: int | None = None):
    """Walk bucket chains tail->head, stamping the pool slot arena.

    The bucket-list rendering of ``bulk_retrieve.fused_walk``'s arena: per
    active query the chain is walked once (all queries in lockstep, one
    bucket per round, fixed-width chunked vector reads), and every value
    slot is stamped with (query index, value rank) — rank being the
    value's head-first position ``cum[j] + lane``, exactly the order the
    reference emits.  Distinct queries own disjoint chains, so stamps
    never collide — the same invariant the OA walk gets from
    one-key-per-slot.  Returns (qarena, rank_arena) over pool slots.

    **Dense mode** (``rep_base`` given): the walk records only each
    query's per-bucket data-start pointer — an (n,)-sized scatter per
    round instead of the (n, chunk) slot stamping — and the
    representative-dense slot list ``_emit_dense`` consumes is then built
    by ONE output-scale gather: dense position ``d`` finds its owning
    representative (``searchsorted`` over the cumulative rep counts), its
    rank's bucket (``searchsorted`` over the growth schedule), and reads
    ``slot = dstart[rep, bucket] + (rank - cum[bucket])``.  A gather has
    no write hazards and its cost tracks the OUTPUT size, not
    ``n * max_bucket`` — the fix for the fused-retrieve gap, where the
    lockstep stamping dwarfed the two-pass reference at small batch.
    Returns that (dense_cap,) slot list alone.
    """
    n = active.shape[0]
    pool_cap = table.pool_capacity
    dense = rep_base is not None
    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)
    max_rounds = len(table.sizes)
    chunk = int(min(max(table.sizes), 128))
    lanes_c = jnp.arange(chunk, dtype=_I)
    if dense:
        arenas = (jnp.zeros((n * max_rounds,), _I),)    # dstart, (query, bucket)
    else:
        arenas = (jnp.full((pool_cap,), _I(n)), jnp.zeros((pool_cap,), _I))
    idx = jnp.arange(n, dtype=_I)
    j0 = jnp.where(active, bidx, -1)

    def cond(st):
        r, j = st[0], st[1]
        return jnp.logical_and(r < max_rounds, jnp.any(j >= 0))

    def body(st):
        r, j, p = st[:3]
        arenas = st[3:]
        act = j >= 0
        jc = jnp.clip(j, 0, sizes.shape[0] - 1)
        bsize = sizes[jc]
        base = cum[jc]                                  # values before bucket j
        has_link = j > 0
        data_start = p.astype(_I) + has_link.astype(_I)

        if dense:
            dpos = jnp.where(act, idx * max_rounds + jc, n * max_rounds)
            arenas = (arenas[0].at[dpos].set(data_start, mode="drop"),)
        else:
            valid = jnp.minimum(counts - base, bsize)   # tail partially filled
            maxv = jnp.max(jnp.where(act, valid, 0))

            def ccond(cst):
                return cst[0] * chunk < maxv

            def cbody(cst):
                cpos = cst[0]
                lanes = cpos * chunk + lanes_c          # (chunk,)
                gidx = data_start[:, None] + lanes[None, :]
                ok = (lanes[None, :] < valid[:, None]) & act[:, None]
                rv = base[:, None] + lanes[None, :]
                slot = jnp.where(ok, gidx, pool_cap).reshape(-1)
                qv = jnp.broadcast_to(idx[:, None], gidx.shape).reshape(-1)
                qa = cst[1].at[slot].set(qv, mode="drop")
                ra = cst[2].at[slot].set(rv.reshape(-1), mode="drop")
                return cpos + 1, qa, ra

            cres = jax.lax.while_loop(ccond, cbody,
                                      (jnp.zeros((), _I),) + arenas)
            arenas = cres[1:]
        plink = table.pool[jnp.clip(p.astype(_I), 0, pool_cap - 1)]
        p = jnp.where(act & has_link, plink, p)
        j = jnp.where(act, j - 1, j)
        return (r + 1, j, p) + arenas

    res = jax.lax.while_loop(
        cond, body, (jnp.zeros((), _I), j0, ptr) + arenas)
    if not dense:
        return res[3], res[4]
    dstart = res[3]
    # one gather builds the dense slot list: position -> (rep, rank) ->
    # (bucket, lane) -> pool slot.  Positions past the live total read
    # garbage that only the emit-side valid mask ever sees.
    cc = jnp.cumsum(jnp.where(active, counts, 0))       # rep segment ends
    d = jnp.arange(dense_cap, dtype=_I)
    seg = jnp.clip(jnp.searchsorted(cc, d, side="right").astype(_I),
                   0, max(n - 1, 0))
    rank = d - rep_base[seg]
    b = jnp.clip(jnp.searchsorted(cum, rank, side="right").astype(_I) - 1,
                 0, max_rounds - 1)
    return dstart[jnp.clip(seg * max_rounds + b, 0, n * max_rounds - 1)] \
        + (rank - cum[b])


def retrieve_all(table: BucketListHashTable, keys, out_capacity: int,
                 stats: bool = False):
    """Gather every value for each key by walking its bucket list tail->head
    (Fig. 4).  Returns the paper's (values, offsets, counts) layout.

    The default backend rides the fused retrieval engine: duplicate probe
    keys walk once, the chain walk stamps the pool slot arena, and the
    engine's shared compaction (``bulk_retrieve._emit``) packs the output.
    ``"pallas"`` runs the chain walk as the COPS bucket-walk tile;
    ``"scan"`` keeps the private two-pass reference — all bit-identical.
    ``stats`` (static) appends an in-graph ``obs.metrics.TableStats``.
    """
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        res = cops_ops.bucket_retrieve_all(table, keys, out_capacity)
    elif table.backend != "scan":
        res = _retrieve_fused(table, keys, out_capacity)
    else:
        res = retrieve_all_scan(table, keys, out_capacity)
    if stats:
        from repro.obs import metrics
        return res + (metrics.bolt_on_stats(table.key_store, keys),)
    return res


def _retrieve_fused(table: BucketListHashTable, keys, out_capacity: int,
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused path: dedup + one handle probe + one chain walk + shared emit."""
    ks = table.key_store
    keys = sv.normalize_key_batch(keys, ks.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        return (jnp.zeros((out_capacity,), _U), jnp.zeros((1,), _I),
                jnp.zeros((0,), _I))
    is_rep, rep_of, found, ptr, rcnt, bidx, counts = _handle_probe(table, keys)
    rep_base = bulk_retrieve.rep_offsets(is_rep, rcnt)
    dcap = bulk_retrieve.dense_capacity(table.pool_capacity, out_capacity)
    rd = chain_arena(table, found, ptr, rcnt, bidx,
                     rep_base=rep_base, dense_cap=dcap)
    out, offsets, counts = bulk_retrieve._emit_dense(
        lambda s: table.pool[s][:, None], table.pool_capacity, out_capacity,
        counts, rep_of, rep_base, rd)
    return out[:, 0], offsets, counts


def retrieve_all_scan(table: BucketListHashTable, keys, out_capacity: int,
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference two-pass retrieval: per-query handle lookup, then every
    queried list walked in lockstep (no dedup, no shared compaction)."""
    ks = table.key_store
    keys = sv.normalize_key_batch(keys, ks.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        return (jnp.zeros((out_capacity,), _U), jnp.zeros((1,), _I),
                jnp.zeros((0,), _I))
    handles, found = sv.retrieve(ks, keys)
    ptr, count, bidx, _ = unpack_handle(handles)
    counts = jnp.where(found, count, 0)
    offsets = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts)])
    sizes = jnp.asarray(table.sizes, _I)
    cum = jnp.asarray(table.cum, _I)
    s_max = int(max(table.sizes))
    max_rounds = len(table.sizes)
    out = jnp.zeros((out_capacity,), _U)
    # buckets are read in fixed-width chunks with a data-dependent inner
    # loop: rounds where every active bucket is small never pay for s_max
    # (growth=1.1 schedules reach s_max in the hundreds, but r=1 workloads
    # only ever touch size-1 buckets)
    chunk = int(min(s_max, 128))
    lanes_c = jnp.arange(chunk, dtype=_I)

    def cond(st):
        r, j, ptr, out = st
        return jnp.logical_and(r < max_rounds, jnp.any(j >= 0))

    def body(st):
        r, j, ptr, out = st
        active = j >= 0
        jc = jnp.clip(j, 0, sizes.shape[0] - 1)
        bsize = sizes[jc]
        base = cum[jc]                                        # values before bucket j
        has_link = (j > 0)
        data_start = ptr.astype(_I) + has_link.astype(_I)
        # tail bucket may be partially filled
        valid_in_bucket = jnp.minimum(counts - base, bsize)
        max_valid = jnp.max(jnp.where(active, valid_in_bucket, 0))

        def chunk_cond(cst):
            c, out = cst
            return c * chunk < max_valid

        def chunk_body(cst):
            c, out = cst
            lanes = c * chunk + lanes_c                       # (chunk,)
            gidx = data_start[:, None] + lanes[None, :]       # (n, chunk)
            vals = table.pool[jnp.clip(gidx, 0, table.pool_capacity - 1)]
            lane_ok = ((lanes[None, :] < valid_in_bucket[:, None])
                       & active[:, None])
            pos = offsets[:n, None] + base[:, None] + lanes[None, :]
            pos = jnp.where(lane_ok, pos, out_capacity)
            out = out.at[pos.reshape(-1)].set(vals.reshape(-1), mode="drop")
            return c + 1, out

        _, out = jax.lax.while_loop(chunk_cond, chunk_body,
                                    (jnp.zeros((), _I), out))
        # follow the prev link
        link = table.pool[jnp.clip(ptr.astype(_I), 0, table.pool_capacity - 1)]
        ptr = jnp.where(active & has_link, link, ptr)
        j = jnp.where(active, j - 1, j)
        return r + 1, j, ptr, out

    j0 = jnp.where(found, bidx, -1)
    _, _, _, out = jax.lax.while_loop(cond, body,
                                      (jnp.zeros((), _I), j0, ptr, out))
    return out, offsets, counts


def for_each(table: BucketListHashTable, keys, fn: Callable, max_values: int):
    """Apply ``fn(key, value, valid)`` per (query, value) pair (cf. §IV-B.4)."""
    ks = table.key_store
    keys_n = sv.normalize_key_batch(keys, ks.key_words, "keys")
    n = keys_n.shape[0]
    vals, offsets, counts = retrieve_all(table, keys_n, n * max_values)
    idx = offsets[:n, None] + jnp.arange(max_values)[None, :]
    valid = jnp.arange(max_values)[None, :] < counts[:, None]
    per_key = vals[jnp.where(valid, idx, 0)]
    return jax.vmap(lambda k, vs, ms: jax.vmap(lambda v, m: fn(k, v, m))(vs, ms))(
        keys_n, per_key, valid)
