"""SingleValueHashTable — open-addressing, COPS probing, functional updates.

The table is a pytree: ``insert``/``erase`` return a new table (XLA reuses
the buffers in-place under jit when the argument is donated), ``retrieve`` is
pure.  This is the JAX rendering of the paper's host-sided *and* device-sided
interface (DESIGN.md §3.1): because ops are pure jittable functions they can
be fused into larger computations exactly like the CUDA device-sided API.

Semantics (paper §IV-B.3–5, adapted):

- ``insert`` upserts: a present key has its value overwritten and reports
  ``STATUS_UPDATED`` (the paper's "duplicate warning").  Absent keys claim the
  earliest candidate slot (EMPTY or TOMBSTONE) in probe order.  Unlike a
  naive tombstone-reuse scheme we keep probing until a *match* or an *EMPTY*
  window before claiming a remembered tombstone — this preserves the
  invariant "at most one live copy per key" after deletions, and keeps every
  live key at-or-before the first EMPTY window of its probe sequence, which
  is what lets retrieval stop at the first EMPTY (paper §IV-B.4).
- ``erase`` writes TOMBSTONEs (§IV-B.5).
- Insertion has two equivalent renderings, selected by ``backend``:

  * ``"jax"`` (default) — the **vectorized bulk-build engine**
    (``repro.core.bulk``): intra-batch duplicates are pre-merged with
    sort + segment-combine, then whole-batch rounds of probe →
    scatter-min slot arbitration → batched scatter resolve the batch in
    ~max_rounds vectorized sweeps instead of n sequential probe walks.
  * ``"scan"`` — the sequential reference: ``lax.scan`` over the batch,
    one probe walk per key.  Within a shard there is exactly one writer
    (ownership partitioning, DESIGN.md §2), so serial order — not CAS —
    is the correctness mechanism.  The bulk engine reproduces this order
    exactly (bit-identical state and statuses); the scan path is kept as
    the oracle for parity tests and as the fallback for RMW folds with no
    associative combiner.
  * ``"pallas"`` — the COPS Pallas kernel (``repro.kernels.cops``).

  Retrieval has no write hazards and is fully vectorized on every backend.

Key/value widths are in 32-bit words (1 => u32, 2 => u64 as hi/lo planes,
N => composite multi-column keys packed by ``hashing.pack_columns`` —
key batches may be passed as tuples of u32 columns, see ``normalize_keys``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, layouts, probing
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_UPDATED,
    TOMBSTONE_KEY,
    register_struct,
    static_field,
    table_geometry,
)

_U = jnp.uint32
_I = jnp.int32


@register_struct
@dataclasses.dataclass
class SingleValueHashTable:
    store: dict
    count: jax.Array                      # live keys (i32 scalar)
    num_rows: int = static_field()
    window: int = static_field()
    key_words: int = static_field()
    value_words: int = static_field()
    scheme: str = static_field()
    layout: str = static_field()
    seed: int = static_field()
    max_probes: int = static_field()
    backend: str = static_field()

    # -- convenience (python-side) -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.num_rows * self.window

    @property
    def ops(self) -> layouts.StoreOps:
        """The table's store protocol (cached geometry-bound layout ops)."""
        return layouts.make_ops(self.layout, self.num_rows, self.window,
                                self.key_words, self.value_words)

    def load_factor(self) -> jax.Array:
        return self.count.astype(jnp.float32) / jnp.float32(self.capacity)

    def key_planes(self) -> jax.Array:
        return self.ops.key_planes(self.store)

    def value_planes(self) -> jax.Array:
        return self.ops.value_planes(self.store)


def create(min_capacity: int, *, key_words: int = 1, value_words: int = 1,
           window: int = DEFAULT_WINDOW, scheme: str = "cops",
           layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax",
           kind: str | None = None,
           quotient: bool = False) -> SingleValueHashTable:
    """Create an empty table with capacity >= min_capacity rounded to p*W, p prime.

    ``kind="bucketed"`` selects the two-choice bucketed lane in one
    switch: scheme ``"bucketed"`` (two candidate buckets + bounded cuckoo
    eviction on insert, see ``core.cuckoo``) over the bucketed store
    geometry.  ``quotient=True`` additionally stores ``q*2 + choice``
    remainders instead of full key words (< 32 key bits per slot; 1-word
    keys only — see ``core.probing`` module docstring).
    """
    if kind is not None:
        if kind != "bucketed":
            raise ValueError(f"unknown table kind {kind!r}")
        scheme = "bucketed"
    if scheme == "bucketed" and layout == "soa":
        layout = "bucketedq" if quotient else "bucketed"
    if quotient:
        if scheme != "bucketed":
            raise ValueError("quotient storage requires scheme='bucketed'")
        layout = "bucketedq"
    if scheme not in probing.SCHEMES:
        raise ValueError(f"scheme {scheme!r} not in {probing.SCHEMES}")
    num_rows, _ = table_geometry(min_capacity, window)
    store = layouts.create(layout, num_rows, window, key_words, value_words)
    return SingleValueHashTable(
        store=store, count=jnp.zeros((), _I), num_rows=num_rows, window=window,
        key_words=key_words, value_words=value_words, scheme=scheme, layout=layout,
        seed=seed, max_probes=int(max_probes or num_rows), backend=backend)


# ---------------------------------------------------------------------------
# normalization helpers
# ---------------------------------------------------------------------------

def as_key_planes(x):
    """Coerce the accepted key spellings to a plane array (others unchanged).

    - a TUPLE of (n,) u32 ARRAY columns — a *composite* multi-column key
      — packs via ``hashing.pack_columns`` (column 0 most significant).
      Only tuples whose every element is already a 1-D array trigger
      this: plain lists, tuples of scalars (``(1, 2, 3)``) and nested
      tuples of numbers keep their historical ``jnp.asarray`` meaning,
      so no pre-existing spelling is silently reinterpreted;
    - host-side numpy uint64 — splits into the table-native (lo, hi)
      planes via ``common.split_u64`` (no jax_enable_x64 needed);
    - anything else passes through for ``normalize_words``' own checks.
    """
    if (isinstance(x, tuple) and len(x) > 0
            and all(isinstance(c, (np.ndarray, jax.Array))
                    and c.ndim == 1 for c in x)):
        return hashing.pack_columns(x)
    if isinstance(x, np.ndarray) and x.dtype == np.uint64:
        from repro.core.common import split_u64
        hi, lo = split_u64(x)
        return jnp.stack([lo, hi], axis=1)
    return x


def normalize_words(x, words: int, name: str) -> jax.Array:
    """Accept (n,) u32 [words==1] or (n, words) u32; return (n, words).

    Plain word normalization — used for VALUE batches as well as keys,
    so it performs no key-specific coercion (a tuple of value columns
    would otherwise be silently packed in the key convention's reversed
    plane order).  Key call sites go through ``normalize_key_batch``.
    """
    x = jnp.asarray(x)
    if x.dtype != jnp.uint32:
        if x.dtype in (jnp.int32,):
            x = x.astype(_U)
        else:
            raise TypeError(f"{name} must be uint32 words, got {x.dtype}")
    if x.ndim == 1:
        x = x[:, None]
    if x.shape[-1] != words:
        raise ValueError(f"{name} has {x.shape[-1]} words, table expects {words}")
    return x


def normalize_key_batch(x, words: int, name: str = "keys") -> jax.Array:
    """``normalize_words`` for KEY batches: additionally accepts the
    composite spellings (tuple of u32 columns, host numpy uint64) via
    ``as_key_planes``.  Every key-consuming table entry point normalizes
    through here, so the whole API takes all three spellings."""
    return normalize_words(as_key_planes(x), words, name)


def normalize_keys(x, words: int | None = None, name: str = "keys",
                   ) -> tuple[jax.Array, int]:
    """``normalize_words`` that can *infer* the word count from the input.

    The entry point for APIs that build their own table (relational
    ``hash_join`` / ``aggregate`` / ``distinct``): a tuple of N columns
    infers ``key_words = N``, a (n, kw) plane array infers ``kw``, a flat
    (n,) batch infers 1, numpy uint64 infers 2.  An explicit ``words``
    still wins (and is validated).  Returns ``(planes, key_words)``.
    """
    x = as_key_planes(x)
    if words is None:
        arr = jnp.asarray(x)
        words = arr.shape[-1] if arr.ndim == 2 else 1
    return normalize_words(x, words, name), words


def key_hash_word(keys: jax.Array) -> jax.Array:
    """Fold (n, key_words) into the u32 word fed to the hash mixers."""
    if keys.shape[-1] == 1:
        return keys[..., 0]
    word = keys[..., 0]
    for w in range(1, keys.shape[-1]):
        word = hashing.combine_planes(keys[..., w], word)
    return word


def probe_words(table, keys: jax.Array) -> jax.Array:
    """The per-key u32 "probe word" every walk derives rows/steps from.

    Plain stores hash the folded key word downstream; quotient stores
    carry the FULL mixed hash as the probe word (row = word mod p, match
    target = attempt-dependent remainder — see ``probing.match_word``),
    which keeps decode exact.
    """
    if table.ops.quotient:
        return hashing.full_hash(keys[:, 0], table.seed)
    return key_hash_word(keys)


def _tstatic(table):
    """(ops, scheme, seed, effective_probes) — the scan walks' static tuple.

    Mirrors ``bulk._tstatic``: the probe budget is clamped to the
    scheme's distinct-row coverage (``probing.effective_probes``) so the
    sequential walks are revisit-free too — the same coverage-clamp
    bugfix, applied to the reference paths.
    """
    return (table.ops, table.scheme, table.seed,
            probing.effective_probes(table.scheme, table.max_probes,
                                     table.num_rows))


# ---------------------------------------------------------------------------
# vectorized probe walk (shared by retrieve / erase / locate)
# ---------------------------------------------------------------------------

def _locate(table: SingleValueHashTable, keys: jax.Array):
    """Vectorized COPS walk for a batch of keys.

    Returns (rows, lanes, found) — position of each key if present.  Walks
    until every key has either matched or hit a window containing EMPTY
    (absence proof), or max_probes is exhausted.
    """
    n = keys.shape[0]
    quotient = table.ops.quotient
    word = probe_words(table, keys)
    row0 = probing.initial_row(word, table.num_rows, table.seed, quotient)
    step = probing.row_step(table.scheme, word, table.num_rows, table.seed,
                            quotient)
    max_probes = probing.effective_probes(table.scheme, table.max_probes,
                                          table.num_rows)
    w = table.window

    def cond(state):
        attempt, row, done, frow, flane, found = state
        return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

    def body(state):
        attempt, row, done, frow, flane, found = state
        win = table.ops.key_windows(table.store, row)
        if quotient:
            tgt = probing.match_word(word, table.num_rows, attempt, True)
            match = win[:, 0, :] == tgt[:, None]                  # (n, W)
        else:
            match = jnp.all(win == keys[:, :, None], axis=1)      # (n, W)
        has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)   # (n,)
        mlane = probing.vote_lowest(match)                        # (n,) W if none
        hit = (mlane < w) & ~done
        frow = jnp.where(hit, row, frow)
        flane = jnp.where(hit, mlane.astype(_U), flane)
        found = found | hit
        done = done | hit | has_empty
        nrow = probing.advance_row(table.scheme, row, step, attempt, table.num_rows)
        row = jnp.where(done, row, nrow)
        return attempt + 1, row, done, frow, flane, found

    state = (jnp.zeros((), _I), row0, jnp.zeros((n,), bool),
             jnp.zeros((n,), _U), jnp.zeros((n,), _U), jnp.zeros((n,), bool))
    _, _, _, frow, flane, found = jax.lax.while_loop(cond, body, state)
    return frow, flane, found


def retrieve(table: SingleValueHashTable, keys, stats: bool = False):
    """Batch lookup -> (values (n, value_words) [or (n,) if 1 word], found (n,) bool).

    Dispatches on ``table.backend`` like ``insert``: the default ``"jax"``
    path is the fused bulk-retrieval engine (``repro.core.bulk_retrieve``
    — duplicate probe keys walk the table once and fan out by group),
    ``"scan"`` keeps the direct per-element walk as the bit-exact
    reference, and ``"pallas"`` runs the COPS lookup kernel.

    ``stats`` (static) appends an in-graph ``obs.metrics.TableStats`` to
    the return; ``stats=False`` compiles to the pre-telemetry graph.
    """
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        vals, found = cops_ops.retrieve(table, keys)
    elif table.backend != "scan":
        from repro.core import bulk_retrieve
        return bulk_retrieve.retrieve_single(table, keys, stats=stats)
    else:
        vals, found = retrieve_scan(table, keys)
    if stats:
        from repro.obs import metrics
        return vals, found, metrics.bolt_on_stats(table, keys)
    return vals, found


def retrieve_scan(table: SingleValueHashTable, keys) -> tuple[jax.Array, jax.Array]:
    """Reference lookup: one direct probe walk per batch (no dedup)."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    rows, lanes, found = _locate(table, keys)
    vp = table.value_planes()                                     # (vw, p, W)
    vals = vp[:, rows, lanes].T                                   # (n, vw)
    vals = jnp.where(found[:, None], vals, 0)
    if table.value_words == 1:
        return vals[:, 0], found
    return vals, found


def contains(table: SingleValueHashTable, keys) -> jax.Array:
    keys = normalize_key_batch(keys, table.key_words, "keys")
    if table.backend != "scan":
        from repro.core import bulk_retrieve
        return bulk_retrieve.contains_single(table, keys)
    return _locate(table, keys)[2]


def _distinct_count(keys: jax.Array, sel: jax.Array) -> jax.Array:
    """Number of distinct key vectors among ``keys[sel]`` (O(n log n) sort)."""
    n = sel.shape[0]
    kw = keys.shape[1]
    ops = [(~sel).astype(_U)] + [keys[:, w] for w in range(kw)]
    out = jax.lax.sort(tuple(ops), num_keys=kw + 1)
    flag, skeys = out[0], jnp.stack(out[1:], axis=1)
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            jnp.all(skeys[1:] == skeys[:-1], axis=1)
                            & (flag[1:] == 0) & (flag[:-1] == 0)])
    return jnp.sum((flag == 0) & ~same, dtype=_I)


def erase(table: SingleValueHashTable, keys, mask=None) -> tuple[SingleValueHashTable, jax.Array]:
    """Tombstone matching slots (paper §IV-B.5). Returns (table, erased_mask).

    The default path folds erase into the fused bulk-retrieval engine:
    one representative walk locates every distinct live key and a single
    batched scatter writes the tombstones (the count delta falls out of
    the group structure).  ``backend="scan"`` keeps the direct walk +
    distinct-count reference.
    """
    if table.backend != "scan":
        from repro.core import bulk_retrieve
        return bulk_retrieve.erase_single(table, keys, mask)
    return erase_scan(table, keys, mask)


def erase_scan(table: SingleValueHashTable, keys, mask=None,
               ) -> tuple[SingleValueHashTable, jax.Array]:
    """Reference erase: direct batch walk + distinct-key count delta."""
    keys = normalize_key_batch(keys, table.key_words, "keys")
    rows, lanes, found = _locate(table, keys)
    if mask is not None:
        found = found & mask
    # OOR row == num_rows drops masked/not-found scatters.
    srows = jnp.where(found, rows, _U(table.num_rows))
    store = table.ops.scatter_key_word(table.store, srows, lanes,
                                       TOMBSTONE_KEY)
    # Live-count delta = distinct erased keys (duplicates in the batch hit
    # one slot, so a first-occurrence dedup — not a per-element sum, and not
    # the old O(capacity) full-table recount — gives the exact decrement.
    count = table.count - _distinct_count(keys, found)
    return dataclasses.replace(table, store=store, count=count), found


# ---------------------------------------------------------------------------
# insertion — bulk scatter-arbitration engine by default (repro.core.bulk);
# backend="scan" keeps the sequential-over-the-batch reference
# ---------------------------------------------------------------------------

def _probe_for_insert(table_static, store, key_vec, word):
    """Walk the probe sequence for one key.

    Returns (mode, row, lane): mode 0 = matched existing key, 1 = claim
    candidate slot, 2 = full.  ``table_static`` is the engines' shared
    (ops, scheme, seed, max_probes) tuple — the store protocol object
    carries the geometry.
    """
    ops, scheme, seed, max_probes = table_static
    num_rows, w = ops.num_rows, ops.window
    row0 = probing.initial_row(word, num_rows, seed, ops.quotient)
    step = probing.row_step(scheme, word, num_rows, seed, ops.quotient)

    def cond(st):
        attempt, row, done, *_ = st
        return jnp.logical_and(attempt < max_probes, ~done)

    def body(st):
        attempt, row, done, crow, clane, have_cand, mrow, mlane, matched = st
        win = ops.key_windows(store, row[None])[0]                  # (kw, W)
        if ops.quotient:
            match = win[0] == probing.match_word(word, num_rows, attempt,
                                                 True)              # (W,)
        else:
            match = jnp.all(win == key_vec[:, None], axis=0)               # (W,)
        empty = win[0] == EMPTY_KEY
        tomb = win[0] == TOMBSTONE_KEY
        m_lane = probing.vote_lowest(match[None])[0]
        c_lane = probing.vote_lowest((empty | tomb)[None])[0]
        has_empty = jnp.any(empty)
        hit = m_lane < w
        # remember the EARLIEST candidate seen over the whole walk
        new_cand = jnp.logical_and(~have_cand, c_lane < w)
        crow = jnp.where(new_cand, row, crow)
        clane = jnp.where(new_cand, c_lane.astype(_U), clane)
        have_cand = have_cand | (c_lane < w)
        mrow = jnp.where(hit, row, mrow)
        mlane = jnp.where(hit, m_lane.astype(_U), mlane)
        matched = matched | hit
        done = hit | has_empty
        nrow = probing.advance_row(scheme, row, step, attempt, num_rows)
        return (attempt + 1, jnp.where(done, row, nrow), done, crow, clane,
                have_cand, mrow, mlane, matched)

    z = jnp.zeros((), _U)
    st = (jnp.zeros((), _I), row0, jnp.zeros((), bool), z, z,
          jnp.zeros((), bool), z, z, jnp.zeros((), bool))
    (_, _, _, crow, clane, have_cand, mrow, mlane, matched) = \
        jax.lax.while_loop(cond, body, st)
    mode = jnp.where(matched, _I(0), jnp.where(have_cand, _I(1), _I(2)))
    row = jnp.where(matched, mrow, crow)
    lane = jnp.where(matched, mlane, clane)
    return mode, row, lane


def insert(table: SingleValueHashTable, keys, values, mask=None,
           stats: bool = False):
    """Batch upsert. Returns (table, status (n,) i32) — see STATUS_* codes.

    Duplicate keys inside one batch behave as consecutive upserts (second
    occurrence reports STATUS_UPDATED).  Dispatches on ``table.backend``:
    ``"jax"`` runs the vectorized bulk engine, ``"scan"`` the sequential
    reference, ``"pallas"`` the COPS kernel — all bit-identical.

    ``stats`` (static) appends an in-graph ``obs.metrics.TableStats``:
    the jax backend threads counters through the engine loops; scan and
    pallas run their op unchanged and measure with a bolt-on walk.
    """
    if table.scheme == "bucketed":
        return _insert_bucketed(table, keys, values, mask, stats)
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        ntable, status = cops_ops.insert(table, keys, values, mask)
    elif table.backend != "scan":
        from repro.core import bulk
        return bulk.insert_single(table, keys, values, mask, stats=stats)
    else:
        ntable, status = insert_scan(table, keys, values, mask)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(ntable, keys,
                                                     status=status, mask=mask)
    return ntable, status


def _core_insert(table: SingleValueHashTable, keys_n, values_n, mask):
    """Backend dispatch on pre-normalized batches, WITHOUT the bucketed
    rescue — the plain insert the cuckoo pass composes over (and re-enters
    for the post-eviction re-insert; it must never recurse into rescue)."""
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        return cops_ops.insert(table, keys_n, values_n, mask)
    if table.backend != "scan":
        from repro.core import bulk
        return bulk.insert_single(table, keys_n, values_n, mask)
    return insert_scan(table, keys_n, values_n, mask)


def _insert_bucketed(table: SingleValueHashTable, keys, values, mask,
                     stats: bool):
    """Bucketed-lane insert: plain two-choice placement, then the bounded
    cuckoo-eviction rescue (``core.cuckoo``) for residual FULL claimers.
    Identical rescue graph on every backend => parity by construction."""
    keys_n = normalize_key_batch(keys, table.key_words, "keys")
    values_n = normalize_words(values, table.value_words, "values")
    ntable, status = _core_insert(table, keys_n, values_n, mask)
    from repro.core import cuckoo
    ntable, status = cuckoo.rescue(ntable, keys_n, values_n, mask, status,
                                   _core_insert)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(ntable, keys_n,
                                                     status=status, mask=mask)
    return ntable, status


def insert_scan(table: SingleValueHashTable, keys, values, mask=None,
                ) -> tuple[SingleValueHashTable, jax.Array]:
    """Sequential-scan reference upsert: one probe walk per batch element.

    Within a shard there is exactly one writer, so serial order — not CAS —
    provides the paper's linearizability (DESIGN.md §2).  Kept as the parity
    oracle for the bulk engine and the Pallas kernel.
    """
    keys = normalize_key_batch(keys, table.key_words, "keys")
    values = normalize_words(values, table.value_words, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    words = probe_words(table, keys)
    tstatic = _tstatic(table)

    def step(carry, inp):
        store, count = carry
        k, v, word, m = inp
        mode, row, lane = _probe_for_insert(tstatic, store, k, word)
        # case 0: no-op (masked / full), 1: update value, 2: claim slot.
        # Writes are masked via out-of-range rows (dropped scatters) rather
        # than lax.switch — conditional branches returning the store defeat
        # in-place buffer reuse (XLA copies the whole table per element).
        case = jnp.where(~m, _I(0),
                         jnp.where(mode == 0, _I(1),
                                   jnp.where(mode == 1, _I(2), _I(0))))
        oor = _U(table.num_rows)
        vrow = jnp.where(case >= 1, row, oor)
        store = table.ops.scatter_values(store, vrow[None], lane[None],
                                         v[None])
        krow = jnp.where(case == 2, row, oor)
        kvec = k
        if table.ops.quotient:
            row0 = probing.initial_row(word, table.num_rows, table.seed, True)
            kvec = probing.stored_word(word, table.num_rows, row != row0,
                                       True)[None]
        store = table.ops.scatter_keys(store, krow[None], lane[None],
                                       kvec[None])
        count = count + jnp.where(case == 2, _I(1), _I(0))
        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(mode == 0, _I(STATUS_UPDATED),
                                     jnp.where(mode == 1, _I(STATUS_INSERTED),
                                               _I(STATUS_FULL))))
        return (store, count), status

    (store, count), status = jax.lax.scan(step, (table.store, table.count),
                                          (keys, values, words, mask))
    return dataclasses.replace(table, store=store, count=count), status


def insert_or_grow(table: SingleValueHashTable, keys, values, mask=None, *,
                   policy=None, max_attempts: int = 4):
    """``insert`` under the auto-growth policy: migrates (grow/compact)
    instead of ever returning ``STATUS_FULL`` while capacity headroom
    remains.  Host-side wrapper — see ``repro.core.migrate``."""
    from repro.core import migrate
    return migrate.insert_or_grow(
        table, keys, values, mask,
        policy=migrate.DEFAULT_POLICY if policy is None else policy,
        max_attempts=max_attempts)


# ---------------------------------------------------------------------------
# higher-order ops (paper §IV-B.4: for_each / for_all)
# ---------------------------------------------------------------------------

def for_each(table: SingleValueHashTable, keys, fn: Callable) -> Any:
    """Apply ``fn(key, value, found)`` vectorized over a query batch.

    The JAX rendering of the paper's device-sided callback: ``fn`` is traced
    into the same jitted computation, so no intermediate results hit HBM.
    """
    keys = normalize_key_batch(keys, table.key_words, "keys")
    vals, found = retrieve(table, keys)
    return jax.vmap(fn)(keys, normalize_words(vals, table.value_words, "values"),
                        found)


def for_all(table: SingleValueHashTable, fn: Callable) -> Any:
    """Apply ``fn(key, value, live)`` over every slot of the table."""
    kp = table.key_planes().reshape(table.key_words, -1).T      # (c, kw)
    vp = table.value_planes().reshape(table.value_words, -1).T  # (c, vw)
    live = (kp[:, 0] != EMPTY_KEY) & (kp[:, 0] != TOMBSTONE_KEY)
    return jax.vmap(fn)(kp, vp, live)


def update_values(table: SingleValueHashTable, keys, update_fn: Callable,
                  init, mask=None, values=None, combine: Callable | None = None,
                  stats: bool = False):
    """Read-modify-write upsert: present -> update_fn(old, key, new),
    absent -> insert ``init``.  Substrate for CountingHashTable and the
    group-by aggregates in repro.relational.

    ``values`` optionally carries a per-element payload into ``update_fn`` as
    its third argument (the aggregation operand); when omitted the broadcast
    ``init`` element is passed instead, so counters need no separate stream.

    ``combine(a, b)`` is the associative pre-aggregation of the operand
    stream (``update_fn(update_fn(x,k,a),k,b) == update_fn(x,k,combine(a,b))``
    — e.g. ``+`` for sums, ``minimum`` for min).  When given (and the
    backend is not ``"scan"``), duplicates are pre-merged and the vectorized
    bulk engine runs; without it the fold is not reorderable and the
    sequential scan reference is used.
    """
    keys = normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    init = normalize_words(jnp.broadcast_to(jnp.asarray(init, _U),
                                            (n,) if table.value_words == 1
                                            else (n, table.value_words)),
                           table.value_words, "init")
    values = init if values is None else normalize_words(
        values, table.value_words, "values")
    if combine is not None and table.backend != "scan":
        from repro.core import bulk
        return bulk.update_single(table, keys, update_fn, combine, init,
                                  values, mask, stats=stats)
    words = probe_words(table, keys)
    tstatic = _tstatic(table)

    def step(carry, inp):
        store, count = carry
        k, v0, vnew_in, word, m = inp
        mode, row, lane = _probe_for_insert(tstatic, store, k, word)
        old = table.ops.value_windows(store, row[None])[0, :, lane]
        upd = update_fn(old, k, vnew_in)
        case = jnp.where(~m, _I(0),
                         jnp.where(mode == 0, _I(1),
                                   jnp.where(mode == 1, _I(2), _I(0))))
        oor = _U(table.num_rows)
        vrow = jnp.where(case >= 1, row, oor)
        vnew = jnp.where(case == 1, upd, v0)
        store = table.ops.scatter_values(store, vrow[None], lane[None],
                                         vnew[None])
        krow = jnp.where(case == 2, row, oor)
        kvec = k
        if table.ops.quotient:
            row0 = probing.initial_row(word, table.num_rows, table.seed, True)
            kvec = probing.stored_word(word, table.num_rows, row != row0,
                                       True)[None]
        store = table.ops.scatter_keys(store, krow[None], lane[None],
                                       kvec[None])
        count = count + jnp.where(case == 2, _I(1), _I(0))
        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(mode == 0, _I(STATUS_UPDATED),
                                     jnp.where(mode == 1, _I(STATUS_INSERTED),
                                               _I(STATUS_FULL))))
        return (store, count), status

    (store, count), status = jax.lax.scan(step, (table.store, table.count),
                                          (keys, init, values, words, mask))
    ntable = dataclasses.replace(table, store=store, count=count)
    if stats:
        from repro.obs import metrics
        return ntable, status, metrics.bolt_on_stats(ntable, keys,
                                                     status=status, mask=mask)
    return ntable, status


# ---------------------------------------------------------------------------
# donation-safe jitted entry points (streaming/serving hot paths)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def insert_donated(table: SingleValueHashTable, keys, values, mask=None):
    """``insert`` jitted with the table argument DONATED: XLA aliases the
    store buffers input->output instead of copying a table-sized arena
    per call.  The caller's ``table`` is consumed — rebind the result
    (``table, st = sv.insert_donated(table, ...)``), exactly like a scan
    carry.  One compilation per (geometry, batch shape); used by the
    sustained-traffic serve loop (``serving.serve_loop``) and audited via
    ``launch.hlo_census.input_output_aliases``."""
    return insert(table, keys, values, mask)


@functools.partial(jax.jit, donate_argnums=(0,))
def erase_donated(table: SingleValueHashTable, keys, mask=None):
    """``erase`` with the table donated — see ``insert_donated``."""
    return erase(table, keys, mask)
