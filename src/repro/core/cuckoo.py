"""Bounded cuckoo-eviction rescue for the bucketed two-choice lane.

The ``"bucketed"`` scheme gives every key exactly two candidate buckets;
the bulk-build fixpoint (``core.bulk``) or sequential scan places each
claimer in the first of its two rows with a free lane, exactly like the
other schemes.  Near capacity some claimers find BOTH buckets full and
report FULL even though a short eviction chain would make room — the
cuckoo trade (Compact Parallel Hash Tables, PAPERS.md).  This module adds
that chain as a **vectorized rescue pass** on top of the finished insert:

1. select, per failed claimer, a *victim* — an occupied slot in one of the
   claimer's two buckets whose OWN alternate bucket has a free lane
   (victims are decodable in place: plain stores re-hash the stored key,
   quotient stores read the ``q*2 + choice`` word directly);
2. arbitrate: scatter-min by claimer priority makes victim slots unique,
   then the virtual-fill ranking (``bulk._rank_by_row``) hands each moved
   victim a unique free lane of its target bucket — no two victims, and
   no victim and claimer, ever collide on a slot;
3. move the victims (one batched scatter + tombstone of the vacated
   slots — the vacated slot becomes a TOMBSTONE, never EMPTY, which is
   what keeps stop-at-EMPTY retrieval sound under eviction);
4. re-insert the failed claimers through the table's ordinary insert path
   (no recursion into the rescue), where they claim the fresh tombstones.

The pass repeats ``BUCKETED_MAX_EVICTIONS`` times (python loop — the
bound is static); claimers still FULL after the last round keep the
plain two-choice walk's verdict — the bounded-eviction guard's fallback
to the reference walk.  Every step is one shared vectorized function
driven only by batch order, so the jax, scan and pallas backends remain
bit-exact by construction: they feed the same post-insert state in and
run the identical rescue graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bulk, hashing, probing
from repro.core.common import (
    EMPTY_KEY,
    STATUS_FULL,
    TOMBSTONE_KEY,
)

_U = jnp.uint32
_I = jnp.int32

#: eviction rounds per insert call (static).  Two rounds clear the
#: overwhelming majority of residual FULLs at rho <= 0.95 with W >= 8
#: buckets; the fallback past that is the plain two-choice verdict.
BUCKETED_MAX_EVICTIONS = 2


def _fold_planes(kp_flat):
    """(kw, cap) stored key planes -> (cap,) probe word (hash fold)."""
    kw = kp_flat.shape[0]
    if kw == 1:
        return kp_flat[0]
    word = kp_flat[0]
    for w in range(1, kw):
        word = hashing.combine_planes(kp_flat[w], word)
    return word


def _alt_rows_flat(ops, seed, kp_flat):
    """Per-slot ALTERNATE bucket row, flat (cap,) — garbage on dead slots.

    Plain stores re-derive (b1, b2) from the stored key word; quotient
    stores decode the choice bit and step straight off the stored
    ``q*2 + choice`` word (g is a function of q only, by construction).
    """
    p = ops.num_rows
    cap = ops.arena_capacity
    rows = (jnp.arange(cap, dtype=_U) // _U(ops.window))
    if ops.quotient:
        stored = kp_flat[0]
        q = stored >> _U(1)
        choice = (stored & _U(1)) == _U(1)
        g = hashing.hash_step(q, p, seed)
        return jnp.where(choice, (rows + _U(p) - g) % _U(p),
                         (rows + g) % _U(p))
    word = _fold_planes(kp_flat)
    b1 = hashing.hash_rows(word, p, seed)
    g = hashing.hash_step(word, p, seed)
    b2 = (b1 + g) % _U(p)
    return jnp.where(rows == b1, b2, b1)


def _free_lane_mask(ops, store):
    """(p, W) candidate mask + per-row free count + u32 ballot (W<=32)."""
    kp0 = ops.key_planes(store)[0]
    cand = (kp0 == EMPTY_KEY) | (kp0 == TOMBSTONE_KEY)
    if ops.window <= 32:
        bits = jax.lax.broadcasted_iota(_U, cand.shape, 1)
        cmask = jnp.sum(jnp.where(cand, _U(1) << bits, _U(0)), axis=1)
        n_free = jax.lax.population_count(cmask).astype(_I)
    else:
        cmask = None
        n_free = jnp.sum(cand.astype(_I), axis=1)
    return cand, n_free, cmask


def _nth_lane(cand, cmask, rows, rank, window):
    """rank-th free lane of each row (mirrors ``bulk.place_claims``)."""
    if cmask is not None:
        return bulk._nth_set_lane(cmask[rows], rank, window)
    crow = cand[rows]
    crank = jnp.cumsum(crow.astype(_I), axis=1) - 1
    lanes = jax.lax.broadcasted_iota(_I, crow.shape, 1)
    return jnp.min(jnp.where(crow & (crank == rank[:, None]), lanes,
                             _I(window)), axis=1)


def _one_round(table, keys_n, values_n, live, status, core_insert):
    """One eviction round: move victims, then re-insert failed claimers."""
    ops = table.ops
    p, w = ops.num_rows, ops.window
    n = keys_n.shape[0]
    idx = jnp.arange(n, dtype=_U)
    failed = live & (status == STATUS_FULL)

    kp_flat = ops.key_planes(table.store).reshape(table.key_words,
                                                  ops.arena_capacity)
    alt = _alt_rows_flat(ops, table.seed, kp_flat)
    cand, n_free, cmask = _free_lane_mask(ops, table.store)
    live_slot = ~cand.reshape(-1)
    # a slot is an eligible victim iff occupied and its alternate bucket
    # has at least one free lane to receive it
    eligible = (live_slot & (n_free[alt] > 0)).reshape(p, w)

    from repro.core import single_value as sv
    words = sv.probe_words(table, keys_n)
    c1 = probing.initial_row(words, p, table.seed, ops.quotient)
    g = probing.row_step("bucketed", words, p, table.seed, ops.quotient)
    c2 = (c1 + g) % _U(p)

    elig1, elig2 = eligible[c1], eligible[c2]
    lane1 = probing.vote_lowest(elig1)
    lane2 = probing.vote_lowest(elig2)
    has1, has2 = lane1 < w, lane2 < w
    vrow = jnp.where(has1, c1, c2)
    vlane = jnp.where(has1, lane1, lane2).astype(_U)
    propose = failed & (has1 | has2)

    # victim slots unique: lowest claimer index wins each slot
    cap = ops.arena_capacity
    vslot = jnp.where(propose, vrow.astype(_I) * w + vlane.astype(_I), cap)
    arena = jnp.full((cap + 1,), _U(n), _U).at[vslot].min(idx)
    win = propose & (arena[vslot] == idx)

    # target lanes unique: rank winners per target row, rank-th free lane
    t_row = alt[jnp.clip(vslot, 0, cap - 1)].astype(_U)
    rank = bulk._rank_by_row(t_row, idx, win, p, True)
    moved = win & (rank < n_free[t_row])
    t_lane = _nth_lane(cand, cmask, t_row, rank, w).astype(_U)

    # gather victim key/value words, flip the quotient choice bit
    vk = kp_flat[:, jnp.clip(vslot, 0, cap - 1)].T             # (n, kw)
    if ops.quotient:
        vk = vk ^ _U(1)
    vp_flat = ops.value_planes(table.store).reshape(table.value_words, cap)
    vv = vp_flat[:, jnp.clip(vslot, 0, cap - 1)].T             # (n, vw)

    oor = _U(p)
    mrow = jnp.where(moved, t_row, oor)
    store = ops.scatter_keys(table.store, mrow, t_lane, vk)
    store = ops.scatter_values(store, mrow, t_lane, vv)
    store = ops.scatter_key_word(store, jnp.where(moved, vrow, oor), vlane,
                                 TOMBSTONE_KEY)
    import dataclasses
    table = dataclasses.replace(table, store=store)

    # re-insert the failed claimers through the plain insert (no rescue)
    table, st2 = core_insert(table, keys_n, values_n, failed)
    status = jnp.where(failed, st2, status)
    return table, status


def rescue(table, keys_n, values_n, mask, status, core_insert):
    """Run the bounded eviction rescue; returns (table, status).

    ``core_insert(table, keys, values, mask) -> (table, status)`` must be
    the table kind's plain insert for the table's backend (never the
    rescue-wrapped entry point).  The whole pass is skipped via
    ``lax.cond`` when no element is FULL.
    """
    n = keys_n.shape[0]
    live = jnp.ones((n,), bool) if mask is None else mask
    for _ in range(BUCKETED_MAX_EVICTIONS):
        table, status = jax.lax.cond(
            jnp.any(live & (status == STATUS_FULL)),
            lambda t, s: _one_round(t, keys_n, values_n, live, s,
                                    core_insert),
            lambda t, s: (t, s),
            table, status)
    return table, status
