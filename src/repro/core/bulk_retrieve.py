"""Vectorized bulk-retrieval engine — one fused probe walk per query batch.

The retrieval counterpart of the bulk-build engine (``repro.core.bulk``).
The paper's §IV-B.4 pattern sizes multi-value output with a *counting
pass* and then re-probes to gather — two full walks over the store — and
the scan-reference paths in ``multi_value`` keep exactly that shape.  GPU
hash-table throughput is dominated by passes over the store (cache-line
efficiency — Compact Parallel Hash Tables, 2406.09255), so this module
collapses count + gather into ONE walk:

1. **Dedup front-end** — duplicate probe keys are grouped (the bulk
   engine's sort + ``searchsorted`` fast lane for 1-word keys; for wide
   keys — u64 or composite ``key_words >= 2`` — the stable multi-plane
   lexicographic payload sort, whose group segments are bounded by the
   all-plane adjacent compare, so composite keys differing only in a
   high plane never share a representative) and only one
   *representative* per distinct live key walks the table; results fan
   back out to every duplicate by segment at the end.
2. **Fused walk** — representatives run a single vectorized COPS walk
   that simultaneously accumulates per-query match *counts* and records
   every matching slot in a slot-space *arena*: ``arena[slot] = (query,
   local_rank)`` where ``local_rank`` is the match's position in walk
   order (window by window, lane order within a window — exactly the
   order the reference gather pass emits).  A slot holds one key, and
   representatives are distinct keys, so arena writes never collide.
3. **Compact** — per-query counts produce the output offsets (the
   prefix-sum layout callers already rely on); one batched scatter packs
   the arena into a representative-dense slot list, and one batched
   gather reads ``values[offsets[i] + j]`` straight from the store planes
   through that list.  Duplicate queries replicate their representative's
   segment for free (a gather has no write hazards).

The walk also drives **bulk erase**: the arena's occupied-slot mask IS
the set of slots to tombstone, applied as one dense batched write after
the walk instead of a scatter per probe window (WarpSpeed, 2509.16407,
makes the case that bulk erase belongs in the same engine as bulk build).
Tombstoning after the walk is bit-equivalent to the reference's in-walk
scatters: a tombstone never matches another live query key and never
creates an EMPTY, so no other query's walk can observe the difference.

**The slot-arena contract** (``layouts.StoreOps``): the walk records
matches as FLAT SLOT IDS — ``arena_capacity`` ids, ``arena_values(store,
slots)`` gathers value vectors by id, ``arena_tombstone`` deletes by
occupied-mask.  Any store that renders those three rides this engine's
walk + compaction unchanged: open-addressing layouts expose
``row * window + lane``, the bucket-list table its value pool.

**The revisit-free guard** (``fused_ok``): the arena holds at most one
(query, rank) pair per slot, so the fused gather/erase path requires
walks that never visit a probe row twice — cops/linear with
``max_probes <= num_rows``.  Quadratic or wrapped walks can legitimately
re-emit a slot per visit, semantics only the two-walk reference
produces, so dispatchers fall back to it (counting has no arena and
stays fused regardless).

Everything here is bit-exact against the ``backend="scan"`` reference
paths (the pre-PR while-loop walks kept in ``single_value`` /
``multi_value``): identical values, offsets, counts, found/erased masks,
and post-erase store planes.  ``tests/test_retrieve.py`` asserts this on
adversarial batches (duplicates, masks, tombstone-riddled tables,
``out_capacity`` overflow, u64 keys, empty batches);
``tests/test_composite_keys.py`` extends the matrix to composite
multi-column keys against packed single-word references.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import probing
from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY

_U = jnp.uint32
_I = jnp.int32

UNROLL_PROBES = probing.UNROLL_PROBES


def _tstatic(table):
    """(store protocol, scheme, seed, effective_probes) — the engines'
    static tuple; the budget is coverage-clamped like ``bulk._tstatic``."""
    return (table.ops, table.scheme, table.seed,
            probing.effective_probes(table.scheme, table.max_probes,
                                     table.num_rows))


def fused_ok(table) -> bool:
    """Static predicate: can the slot arena represent this table's walks?

    The arena maps each store slot to at most one (query, rank) pair, so
    the fused gather/erase requires *revisit-free* walks — no probe row
    visited twice.  With every engine's budget clamped to the scheme's
    distinct-row coverage (``probing.effective_probes`` — the
    coverage-clamp bugfix), EVERY scheme's walk is revisit-free by
    construction: cops/linear generate Z_p for the first ``num_rows``
    attempts, quadratic's first (p+1)/2 residues ``l^2 mod p`` are
    distinct, bucketed visits exactly its two buckets.  This predicate
    therefore now always holds; it is kept as the documented eligibility
    switch for future walks that may revisit (e.g. multi-pass or wrapped
    schemes with an un-clampable budget).
    """
    return (probing.effective_probes(table.scheme, table.max_probes,
                                     table.num_rows)
            <= probing.scheme_coverage(table.scheme, table.num_rows))


# ---------------------------------------------------------------------------
# dedup front-end — one representative walk per distinct live key
# ---------------------------------------------------------------------------

def group_queries(keys, live):
    """Group duplicate query keys; returns (is_rep, rep_of) in batch order.

    ``is_rep`` marks the first live occurrence of each distinct live key
    (the element that walks the table); ``rep_of[i]`` is the batch index
    of element i's representative — ``n`` when i's key has no live
    occurrence (only possible for masked elements, which never read it).
    Reuses the bulk-build engine's group machinery: the payload-free sort
    fast lane for 1-word keys, the stable (masked, key words, index) sort
    for wide keys.
    """
    from repro.core import bulk
    n, kw = keys.shape
    if kw == 1:
        is_rep, rep_of, _, _, _ = bulk._group_fast(keys[:, 0], live)
        return is_rep, rep_of.astype(_I)
    flag, skeys, sidx, _ = bulk._sort_batch(keys, live, [])
    live_s, is_rep_s, first_pos, _ = bulk._group_structure(flag, skeys)
    rep_s = jnp.where(live_s, sidx[first_pos].astype(_I), _I(n))
    rep_of = jnp.zeros((n,), _I).at[sidx].set(rep_s)
    is_rep = jnp.zeros((n,), bool).at[sidx].set(is_rep_s)
    return is_rep, rep_of


# ---------------------------------------------------------------------------
# the fused walk — counts + slot arena in a single pass over the store
# ---------------------------------------------------------------------------

def fused_walk(tstatic, store, keys, words, active, *, collect, count=None,
               stats=False):
    """One COPS walk for every active element, emitting counts AND matches.

    Returns ``(cnt, qarena, rank_arena)``: per-element match counts (0 for
    inactive elements), and — when ``collect`` — two flat (capacity,)
    arenas giving, per store slot, the matching element's batch index
    (sentinel ``n`` if none) and the match's walk-order rank within that
    element's result segment.  Stops per element at the first window
    containing EMPTY (the absence frontier; tombstones do not stop the
    walk) or after ``max_probes`` windows, exactly like the reference
    counting/gather walks.  ``count`` (the table's live count) short-cuts
    the walk on an empty store.

    Distinct active keys can never match the same slot, so arena writes
    are collision-free by construction — the retrieval-side analogue of
    the build engine's unique (row, rank) placement invariant.

    ``stats`` (static) additionally carries a per-element probe-length
    counter (windows examined) and returns it as a fourth output; the
    stats-off graph is byte-identical to the three-output walk.
    """
    ops, scheme, seed, max_probes = tstatic
    num_rows, w = ops.num_rows, ops.window
    n = keys.shape[0]
    cap = ops.arena_capacity
    ashape = (cap,) if collect else (1,)
    # pack (query, rank) into one i32 arena when it cannot overflow —
    # halves the per-window scatter traffic on the hot path
    packed = collect and n * cap < 2 ** 31
    row0 = probing.initial_row(words, num_rows, seed, ops.quotient)
    step = probing.row_step(scheme, words, num_rows, seed, ops.quotient)
    qa0 = jnp.full(ashape, _I(-1) if packed else _I(n), _I)
    ra0 = jnp.zeros(ashape if not packed else (1,), _I)
    idx = jnp.arange(n, dtype=_I)

    def empty(_):
        out = (jnp.zeros((n,), _I), qa0, ra0)
        return out + ((jnp.zeros((n,), _I),) if stats else ())

    def walk(_):
        def cond(st):
            attempt, row, done, seen, qa, ra = st[:6]
            return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

        def body(st):
            if stats:
                attempt, row, done, seen, qa, ra, plen = st
                plen = plen + (~done).astype(_I)
            else:
                attempt, row, done, seen, qa, ra = st
            win = ops.key_windows(store, row)
            if ops.quotient:
                tgt = probing.match_word(words, num_rows, attempt,
                                         quotient=True)
                match = (win[:, 0, :] == tgt[:, None]) & ~done[:, None]
            else:
                match = (jnp.all(win == keys[:, :, None], axis=1)
                         & ~done[:, None])
            has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)
            if collect:
                lanes = jax.lax.broadcasted_iota(_I, match.shape, 1)
                slot = row.astype(_I)[:, None] * w + lanes
                slot = jnp.where(match, slot, cap).reshape(-1)
                rank = jnp.cumsum(match.astype(_I), axis=1) - 1
                local = jnp.broadcast_to(seen[:, None] + rank, match.shape)
                qcol = jnp.broadcast_to(idx[:, None], match.shape)
                if packed:
                    qa = qa.at[slot].set(
                        (qcol * cap + local).reshape(-1), mode="drop")
                else:
                    qa = qa.at[slot].set(qcol.reshape(-1), mode="drop")
                    ra = ra.at[slot].set(local.reshape(-1), mode="drop")
            seen = seen + probing.vote_count(match)
            done = done | has_empty
            nrow = probing.advance_row(scheme, row, step, attempt, num_rows)
            out = (attempt + 1, jnp.where(done, row, nrow), done, seen, qa,
                   ra)
            return out + ((plen,) if stats else ())

        st = (jnp.zeros((), _I), row0, ~active, jnp.zeros((n,), _I), qa0, ra0)
        if stats:
            st = st + (jnp.zeros((n,), _I),)
        if max_probes <= UNROLL_PROBES:
            # bucketed walks have a static <= 2-window budget: unroll the
            # attempts so the walk costs the same at every load factor
            # (no early-exit all-done reduction; body is a no-op once an
            # element is done, so the outputs are identical)
            res = st
            for _ in range(max_probes):
                res = body(res)
        else:
            res = jax.lax.while_loop(cond, body, st)
        out = (res[3], res[4], res[5])
        return out + ((res[6],) if stats else ())

    if count is None:
        res = walk(None)
    else:
        res = jax.lax.cond(count == 0, empty, walk, None)
    cnt, qa, ra = res[:3]
    if packed:
        ra = jnp.where(qa >= 0, qa % cap, 0)
        qa = jnp.where(qa >= 0, qa // cap, n)
    if stats:
        return cnt, qa, ra, res[3]
    return cnt, qa, ra


# ---------------------------------------------------------------------------
# compaction — arena + counts -> the paper's (values, offsets, counts)
# ---------------------------------------------------------------------------

def _fan_out(rcnt, rep_of, live, n):
    """Per-query counts from representative counts (masked queries -> 0)."""
    safe = jnp.clip(rep_of, 0, max(n - 1, 0))
    return jnp.where(live, rcnt[safe], 0)


def rep_offsets(is_rep, rcnt):
    """Representative-dense base offsets, in batch order of representatives:
    ``rep_base[i]`` is where representative i's value segment starts in the
    dense slot list (garbage for non-representatives, never read)."""
    repc = jnp.where(is_rep, rcnt, 0)
    return jnp.cumsum(repc) - repc


def dense_capacity(cap, out_capacity) -> int:
    """Size of the representative-dense slot list.

    ``min(cap, out_capacity)`` suffices: every dense position a valid
    output element reads satisfies ``gpos <= j < out_capacity`` (each
    representative counted in ``rep_base`` has its first occurrence — and
    hence at least one full segment — before any query that reads it), and
    is also ``< cap`` (one slot per distinct stored value).  Writes past
    the truncation drop; truncated reads are zeroed by the valid mask.
    """
    return min(cap, max(int(out_capacity), 1))


def _emit(arena_values, cap, out_capacity, counts, is_rep, rep_of, rcnt,
          qarena, rank_arena):
    """Pack the walk's arena into the prefix-sum output layout.

    One scatter orders matched slots representative-dense (walk order
    within each representative), one gather fans the slot values out into
    every query's segment.  Entries past each segment — and everything
    past the true total when ``out_capacity`` truncates — stay zero,
    matching the reference's drop-scatter semantics bit for bit.

    The dense list is ``dense_capacity``-sized, NOT arena-sized: the
    scatter still reads the (cap,) arena once, but its target (and the
    whole downstream gather chain) shrinks to the output's own scale —
    the fix for pool-heavy stores whose arena dwarfs the batch.

    ``arena_values`` is the store's slot-arena hook (``slots -> (m, vw)``,
    cf. ``layouts.StoreOps.arena_values``) and ``cap`` its capacity: the
    open-addressing tables expose row*W+lane slot ids, the bucket-list
    table its value pool — either store shape rides this one compaction.
    """
    n = rep_of.shape[0]
    rep_base = rep_offsets(is_rep, rcnt)
    dcap = dense_capacity(cap, out_capacity)
    okslot = qarena < n
    safe_q = jnp.clip(qarena, 0, max(n - 1, 0))
    pos = jnp.where(okslot, rep_base[safe_q] + rank_arena, dcap)
    rep_dense = jnp.zeros((dcap,), _I).at[pos].set(
        jnp.arange(cap, dtype=_I), mode="drop")
    return _emit_dense(arena_values, cap, out_capacity, counts, rep_of,
                       rep_base, rep_dense)


def _emit_dense(arena_values, cap, out_capacity, counts, rep_of, rep_base,
                rep_dense):
    """Gather half of ``_emit``: fan a representative-dense slot list out
    into every query's prefix-sum segment.  ``rep_dense`` holds flat slot
    ids at ``rep_base[rep] + rank`` (walk order) — built either by
    ``_emit``'s arena scatter or stamped directly by a walk that knows its
    ranks up front (``bucket_list.chain_arena`` dense mode)."""
    n = rep_of.shape[0]
    dcap = rep_dense.shape[0]
    offsets = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts)])
    j = jnp.arange(out_capacity, dtype=_I)
    seg = jnp.searchsorted(offsets[1:], j, side="right").astype(_I)
    segc = jnp.clip(seg, 0, max(n - 1, 0))
    local = j - offsets[segc]
    valid = j < offsets[n]
    gpos = jnp.clip(rep_base[jnp.clip(rep_of[segc], 0, max(n - 1, 0))] + local,
                    0, dcap - 1)
    slot = jnp.clip(rep_dense[gpos], 0, cap - 1)
    svals = arena_values(slot)                              # (out_capacity, vw)
    out = jnp.where(valid[:, None], svals, 0)
    return out, offsets, counts


def _emit_store(table, out_capacity, counts, is_rep, rep_of, rcnt, qarena,
                rank_arena):
    """_emit over an open-addressing table's own slot arena."""
    return _emit(lambda s: table.ops.arena_values(table.store, s),
                 table.ops.arena_capacity, out_capacity, counts, is_rep,
                 rep_of, rcnt, qarena, rank_arena)


# ---------------------------------------------------------------------------
# multi-value entry points
# ---------------------------------------------------------------------------

def _retrieval_stats(table, plen=None, active=None):
    """TableStats for a pure retrieval walk (no statuses, no fixpoint)."""
    from repro.obs import metrics
    return metrics.table_stats(table.ops, table.store, plen=plen,
                               active=active)


def count_multi(table, keys, mask=None, stats=False):
    """Fused path for ``multi_value.count_values`` (dedup + one walk)."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        out = jnp.zeros((0,), _I)
        return (out, _retrieval_stats(table)) if stats else out
    live = jnp.ones((n,), bool) if mask is None else mask
    is_rep, rep_of = group_queries(keys, live)
    words = sv.probe_words(table, keys)
    fw = fused_walk(_tstatic(table), table.store, keys, words, is_rep,
                    collect=False, count=table.count, stats=stats)
    counts = _fan_out(fw[0], rep_of, live, n)
    if stats:
        return counts, _retrieval_stats(table, plen=fw[3], active=is_rep)
    return counts


def retrieve_all_multi(table, keys, out_capacity, mask=None, stats=False):
    """Fused path for ``multi_value.retrieve_all``: the single-walk
    count+gather this engine exists for."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    vw = table.value_words
    if n == 0:
        out = jnp.zeros((out_capacity, vw), _U)
        res = ((out[:, 0] if vw == 1 else out), jnp.zeros((1,), _I),
               jnp.zeros((0,), _I))
        return res + ((_retrieval_stats(table),) if stats else ())
    live = jnp.ones((n,), bool) if mask is None else mask
    is_rep, rep_of = group_queries(keys, live)
    words = sv.probe_words(table, keys)
    fw = fused_walk(
        _tstatic(table), table.store, keys, words, is_rep, collect=True,
        count=table.count, stats=stats)
    rcnt, qarena, rank_arena = fw[:3]
    counts = _fan_out(rcnt, rep_of, live, n)
    out, offsets, counts = _emit_store(table, out_capacity, counts, is_rep,
                                       rep_of, rcnt, qarena, rank_arena)
    res = ((out[:, 0] if vw == 1 else out), offsets, counts)
    if stats:
        return res + (_retrieval_stats(table, plen=fw[3], active=is_rep),)
    return res


def erase_multi(table, keys):
    """Fused path for ``multi_value.erase``: the walk's occupied-arena mask
    drives one dense batched tombstone write."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        return table, jnp.zeros((0,), _I)
    live = jnp.ones((n,), bool)
    is_rep, rep_of = group_queries(keys, live)
    words = sv.probe_words(table, keys)
    rcnt, qarena, _ = fused_walk(_tstatic(table), table.store, keys, words,
                                 is_rep, collect=True, count=table.count)
    store = table.ops.arena_tombstone(table.store, qarena < n)
    counts = _fan_out(rcnt, rep_of, live, n)
    erased = jnp.sum(jnp.where(is_rep, rcnt, 0), dtype=_I)
    return dataclasses.replace(table, store=store,
                               count=table.count - erased), counts


# ---------------------------------------------------------------------------
# single-value entry points (dedup + one located walk, shared with erase)
# ---------------------------------------------------------------------------

def _locate_reps(table, keys, stats=False):
    from repro.core import bulk
    from repro.core import single_value as sv
    n = keys.shape[0]
    live = jnp.ones((n,), bool)
    is_rep, rep_of = group_queries(keys, live)
    words = sv.probe_words(table, keys)
    pm = bulk.probe_matches(
        _tstatic(table), table.store, keys, words, is_rep, table.count,
        stats=stats)
    matched, mrow, mlane = pm[:3]
    out = (is_rep, rep_of, matched, mrow, mlane)
    return out + ((pm[3],) if stats else ())


def retrieve_single(table, keys, stats=False):
    """Fused path for ``single_value.retrieve``: duplicate probe keys walk
    once; duplicates read their representative's slot."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    vw = table.value_words
    if n == 0:
        vals = jnp.zeros((0, vw), _U)
        res = ((vals[:, 0] if vw == 1 else vals), jnp.zeros((0,), bool))
        return res + ((_retrieval_stats(table),) if stats else ())
    lr = _locate_reps(table, keys, stats=stats)
    is_rep, rep_of, matched, mrow, mlane = lr[:5]
    vp = table.value_planes()                                 # (vw, p, W)
    rvals = vp[:, mrow, mlane].T                              # (n, vw)
    found = matched[rep_of]
    vals = jnp.where(found[:, None], rvals[rep_of], 0)
    res = ((vals[:, 0] if vw == 1 else vals), found)
    if stats:
        return res + (_retrieval_stats(table, plen=lr[5], active=is_rep),)
    return res


def contains_single(table, keys):
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    if keys.shape[0] == 0:
        return jnp.zeros((0,), bool)
    _, rep_of, matched, _, _ = _locate_reps(table, keys)
    return matched[rep_of]


def erase_single(table, keys, mask=None):
    """Fused path for ``single_value.erase``: one representative walk, one
    batched tombstone scatter, count delta from the group structure (no
    separate distinct-count sort)."""
    from repro.core import bulk
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        return table, jnp.zeros((0,), bool)
    live = jnp.ones((n,), bool) if mask is None else mask
    is_rep, rep_of = group_queries(keys, live)
    words = sv.probe_words(table, keys)
    matched, mrow, mlane = bulk.probe_matches(
        _tstatic(table), table.store, keys, words, is_rep, table.count)
    hit = is_rep & matched
    srows = jnp.where(hit, mrow, _U(table.num_rows))
    store = table.ops.scatter_key_word(table.store, srows, mlane,
                                       TOMBSTONE_KEY)
    safe = jnp.clip(rep_of, 0, max(n - 1, 0))
    erased = live & matched[safe] & (rep_of < n)
    count = table.count - jnp.sum(hit, dtype=_I)
    return dataclasses.replace(table, store=store, count=count), erased
