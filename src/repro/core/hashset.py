"""HashSet — keys only, each stored once (paper §IV: "stores set of keys").

A thin wrapper over SingleValueHashTable with zero value words: the layout
machinery handles value_words == 0 (empty value planes), so probing/insert/
erase are shared verbatim — including composite multi-word keys (pass
``key_words=N`` at ``create`` and feed tuples of u32 columns or (n, N)
plane arrays; see ``single_value.normalize_keys``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import single_value as sv
from repro.core.common import DEFAULT_SEED, DEFAULT_WINDOW, STATUS_INSERTED

HashSet = sv.SingleValueHashTable


def create(min_capacity: int, *, key_words: int = 1, window: int = DEFAULT_WINDOW,
           scheme: str = "cops", layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax") -> HashSet:
    if layout == "packed":
        raise ValueError("packed layout needs a value word; use soa/aos for HashSet")
    return sv.create(min_capacity, key_words=key_words, value_words=0,
                     window=window, scheme=scheme, layout=layout, seed=seed,
                     max_probes=max_probes, backend=backend)


def add(hs: HashSet, keys, mask=None) -> tuple[HashSet, jax.Array]:
    """Insert keys; returns (set, newly_added mask)."""
    keys_n = sv.normalize_key_batch(keys, hs.key_words, "keys")
    vals = jnp.zeros((keys_n.shape[0], 0), jnp.uint32)
    hs, status = sv.insert(hs, keys_n, vals, mask)
    return hs, status == STATUS_INSERTED


def contains(hs: HashSet, keys) -> jax.Array:
    return sv.contains(hs, keys)


def remove(hs: HashSet, keys, mask=None) -> tuple[HashSet, jax.Array]:
    return sv.erase(hs, keys, mask)


def size(hs: HashSet) -> jax.Array:
    return hs.count
