"""BloomFilter — blocked bloom filter, set-membership with false positives.

Blocked design (one cache-line-sized block per key, k bits inside it): the
GPU rationale — one memory transaction per op — maps directly to the TPU,
where the block is one vector-aligned row.  Insertion is naturally
*order-free* (bit-OR is commutative/idempotent), so unlike the hash tables
it needs no serialization and both ops are fully vectorized across the
batch.

The pure-JAX state is one byte per bit, shaped (num_blocks, block_bits) —
scatter-max implements OR.  ``pack_words``/``unpack_words`` convert to the
dense u32-word representation used by the Pallas kernel and by size
accounting.

**Staleness after erase (the filter contract).**  A bloom filter cannot
delete: bits are shared between keys, so clearing on erase would create
false *negatives* for the surviving keys that set the same bits.  The
contract is therefore one-sided: a key inserted into the filter is
``contains=True`` forever-until-rebuild (no false negatives, ever), and
erasing from the backing table leaves the filter *permissive* — the dead
key keeps advertising until :func:`rebuild_from_table` resweeps the live
set, which the compaction hook (``serving.elastic.compact_all``) and the
growth path do.  Between rebuilds, fill fraction only grows and stale
positives only cost a wasted probe, never a wrong answer.  The sharded
lookup front-end (``serving/elastic.py``, ``core/distributed.py``)
depends on exactly this: a filter miss is *proof of absence* and the
cross-shard probe can be skipped; a filter hit is merely a hint.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.common import next_prime, register_struct, static_field

_U = jnp.uint32
_I = jnp.int32


@register_struct
@dataclasses.dataclass
class BloomFilter:
    bits: jax.Array                       # (num_blocks, block_bits) u8 in {0,1}
    num_blocks: int = static_field()
    block_bits: int = static_field()
    k: int = static_field()
    seed: int = static_field()

    @property
    def num_bits(self) -> int:
        return self.num_blocks * self.block_bits


def create(num_bits: int, *, k: int = 4, block_bits: int = 512,
           seed: int = 0x9E3779B9) -> BloomFilter:
    num_blocks = next_prime(max(1, num_bits // block_bits))
    return BloomFilter(bits=jnp.zeros((num_blocks, block_bits), jnp.uint8),
                       num_blocks=num_blocks, block_bits=block_bits, k=k, seed=seed)


def _positions(f: BloomFilter, keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(block_idx (n,), bit_idx (n, k)) for each key."""
    keys = keys.astype(_U)
    block = hashing.mix_murmur3(keys ^ _U(f.seed)) % _U(f.num_blocks)
    hs = []
    h = hashing.mix_xxhash(keys ^ _U(f.seed))
    g = hashing.mix_murmur3(keys + _U(0x61C88647))
    for i in range(f.k):
        # Kirsch–Mitzenmacher double hashing for the k probe bits
        hs.append((h + _U(i) * g) % _U(f.block_bits))
    return block, jnp.stack(hs, axis=-1)


def insert(f: BloomFilter, keys, mask=None) -> BloomFilter:
    keys = jnp.asarray(keys)
    block, bitpos = _positions(f, keys)
    if mask is not None:
        block = jnp.where(mask, block, _U(f.num_blocks))      # OOR drop
    rows = jnp.broadcast_to(block[:, None], bitpos.shape).reshape(-1)
    cols = bitpos.reshape(-1)
    bits = f.bits.at[rows, cols].max(jnp.uint8(1), mode="drop")
    return dataclasses.replace(f, bits=bits)


def contains(f: BloomFilter, keys) -> jax.Array:
    """Membership query — false positives possible, false negatives never."""
    keys = jnp.asarray(keys)
    block, bitpos = _positions(f, keys)
    rows = jnp.broadcast_to(block[:, None], bitpos.shape)
    got = f.bits[rows, bitpos]
    return jnp.all(got == 1, axis=-1)


def contains_stack(proto: BloomFilter, bits_stack: jax.Array,
                   owners: jax.Array, keys) -> jax.Array:
    """Membership of each key in its *owner's* filter, over stacked bits.

    ``bits_stack`` is ``(P, num_blocks, block_bits)`` — one filter plane
    per shard, all sharing ``proto``'s geometry (k/seed/block_bits) —
    and ``owners (n,)`` names which plane answers each key.  This is the
    sharded-lookup admission test: one gather per key against the
    all-gathered (or host-stacked) filter planes, no all_to_all needed
    to decide.  Same one-sided guarantee as :func:`contains`.
    """
    keys = jnp.asarray(keys)
    block, bitpos = _positions(proto, keys)
    rows = jnp.broadcast_to(block[:, None], bitpos.shape)
    plane = jnp.broadcast_to(jnp.asarray(owners)[:, None], bitpos.shape)
    got = bits_stack[plane, rows, bitpos]
    return jnp.all(got == 1, axis=-1)


def rebuild_from_table(f: BloomFilter, table) -> BloomFilter:
    """Fresh filter (same geometry as ``f``) advertising exactly the
    table's live keys.

    This is the compaction/growth hook closing the staleness loop (see
    the module docstring): the incremental filter only ever gains bits,
    so after heavy erase churn it advertises long-dead keys; a rebuild
    sweeps the live set (``migrate.live_entries`` — quotient geometries
    decode through the same path migration uses) and re-inserts the
    *folded key word* (``sv.key_hash_word``), which is also what the
    incremental insert path feeds the filter — so a rebuilt filter is a
    subset of the incremental one, never missing a live key.
    """
    from repro.core import migrate
    from repro.core import single_value as sv
    keys, _, live = migrate.live_entries(table)
    words = sv.key_hash_word(keys)
    fresh = dataclasses.replace(f, bits=jnp.zeros_like(f.bits))
    return insert(fresh, words, mask=live)


def fill_fraction(f: BloomFilter) -> jax.Array:
    return jnp.mean(f.bits.astype(jnp.float32))


def pack_words(f: BloomFilter) -> jax.Array:
    """Dense (num_blocks, block_bits // 32) u32 word representation."""
    b = f.bits.reshape(f.num_blocks, f.block_bits // 32, 32).astype(_U)
    shifts = jnp.arange(32, dtype=_U)
    return jnp.sum(b << shifts[None, None, :], axis=-1, dtype=_U)


def unpack_words(words: jax.Array, block_bits: int, k: int, seed: int) -> BloomFilter:
    num_blocks = words.shape[0]
    shifts = jnp.arange(32, dtype=_U)
    bits = ((words[:, :, None] >> shifts[None, None, :]) & _U(1)).astype(jnp.uint8)
    return BloomFilter(bits=bits.reshape(num_blocks, block_bits),
                       num_blocks=num_blocks, block_bits=block_bits, k=k, seed=seed)
