"""Checkpoint/restore of live table state — versioned, checksummed, bit-exact.

A long-lived table serving traffic must survive a process restart, and an
elastic deployment must be able to re-partition saved state onto a
different shard count (``repro.serving.elastic``).  This module is the
storage layer both rely on: every table kind in the library —
single-value (including counting), multi-value, bucket-list; plain COPS
*and* the bucketed / quotient (``bucketedq``) geometries — serializes its
store planes, allocator metadata and full static config into one
self-describing byte blob, and ``restore`` reconstructs a **bit-exact**
table: same probe geometry (the statics are stored verbatim, not
re-derived), same slot census, same store-plane bytes.

Format (version |SNAPSHOT_VERSION|)::

    WCSNAP1\\n                      # magic line
    {json header}\\n                # version, kind, config, array manifest,
                                    # payload_nbytes, payload_sha256
    <payload>                       # concatenated C-order array bytes

The header's manifest records every array's name (a ``/``-joined pytree
path such as ``store/keys`` or ``key_store/store/values``), dtype, shape
and byte offset.  The sha256 of the payload makes torn writes loud: a
truncated or corrupted snapshot raises :class:`SnapshotError` with a
clear diagnosis — it can never restore into a silently wrong table.
Static tuples (bucket-list ``sizes``/``cum``) survive the JSON round
trip via a recursive list->tuple coercion on restore.

``save``/``load`` add the file layer (writes are atomic: temp file +
``os.replace``, so a crash mid-write leaves the previous snapshot
intact).  :class:`SnapshotWriter` is the **async double-buffered
writer**: ``save`` synchronously copies the table to host memory (so the
caller may immediately donate/mutate its device buffers, exactly like
levanter's async checkpointer) and hands serialization + hashing + disk
I/O to a background thread.  At most one write is in flight and one is
queued; a newer queued save *replaces* the older one (latest wins — the
double buffer), so a serve loop can checkpoint at high frequency without
ever blocking on the disk.

Registry counters (``obs.registry.REGISTRY``): ``snapshot.saves``,
``snapshot.restores``, ``snapshot.bytes_written``,
``snapshot.saves_superseded``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.registry import REGISTRY

SNAPSHOT_VERSION = 1
MAGIC = b"WCSNAP1\n"

#: dtypes a snapshot may carry (closed set: restore never eval()s a dtype)
_DTYPES = {"uint32": np.uint32, "int32": np.int32, "uint8": np.uint8,
           "float32": np.float32, "bool": np.bool_}


class SnapshotError(ValueError):
    """A snapshot failed validation (torn write, corruption, bad version).

    Raised for *any* payload that cannot be proven intact — restoring a
    damaged snapshot must be loud, never a silently wrong table.
    """


def _table_kinds():
    """kind name -> table class (deferred import: sv/mv/bl import chains)."""
    from repro.core import bucket_list as bl
    from repro.core import multi_value as mv
    from repro.core import single_value as sv
    return {"single_value": sv.SingleValueHashTable,
            "multi_value": mv.MultiValueHashTable,
            "bucket_list": bl.BucketListHashTable}


def kind_of(table) -> str:
    """The snapshot kind string of a table (CountingHashTable is the
    single-value class, so it snapshots as ``single_value``)."""
    for name, cls in _table_kinds().items():
        if type(table) is cls:
            return name
    raise TypeError(f"cannot snapshot object of type {type(table).__name__}; "
                    f"supported: {sorted(_table_kinds())}")


# ---------------------------------------------------------------------------
# flatten / rebuild
# ---------------------------------------------------------------------------

def _jsonable_static(v):
    if isinstance(v, tuple):
        return [_jsonable_static(x) for x in v]
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    raise TypeError(f"static field value {v!r} is not JSON-serializable")


def _tupled_static(v):
    """Inverse of ``_jsonable_static``: JSON lists back to tuples (no table
    static field is legitimately a list, so this is unambiguous)."""
    if isinstance(v, list):
        return tuple(_tupled_static(x) for x in v)
    return v


def _collect(obj, prefix: str, arrays: list):
    """Flatten a table dataclass into (config-node, arrays) where the
    config node is JSON-able and ``arrays`` gains (name, np.ndarray)."""
    cfg = {"kind": kind_of(obj), "static": {}, "nested": {}}
    for f in dataclasses.fields(type(obj)):
        v = getattr(obj, f.name)
        name = prefix + f.name
        if f.metadata.get("static"):
            cfg["static"][f.name] = _jsonable_static(v)
        elif dataclasses.is_dataclass(v):
            cfg["nested"][f.name] = _collect(v, name + "/", arrays)
        elif isinstance(v, dict):
            for k in sorted(v):
                arrays.append((f"{name}/{k}", np.asarray(v[k])))
        else:
            arrays.append((name, np.asarray(v)))
    return cfg


def _rebuild(cfg: dict, arrays: dict, prefix: str):
    kinds = _table_kinds()
    if cfg.get("kind") not in kinds:
        raise SnapshotError(f"unknown table kind {cfg.get('kind')!r} "
                            f"(supported: {sorted(kinds)})")
    cls = kinds[cfg["kind"]]
    kwargs = {}
    for f in dataclasses.fields(cls):
        name = prefix + f.name
        if f.metadata.get("static"):
            if f.name not in cfg["static"]:
                raise SnapshotError(f"snapshot header missing static field "
                                    f"{f.name!r} of {cfg['kind']}")
            kwargs[f.name] = _tupled_static(cfg["static"][f.name])
        elif f.name in cfg["nested"]:
            kwargs[f.name] = _rebuild(cfg["nested"][f.name], arrays,
                                      name + "/")
        elif name in arrays:
            kwargs[f.name] = jnp.asarray(arrays[name])
        else:
            sub = {k[len(name) + 1:]: jnp.asarray(a)
                   for k, a in arrays.items() if k.startswith(name + "/")}
            if not sub:
                raise SnapshotError(f"snapshot payload missing arrays for "
                                    f"field {name!r}")
            kwargs[f.name] = sub
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# bytes codec
# ---------------------------------------------------------------------------

def snapshot_bytes(table) -> bytes:
    """Serialize a table to the versioned snapshot byte format."""
    arrays: list = []
    cfg = _collect(table, "", arrays)
    manifest, chunks, offset = [], [], 0
    for name, arr in arrays:
        if arr.dtype.name not in _DTYPES:
            raise TypeError(f"array {name!r} has unsupported dtype "
                            f"{arr.dtype.name}")
        raw = np.ascontiguousarray(arr).tobytes()
        manifest.append({"name": name, "dtype": arr.dtype.name,
                         "shape": list(arr.shape), "offset": offset})
        chunks.append(raw)
        offset += len(raw)
    payload = b"".join(chunks)
    header = {"version": SNAPSHOT_VERSION, "kind": cfg["kind"], "config": cfg,
              "arrays": manifest, "payload_nbytes": len(payload),
              "payload_sha256": hashlib.sha256(payload).hexdigest()}
    return MAGIC + json.dumps(header).encode() + b"\n" + payload


def _parse(data: bytes) -> tuple[dict, bytes]:
    """Validate the blob end to end; raises SnapshotError on any damage."""
    if not data.startswith(MAGIC):
        raise SnapshotError(
            "not a warpcore snapshot (bad magic; expected a file written by "
            "repro.core.snapshot)")
    nl = data.find(b"\n", len(MAGIC))
    if nl < 0:
        raise SnapshotError("torn snapshot: truncated inside the header "
                            "(no header terminator) — refusing to restore")
    try:
        header = json.loads(data[len(MAGIC):nl].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise SnapshotError(f"corrupted snapshot header ({e}) — refusing "
                            "to restore") from e
    if header.get("version") != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"unsupported snapshot version {header.get('version')!r} "
            f"(this build reads version {SNAPSHOT_VERSION})")
    payload = data[nl + 1:]
    want = header.get("payload_nbytes")
    if len(payload) != want:
        raise SnapshotError(
            f"torn snapshot: payload is {len(payload)} bytes, header "
            f"promises {want} — truncated or over-long write, refusing to "
            "restore")
    digest = hashlib.sha256(payload).hexdigest()
    if digest != header.get("payload_sha256"):
        raise SnapshotError(
            "corrupted snapshot: payload sha256 mismatch (bit rot or torn "
            "concurrent write) — refusing to restore a silently wrong table")
    return header, payload


def restore_bytes(data: bytes):
    """Rebuild the bit-exact table from ``snapshot_bytes`` output.

    Every validation failure raises :class:`SnapshotError`; a successful
    restore reproduces the snapshotted table exactly — same statics (probe
    geometry included), same store planes, same counts.
    """
    header, payload = _parse(data)
    arrays = {}
    for ent in header["arrays"]:
        dt = _DTYPES.get(ent["dtype"])
        if dt is None:
            raise SnapshotError(f"snapshot array {ent['name']!r} has "
                                f"unsupported dtype {ent['dtype']!r}")
        shape = tuple(ent["shape"])
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
        off = ent["offset"]
        if off + nbytes > len(payload):
            raise SnapshotError(f"torn snapshot: array {ent['name']!r} "
                                "extends past the payload")
        arrays[ent["name"]] = np.frombuffer(
            payload[off:off + nbytes], dtype=dt).reshape(shape)
    table = _rebuild(header["config"], arrays, "")
    REGISTRY.counter("snapshot.restores").inc(1)
    return table


# ---------------------------------------------------------------------------
# file layer (atomic) + async double-buffered writer
# ---------------------------------------------------------------------------

def _atomic_write(path: str, data: bytes) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(table, path: str) -> int:
    """Snapshot ``table`` to ``path`` atomically; returns bytes written."""
    data = snapshot_bytes(table)
    _atomic_write(path, data)
    REGISTRY.counter("snapshot.saves").inc(1)
    REGISTRY.counter("snapshot.bytes_written").inc(len(data))
    return len(data)


def load(path: str):
    """Restore a table from a snapshot file (see ``restore_bytes``)."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError as e:
        raise SnapshotError(
            f"missing snapshot file {path!r} — torn multi-file checkpoint "
            "or wrong directory") from e
    return restore_bytes(data)


class SnapshotWriter:
    """Async double-buffered snapshot writer.

    ``save(table, path)`` copies the table to host memory *synchronously*
    (cheap; after it returns the caller may donate/overwrite the device
    buffers) and queues serialization + disk I/O on a background thread.
    One write is in flight and at most one is queued *per destination
    path*; queueing a newer save for the same path supersedes the queued
    one — the serve loop can call ``save`` every step and the disk sees
    only the freshest state it can keep up with, while a multi-file
    checkpoint (one snapshot per shard, ``serving.elastic.save``) keeps
    every distinct file.  Writes themselves are atomic (temp + rename),
    so a crash between saves always leaves the last *completed*
    snapshot readable.

    ``flush()`` blocks until everything queued has hit the disk and
    re-raises any background failure; ``close()`` flushes and stops the
    thread.  Usable as a context manager.
    """

    def __init__(self):
        self._cv = threading.Condition()
        self._queued: dict = {}             # path -> host-copied table
        self._busy = False
        self._stop = False
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="snapshot-writer")
        self._thread.start()

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._queued and not self._stop:
                    self._cv.wait()
                if not self._queued and self._stop:
                    return
                path = next(iter(self._queued))   # FIFO by insertion order
                table = self._queued.pop(path)
                self._busy = True
            try:
                save(table, path)
            except BaseException as e:          # surfaced on flush/close
                with self._cv:
                    self._error = e
            finally:
                with self._cv:
                    self._busy = False
                    self._cv.notify_all()

    def save(self, table, path: str) -> None:
        """Queue an async snapshot of ``table`` (host copy taken now)."""
        host = jax.device_get(table)
        with self._cv:
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            if self._stop:
                raise RuntimeError("SnapshotWriter is closed")
            if path in self._queued:
                REGISTRY.counter("snapshot.saves_superseded").inc(1)
                del self._queued[path]        # re-insert at FIFO tail
            self._queued[path] = host
            self._cv.notify_all()

    def flush(self) -> None:
        """Block until all queued writes are durable; re-raise failures."""
        with self._cv:
            while self._queued or self._busy:
                self._cv.wait()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def close(self) -> None:
        self.flush()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=60)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
