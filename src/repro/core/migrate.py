"""Online table growth + tombstone compaction (the WarpSpeed gap).

The paper's tables — and every table in this library until now — are
fixed-capacity at construction: a long-running consumer degrades as
tombstones accumulate (probe walks no longer stop early) and hard-fails
with ``STATUS_FULL`` once traffic outgrows the initial sizing.  WarpSpeed
(PAPERS.md) names exactly this functionality gap in WarpCore-class
tables.  This module closes it with a **bulk migration engine** plus an
**auto-growth policy layer**:

- ``grow(table, new_capacity)`` / ``compact(table)`` sweep every live
  slot out of the old store (tombstones dropped) and re-insert them into
  a fresh store through the existing bulk-build engine — the sort/dedup
  front-end in ``core.bulk`` is already the rehash inner loop, so
  migration is one arena sweep plus one bulk insert, bit-exact on the
  live key/value set.  All three table kinds are covered: single-value
  and multi-value via the open-addressing arena, bucket-list via the
  chain-as-arena walk (``bucket_list.chain_arena``), which also repacks
  the value pool dense (``compact`` reclaims tail-bucket slack and
  abandoned chains).
- ``GrowthPolicy`` captures the when: load-factor threshold,
  tombstone-density threshold, growth factor, max-capacity cap.
- ``insert_or_grow(...)`` is the host-side wrapper consumers call on
  their insert path: it migrates *before* inserting when the policy says
  the batch won't fit cleanly, and retries any ``STATUS_FULL`` /
  ``STATUS_POOL_FULL`` residue after an emergency grow, so insertion
  failure becomes a recoverable event instead of silent data loss.

Policy decisions are recorded to ``obs.registry.REGISTRY``
(``table.grows``, ``table.compactions``, ``table.migrated_slots``) — the
same host-side registry the serving loop already reads.

**Host-side by design.**  Growth changes array shapes, which jit cannot
do mid-graph: the policy reads concrete occupancy numbers and the retry
loop is a Python loop.  ``insert_or_grow`` therefore runs eagerly; when
called under ``jit`` (its inputs are tracers) it degrades gracefully to
the plain insert — the policy is a static *flag* on the consumer, not a
traced branch.  See docs/GROWTH.md for the cost model (a migration is
O(capacity) — amortized O(1) per insert under geometric growth) and for
when compaction beats growth.
"""

from __future__ import annotations

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp

from repro.core import bucket_list as bl
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_POOL_FULL,
    TOMBSTONE_KEY,
)
from repro.obs.registry import REGISTRY

_U = jnp.uint32
_I = jnp.int32


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GrowthPolicy:
    """When to migrate, and by how much.

    Frozen (hashable), so a policy can ride as a *static* pytree field on
    a consumer (e.g. ``serving.PagedKVCache.policy``) — two caches with
    different policies compile separately, and ``policy=None`` consumers
    keep the exact pre-policy graph.

    - ``max_load_factor``: grow when (live + incoming) / capacity would
      exceed this.  COPS probe walks degenerate near-full (fig9), so the
      default leaves headroom well before the hard ceiling.
    - ``max_tombstone_density``: compact when tombstones / capacity
      exceeds this.  Tombstones don't stop probe walks, so density is
      pure probe-length tax — compaction reclaims the slots without
      paying for a larger store.
    - ``growth_factor``: capacity multiplier per grow (geometric growth
      keeps total migration work amortized O(1) per insert).
    - ``max_capacity``: hard cap; at the cap the policy compacts if it
      can and otherwise lets ``STATUS_FULL`` surface to the caller.
    """
    max_load_factor: float = 0.85
    max_tombstone_density: float = 0.25
    growth_factor: float = 2.0
    max_capacity: int = 1 << 24


DEFAULT_POLICY = GrowthPolicy()


def _host_int(x):
    """int(x) for concrete (host-readable) values, None under tracing."""
    if isinstance(x, jax.core.Tracer):
        return None
    try:
        return int(x)
    except (TypeError, ValueError):
        return None


#: one-shot latch for the traced-degradation warning below (warn once per
#: process; the registry counter keeps the full tally)
_warned_traced_skip = False


def _note_traced_skip() -> None:
    """Record that the growth policy was silently skipped under tracing.

    ``insert_or_grow`` inside jit degrades to a plain insert (shapes are
    frozen mid-graph, so no migration can run) — previously this was
    completely silent and a jitted consumer could see STATUS_FULL while
    believing auto-growth protected it.  Every skip now increments the
    ``table.growth_skipped_traced`` registry counter, and the first skip
    per process raises a host-side warning.  See docs/GROWTH.md.
    """
    global _warned_traced_skip
    REGISTRY.counter("table.growth_skipped_traced").inc(1)
    if not _warned_traced_skip:
        _warned_traced_skip = True
        warnings.warn(
            "insert_or_grow called under jit/tracing: the auto-growth "
            "policy is host-side and was skipped, so this call degrades "
            "to a plain insert and may report STATUS_FULL. Call "
            "insert_or_grow eagerly (outside jit) to keep growth active; "
            "see docs/GROWTH.md and the table.growth_skipped_traced "
            "counter.", RuntimeWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# arena sweeps — (keys, values, live) of every slot, tombstones dropped
# ---------------------------------------------------------------------------

def _sweep_oa(table):
    """Live-slot sweep of an open-addressing store.

    Returns (keys (c, kw), values (c, vw), live (c,)) over the slot
    arena; masked-out slots are zeroed so the batch is sentinel-free.
    """
    ops = table.ops
    kp = ops.key_planes(table.store).reshape(table.key_words, -1).T
    vp = ops.value_planes(table.store).reshape(table.value_words, -1).T
    live = (kp[:, 0] != EMPTY_KEY) & (kp[:, 0] != TOMBSTONE_KEY)
    if ops.quotient:
        # quotient slots hold q*2 + choice, not the key: decode through
        # the slot's row (h = q*p + b1, key = unmix(h) ^ seed — exact,
        # the mixer is a bijection).  Decoding here is what makes
        # migration REHASHABLE: the fresh table may have a different p,
        # so raw stored words would be meaningless in the new geometry.
        from repro.core import hashing
        p = ops.num_rows
        s = kp[:, 0]
        rows = jnp.arange(s.shape[0], dtype=_U) // _U(ops.window)
        q = s >> _U(1)
        choice = (s & _U(1)) == _U(1)
        g = hashing.hash_step(q, p, table.seed)
        b1 = jnp.where(choice, (rows + _U(p) - g) % _U(p), rows)
        kp = hashing.unfull_hash(q * _U(p) + b1, table.seed)[:, None]
    return (jnp.where(live[:, None], kp, 0),
            jnp.where(live[:, None], vp, 0), live)


def live_entries(table):
    """Public live-set sweep: ``(keys (c, kw), values (c, vw), live (c,))``.

    The one arena walk every consumer of "what does this table hold"
    shares: migration rebuilds from it, ``bloom.rebuild_from_table``
    re-advertises it, and elastic resharding (``serving.elastic``)
    re-routes it onto a resized mesh.  Open-addressing stores sweep the
    slot arena (quotient geometries decode the stored word back to the
    key — exact, the mixer is a bijection); bucket-list tables linearize
    every chain into a per-key-contiguous stream in original insertion
    order (values are ``(c, 1)``).  Masked-out rows are zeroed, so the
    result is sentinel-free and safe to feed straight into bulk inserts.
    """
    if isinstance(table, bl.BucketListHashTable):
        keys, vals, live = _bucket_stream(table)
        return keys, vals[:, None], live
    return _sweep_oa(table)


def _replace_max_probes(table):
    """max_probes for the migrated table: a full-table default follows the
    new geometry; an explicit tighter bound is preserved."""
    return None if table.max_probes >= table.num_rows else table.max_probes


def _fresh_like_single(table, new_capacity):
    return sv.create(new_capacity, key_words=table.key_words,
                     value_words=table.value_words, window=table.window,
                     scheme=table.scheme, layout=table.layout,
                     seed=table.seed, max_probes=_replace_max_probes(table),
                     backend=table.backend)


def _fresh_like_multi(table, new_capacity):
    return mv.create(new_capacity, key_words=table.key_words,
                     value_words=table.value_words, window=table.window,
                     scheme=table.scheme, layout=table.layout,
                     seed=table.seed, max_probes=_replace_max_probes(table),
                     backend=table.backend)


def _check_migration(old_count, new_count, what: str) -> None:
    """Bit-exact live-set guard: the fresh table must hold every live
    entry.  Host-side only (skipped under tracing, where the in-run
    parity gates in tests/benchmarks cover it)."""
    oc, nc = _host_int(old_count), _host_int(new_count)
    if oc is not None and nc is not None and oc != nc:
        raise ValueError(
            f"{what}: migrated {nc} of {oc} live entries — target capacity "
            f"too small for the live set (grow further or raise max_probes)")


def _migrate_single(table, new_capacity):
    keys, vals, live = _sweep_oa(table)
    fresh = _fresh_like_single(table, new_capacity)
    fresh, _ = sv.insert(fresh, keys, vals, mask=live)
    _check_migration(table.count, fresh.count, "grow/compact(single_value)")
    return fresh, jnp.sum(live, dtype=_I)


def _migrate_multi(table, new_capacity):
    keys, vals, live = _sweep_oa(table)
    fresh = _fresh_like_multi(table, new_capacity)
    fresh, _ = mv.insert(fresh, keys, vals, mask=live)
    _check_migration(table.count, fresh.count, "grow/compact(multi_value)")
    return fresh, jnp.sum(live, dtype=_I)


def _bucket_stream(table):
    """Bucket-list chain walk -> ordered (key, value) stream.

    The key store's slot arena yields every live key and its handle; one
    ``chain_arena`` walk stamps each pool slot with (owning key-slot,
    head-first value rank).  A single scatter linearizes the pool into a
    per-key-contiguous stream in original insertion order.  Returns
    ``(stream_keys (pool_cap, kw), stream_vals (pool_cap,), stream_mask)``.
    """
    ks = table.key_store
    kp = ks.ops.key_planes(ks.store).reshape(ks.key_words, -1).T
    handles = ks.ops.value_planes(ks.store).reshape(2, -1).T      # (c, 2)
    live = (kp[:, 0] != EMPTY_KEY) & (kp[:, 0] != TOMBSTONE_KEY)
    ptr, cnt, bidx, _ = bl.unpack_handle(handles)
    counts = jnp.where(live, cnt, 0)
    offsets = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts)])
    total = offsets[-1]
    kcap = kp.shape[0]
    pool_cap = table.pool_capacity

    qa, ra = bl.chain_arena(table, live, ptr, counts, bidx)
    # destination of each pool slot in the ordered stream (OOR -> dropped)
    owner = jnp.clip(qa, 0, kcap - 1)
    pos = jnp.where(qa < kcap, offsets[owner] + ra, pool_cap)
    stream_vals = jnp.zeros((pool_cap,), _U).at[pos].set(
        table.pool, mode="drop")
    stream_keys = jnp.zeros((pool_cap, ks.key_words), _U).at[pos].set(
        jnp.where((qa < kcap)[:, None], kp[owner], 0), mode="drop")
    stream_mask = jnp.arange(pool_cap) < total
    return stream_keys, stream_vals, stream_mask


def _migrate_bucket(table, new_key_capacity, new_pool_capacity):
    """Bucket-list migration: the ``_bucket_stream`` walk feeds the bulk
    insert, which rebuilds the table — re-bucketing every chain from the
    growth schedule's first size, so the fresh pool is dense (tail slack
    and links of the old layout are reclaimed)."""
    ks = table.key_store
    stream_keys, stream_vals, stream_mask = _bucket_stream(table)
    total = jnp.sum(stream_mask, dtype=_I)

    fresh = bl.create(new_key_capacity, new_pool_capacity, s0=table.s0,
                      growth=table.growth, window=ks.window,
                      scheme=ks.scheme, seed=ks.seed,
                      key_words=ks.key_words, backend=ks.backend)
    fresh, _ = bl.insert(fresh, stream_keys, stream_vals, mask=stream_mask)
    _check_migration(ks.count, fresh.key_store.count,
                     "grow/compact(bucket_list) keys")
    _check_migration(total, jnp.sum(fresh._counts_all()),
                     "grow/compact(bucket_list) values")
    return fresh, total


def compact_in_graph(table):
    """Same-shape tombstone compaction, traceable under jit/scan/cond.

    ``_migrate_single`` is pure jnp end-to-end: the sweep reads the slot
    arena, ``_fresh_like_single`` recreates an *identical geometry* store
    (``table_geometry`` is idempotent on an existing prime row count) and
    the bulk insert rebuilds the live set — so input and output pytrees
    have the same treedef and shapes, which is exactly what ``lax.cond``
    branches and ``lax.scan`` carries require.  The streaming engine
    (``repro.data.stream``) invokes it under an in-graph tombstone-density
    predicate, keeping the whole ingestion loop one compilation.

    Differences from host-side :func:`compact`: no REGISTRY counters (the
    registry is host state; the stream carry counts compactions in its
    own ``StreamCounters``), no migration guard (``_check_migration``
    auto-skips under tracing; the stream parity gates cover it), and
    single-value/counting tables only — the shapes of a bucket-list pool
    repack depend on data, so that path stays host-side.
    """
    if isinstance(table, (bl.BucketListHashTable, mv.MultiValueHashTable)):
        raise TypeError("compact_in_graph supports single-value/counting "
                        "tables only; use host-side compact() for "
                        "multi-value and bucket-list tables")
    fresh, _ = _migrate_single(table, table.capacity)
    return fresh


# ---------------------------------------------------------------------------
# public migration API
# ---------------------------------------------------------------------------

def _dispatch_migrate(table, new_capacity, new_pool_capacity=None):
    if isinstance(table, bl.BucketListHashTable):
        if new_pool_capacity is None:
            # scale the pool with the key store (same growth ratio)
            ratio = max(1.0, new_capacity / max(table.key_capacity, 1))
            new_pool_capacity = int(math.ceil(table.pool_capacity * ratio))
        return _migrate_bucket(table, new_capacity, new_pool_capacity)
    if isinstance(table, mv.MultiValueHashTable):
        return _migrate_multi(table, new_capacity)
    return _migrate_single(table, new_capacity)


def grow(table, new_capacity: int, *, new_pool_capacity: int | None = None):
    """Migrate every live entry into a fresh store of >= ``new_capacity``.

    Tombstones are dropped in transit; the live key/value set (and, for
    multi-value / bucket-list, each key's value multiset in insertion
    order) is preserved bit-exactly.  For bucket-list tables
    ``new_capacity`` sizes the key store and ``new_pool_capacity`` the
    value pool (default: scaled by the same ratio).  Works at any target
    >= the live set — growth and shrink are the same sweep.
    """
    fresh, migrated = _dispatch_migrate(table, new_capacity,
                                        new_pool_capacity)
    REGISTRY.counter("table.grows").inc(1)
    REGISTRY.counter("table.migrated_slots").inc(migrated)
    return fresh


def compact(table):
    """Rebuild the table at its current capacity, dropping tombstones.

    Same-size migration: ``table_geometry`` is idempotent on an existing
    prime row count, so the fresh store has identical geometry — only
    the tombstones (and, for bucket-list, pool fragmentation) disappear.
    Restores early-exit probe walks after deletion churn without paying
    for a larger store.
    """
    if isinstance(table, bl.BucketListHashTable):
        fresh, migrated = _migrate_bucket(table, table.key_capacity,
                                          table.pool_capacity)
    else:
        fresh, migrated = _dispatch_migrate(table, table.capacity)
    REGISTRY.counter("table.compactions").inc(1)
    REGISTRY.counter("table.migrated_slots").inc(migrated)
    return fresh


# ---------------------------------------------------------------------------
# occupancy + policy decisions (host-side)
# ---------------------------------------------------------------------------

def occupancy(table):
    """Host-side occupancy census: (live, tombstones, capacity).

    ``None`` live/tombstones under tracing (policy callers skip).  For
    bucket-list tables the numbers describe the key store; pool usage is
    ``alloc_top`` (checked separately by the policy).
    """
    if isinstance(table, bl.BucketListHashTable):
        store_table = table.key_store
    else:
        store_table = table
    from repro.obs import metrics
    live, tomb, _ = metrics.slot_stats(store_table.ops, store_table.store)
    return _host_int(live), _host_int(tomb), store_table.capacity


def _grown_capacity(cap: int, need: int, policy: GrowthPolicy) -> int:
    """Smallest geometric step of ``cap`` that fits ``need`` under the
    policy's load-factor threshold, clamped to ``max_capacity``."""
    new_cap = cap
    while (new_cap < policy.max_capacity
           and need > policy.max_load_factor * new_cap):
        new_cap = min(int(math.ceil(new_cap * policy.growth_factor)),
                      policy.max_capacity)
    return new_cap


def maybe_migrate(table, policy: GrowthPolicy, incoming: int = 0):
    """Apply the policy ahead of an ``incoming``-element batch.

    Grows when the batch could push live occupancy past the load-factor
    threshold (at the capacity cap: compacts instead if tombstones are
    the blocker); compacts when tombstone density alone crosses its
    threshold.  No-op under tracing or when neither trigger fires.
    Returns the (possibly migrated) table.
    """
    live, tomb, cap = occupancy(table)
    if live is None or tomb is None:
        _note_traced_skip()               # traced: policy is host-side only
        return table
    need = live + incoming
    if need > policy.max_load_factor * cap:
        new_cap = _grown_capacity(cap, need, policy)
        if new_cap > cap:
            return grow(table, new_cap)
        if tomb > 0:                      # at the cap: reclaim what we can
            return compact(table)
        return table
    if (tomb > policy.max_tombstone_density * cap
            or need + tomb > policy.max_load_factor * cap):
        return compact(table)
    if isinstance(table, bl.BucketListHashTable):
        top = _host_int(table.alloc_top)
        if (top is not None
                and top + incoming > policy.max_load_factor
                * table.pool_capacity):
            new_pool = _grown_capacity(table.pool_capacity, top + incoming,
                                       policy)
            if new_pool > table.pool_capacity:
                return grow(table, table.key_capacity,
                            new_pool_capacity=new_pool)
    return table


# ---------------------------------------------------------------------------
# insert_or_grow — the consumer-facing wrapper
# ---------------------------------------------------------------------------

def _default_insert(table, keys, values, mask):
    if isinstance(table, bl.BucketListHashTable):
        return bl.insert(table, keys, values, mask)
    if isinstance(table, mv.MultiValueHashTable):
        return mv.insert(table, keys, values, mask)
    return sv.insert(table, keys, values, mask)


def insert_or_grow(table, keys, values=None, mask=None, *,
                   policy: GrowthPolicy = DEFAULT_POLICY,
                   insert_fn=None, max_attempts: int = 4):
    """Insert with the auto-growth policy: never hard-fail while capacity
    headroom remains.  Returns ``(table, status)`` like ``insert``.

    Host-side (eager) by design — see the module docstring.  The flow:

    1. ``maybe_migrate`` pre-checks the policy (grow for load, compact
       for tombstone churn) so the common case inserts into a table with
       headroom and no element ever reports FULL;
    2. the batch inserts through ``insert_fn`` (default: the table
       kind's own ``insert``; pass an adapter for RMW tables — see
       ``counting.insert_or_grow``);
    3. any ``STATUS_FULL`` / ``STATUS_POOL_FULL`` residue triggers an
       emergency grow (pool grow for POOL_FULL) and the *failed subset*
       retries under its own mask, statuses merged — at most
       ``max_attempts`` rounds, geometric capacity each round.

    At ``policy.max_capacity`` with nothing left to compact, FULL
    statuses surface to the caller unchanged (the policy bounds memory;
    it does not hide genuine exhaustion).
    """
    if insert_fn is None:
        insert_fn = _default_insert
    n = jnp.asarray(keys[0] if isinstance(keys, tuple) else keys).shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    table = maybe_migrate(table, policy, incoming=n)
    table, status = insert_fn(table, keys, values, mask)

    for _ in range(max_attempts):
        failed = (status == STATUS_FULL) | (status == STATUS_POOL_FULL)
        n_failed = _host_int(jnp.sum(failed, dtype=_I))
        if n_failed is None:
            _note_traced_skip()            # traced: no host retry possible
            break
        if n_failed == 0:
            break
        pool_full = _host_int(
            jnp.sum(status == STATUS_POOL_FULL, dtype=_I)) or 0
        live, tomb, cap = occupancy(table)
        if live is None:
            _note_traced_skip()            # traced: no host retry possible
            break
        if pool_full and isinstance(table, bl.BucketListHashTable):
            new_pool = _grown_capacity(
                table.pool_capacity,
                int(math.ceil(table.pool_capacity * policy.growth_factor)),
                policy)
            if new_pool <= table.pool_capacity:
                break
            table = grow(table, table.key_capacity,
                         new_pool_capacity=new_pool)
        elif tomb and live + n_failed <= policy.max_load_factor * cap:
            table = compact(table)         # tombstones were the blocker
        else:
            new_cap = _grown_capacity(cap, live + n_failed, policy)
            if new_cap <= cap:
                # occupancy says "fits" yet FULL happened: probe-sequence
                # exhaustion — take one geometric step for fresh geometry
                new_cap = min(int(math.ceil(cap * policy.growth_factor)),
                              policy.max_capacity)
            if new_cap <= cap:
                break                      # at max_capacity: surface FULL
            table = grow(table, new_cap)
        retry_mask = mask & failed
        table, status2 = insert_fn(table, keys, values, retry_mask)
        status = jnp.where(retry_mask, status2, status)
    return table, status
