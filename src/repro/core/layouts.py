"""Store protocol: the memory-layout abstraction behind every table.

The paper's §IV-A.1 layouts (SOA / AOS / packed, Fig. 1) used to live here
as free functions dispatching on a layout *string*; every engine that
wanted a new access pattern had to thread that string through and grow
another ``if kind == "soa"`` ladder.  This module now exposes the layouts
as a first-class **store protocol**: a small immutable ops object
(:class:`StoreOps`) that binds the table geometry once and renders each
access pattern the engines need —

- **key-plane reads** (``key_planes`` / ``value_planes``): whole-table
  plane views, the probe engines' row-candidate scans;
- **window gathers** (``key_windows`` / ``value_windows``): batched probe
  windows for a vector of rows — one vectorized COPS window per element;
- **slot writes** (``write_slot`` / ``write_value`` and the batched
  ``scatter_keys`` / ``scatter_values`` / ``scatter_batch``): functional
  claims and RMW stores, masked through out-of-range-drop scatters;
- **tombstones** (``scatter_key_word`` / ``tombstone_where``): the erase
  paths' in-band deletion writes;
- the **slot arena** (``arena_capacity`` / ``arena_values`` /
  ``arena_tombstone``): a flat slot-indexed view of the store.  The fused
  bulk-retrieval engine records matches as flat slot ids during its single
  walk and compacts them afterwards; any store that can gather values (and
  write tombstones) by flat slot id can ride that engine.  The contract,
  precisely: (1) ``arena_capacity`` is a static int — the number of
  addressable slots; (2) ``arena_values(store, slots)`` gathers
  ``(len(slots), value_words)`` u32 vectors for any in-range slot-id
  array (callers clip; gathered lanes are masked by caller validity);
  (3) ``arena_tombstone(store, occupied)`` deletes every slot whose
  (capacity,) mask bit is set, in one batched write.  For the
  open-addressing layouts a slot id is ``row * window + lane``; the
  bucket-list table exposes its value *pool* through the same hook
  (``repro.core.bucket_list``), which is what lets one walk/compaction
  implementation serve both store shapes.  The engine-side guard on this
  contract is ``bulk_retrieve.fused_ok``: the arena binds each slot to at
  most one (query, rank) pair, so only revisit-free walks may use it.

Concrete protocols:

- :class:`SoaOps`    — one (words, p, W) plane-major array per kind;
  vector loads of a probe window touch only key words.  **Default on TPU**
  (the paper notes SOA wins when only keys are probed; the VPU is 32-bit
  native — DESIGN.md §2).  ``planar`` is True: plane arrays can be handed
  to the Pallas kernels directly.
- :class:`AosOps`    — a single (p, W, key_words + value_words) slot-major
  array; key+value of one slot are adjacent (paper: better when both are
  always touched).
- :class:`PackedOps` — AOS restricted to 1-word keys and values, the
  analogue of the paper's 64-bit packed-AOS.  On GPU its point is
  single-CAS atomicity; on TPU atomicity is moot (ownership partitioning),
  so it is AOS with an enforced width.

Tables keep a ``layout`` string for construction/serialization, but no
consumer dispatches on it: ``make_ops(layout, ...)`` (cached) resolves it
to the protocol object once and everything downstream calls methods.
All writes are functional (return a new store).  64-bit keys/values use
two u32 words (hi, lo ordering: word 0 is the PRIMARY plane carrying
sentinels); composite multi-column keys generalize this to
``key_words = N`` planes (``hashing.pack_columns`` — plane 0 holds the
last, least-significant column, so the sentinel restriction stays a
plane-0 property and every layout stores N-word keys without a special
case).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY

_U = jnp.uint32

LAYOUTS = ("soa", "aos", "packed", "bucketed", "bucketedq")


@dataclasses.dataclass(frozen=True)
class StoreOps:
    """Base store protocol: geometry-bound layout operations.

    Frozen (hashable) so instances can ride in jit-static table metadata
    and the engines' ``tstatic`` tuples.  Subclasses implement the SOA and
    AOS renderings; everything here is layout-independent arithmetic.
    """

    num_rows: int
    window: int
    key_words: int
    value_words: int

    kind = "base"
    #: plane arrays individually addressable (SOA) — the Pallas kernels'
    #: eligibility predicate (they take bare (p, W) planes).
    planar = False
    #: key plane holds quotient remainders instead of raw keys (overridden
    #: by the bucketed lane; engines read this to pick compare targets).
    quotient = False

    # -- slot arena (flat slot-id view; shared by SOA/AOS) -------------------
    @property
    def arena_capacity(self) -> int:
        """Number of flat slot ids: ``num_rows * window``."""
        return self.num_rows * self.window

    def arena_values(self, store: dict, slots: jax.Array) -> jax.Array:
        """Gather value vectors (n, value_words) at flat slot ids.

        The fused-retrieval compaction hook: ``slots`` are walk-arena slot
        ids (callers clip into range; every gathered lane is masked by the
        caller's validity anyway).
        """
        vp = self.value_planes(store)
        return vp.reshape(self.value_words, self.arena_capacity)[:, slots].T

    def arena_tombstone(self, store: dict, occupied: jax.Array) -> dict:
        """Tombstone every slot where the flat (capacity,) mask is set."""
        return self.tombstone_where(
            store, occupied.reshape(self.num_rows, self.window))


@dataclasses.dataclass(frozen=True)
class SoaOps(StoreOps):
    kind = "soa"
    planar = True

    def create(self) -> dict:
        return {
            "keys": jnp.full((self.key_words, self.num_rows, self.window),
                             EMPTY_KEY, dtype=_U),
            "values": jnp.zeros((self.value_words, self.num_rows, self.window),
                                dtype=_U),
        }

    def key_planes(self, store: dict) -> jax.Array:
        """All key words as a (key_words, p, W) view."""
        return store["keys"]

    def value_planes(self, store: dict) -> jax.Array:
        return store["values"]

    def key_windows(self, store: dict, rows: jax.Array) -> jax.Array:
        """Gather probe windows for a batch of rows -> (n, key_words, W)."""
        return jnp.moveaxis(store["keys"][:, rows, :], 0, 1)

    def value_windows(self, store: dict, rows: jax.Array) -> jax.Array:
        return jnp.moveaxis(store["values"][:, rows, :], 0, 1)

    def write_slot(self, store: dict, row, lane, key_vec: jax.Array,
                   value_vec: jax.Array) -> dict:
        """Functionally write one slot (key + value words)."""
        return {
            "keys": store["keys"].at[:, row, lane].set(key_vec),
            "values": store["values"].at[:, row, lane].set(value_vec),
        }

    def write_value(self, store: dict, row, lane, value_vec: jax.Array) -> dict:
        return {"keys": store["keys"],
                "values": store["values"].at[:, row, lane].set(value_vec)}

    def scatter_key_word(self, store: dict, rows: jax.Array, lanes: jax.Array,
                         word: np.uint32) -> dict:
        """Scatter a constant key word into all key planes at (rows, lanes).

        Out-of-range rows (== num_rows) are dropped — used to mask inactive
        elements in vectorized erase.
        """
        fill = jnp.full(rows.shape, word, dtype=_U)
        keys = store["keys"]
        for w in range(self.key_words):
            keys = keys.at[w, rows, lanes].set(fill, mode="drop")
        return {"keys": keys, "values": store["values"]}

    def tombstone_where(self, store: dict, mask2d: jax.Array) -> dict:
        """Write TOMBSTONE into every key word of the slots where mask2d (p, W).

        The bulk-erase apply: one dense vectorized select over the key planes
        instead of a scatter per probe window — the slot mask comes from the
        fused retrieval walk's match arena.
        """
        tomb = jnp.asarray(TOMBSTONE_KEY, _U)
        keys = jnp.where(mask2d[None, :, :], tomb, store["keys"])
        return {"keys": keys, "values": store["values"]}

    def scatter_values(self, store: dict, rows: jax.Array, lanes: jax.Array,
                       values: jax.Array) -> dict:
        """Scatter per-element value vectors (n, vw) at (rows, lanes); OOR dropped."""
        vals = store["values"]
        for w in range(values.shape[1]):
            vals = vals.at[w, rows, lanes].set(values[:, w], mode="drop")
        return {"keys": store["keys"], "values": vals}

    def scatter_keys(self, store: dict, rows: jax.Array, lanes: jax.Array,
                     keys: jax.Array) -> dict:
        """Scatter per-element key vectors (n, kw) at (rows, lanes); OOR dropped.

        Masked writes via out-of-range rows replace lax.cond/switch branches:
        conditionals returning whole stores defeat XLA's in-place buffer reuse
        (each branch copies the table), while a dropped scatter is O(1)."""
        ks = store["keys"]
        for w in range(keys.shape[1]):
            ks = ks.at[w, rows, lanes].set(keys[:, w], mode="drop")
        return {"keys": ks, "values": store["values"]}

    def scatter_batch(self, store: dict, rows: jax.Array, lanes: jax.Array,
                      keys: jax.Array, vals: jax.Array,
                      key_mask: jax.Array) -> dict:
        """Whole-batch scatter of keys (where key_mask) and vals at (rows, lanes).

        Planes are scattered through their flattened (p*W,) view — 1-D
        scatter indices take XLA's fast path; safe here because the whole
        batch is one scatter (the scan paths keep the 2-D form, which XLA
        updates in place inside the carry).  OOR rows flatten past p*W and
        drop.
        """
        idx = rows * _U(self.window) + lanes
        flat = self.arena_capacity
        kplanes = store["keys"].reshape(self.key_words, flat)
        kidx = jnp.where(key_mask, idx, _U(flat))
        for w in range(self.key_words):
            kplanes = kplanes.at[w, kidx].set(keys[:, w], mode="drop")
        vplanes = store["values"].reshape(self.value_words, flat)
        for w in range(self.value_words):
            vplanes = vplanes.at[w, idx].set(vals[:, w], mode="drop")
        return {"keys": kplanes.reshape(store["keys"].shape),
                "values": vplanes.reshape(store["values"].shape)}


@dataclasses.dataclass(frozen=True)
class BucketedOps(SoaOps):
    """Fixed-width buckets as the vector lane (two-choice storage lane).

    Physically identical to SOA — the (p, W) row IS the bucket, probed
    whole with one vector vote (the TPU analogue of the Compact Parallel
    Hash Tables paper's cache-line-sized buckets) — but bound to the
    ``"bucketed"`` probing scheme semantics: every key has exactly two
    candidate buckets, so probes are bucket-granular and walks are length
    <= 2 regardless of load factor.

    ``quotient=True`` (layout name ``"bucketedq"``) switches the key plane
    to remainder storage: instead of the 32-bit key the slot holds
    ``q*2 + choice`` with ``q = full_hash(key) // p`` — strictly fewer
    than 32 significant bits whenever p >= 7 (``bits_per_slot``), the
    compact-hashing trade.  Requires ``key_words == 1``.
    """

    quotient: bool = False
    kind = "bucketed"

    def __post_init__(self):
        if self.quotient and self.key_words != 1:
            raise ValueError("quotient (bucketedq) requires 1-word keys")

    @property
    def bits_per_slot(self) -> int:
        """Significant key bits stored per slot.

        Quotient stores hold words <= 2*ceil(2^32 / p) + 1; plain stores
        hold raw 32-bit keys.
        """
        if not self.quotient:
            return 32
        max_word = 2 * (((1 << 32) + self.num_rows - 1) // self.num_rows) + 1
        return max(1, (max_word - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class AosOps(StoreOps):
    kind = "aos"

    def create(self) -> dict:
        words = self.key_words + self.value_words
        slots = jnp.zeros((self.num_rows, self.window, words), dtype=_U)
        slots = slots.at[:, :, :self.key_words].set(EMPTY_KEY)
        return {"slots": slots}

    def key_planes(self, store: dict) -> jax.Array:
        return jnp.moveaxis(store["slots"][:, :, :self.key_words], -1, 0)

    def value_planes(self, store: dict) -> jax.Array:
        kw = self.key_words
        return jnp.moveaxis(store["slots"][:, :, kw:kw + self.value_words],
                            -1, 0)

    def key_windows(self, store: dict, rows: jax.Array) -> jax.Array:
        return jnp.moveaxis(store["slots"][rows][:, :, :self.key_words], -1, 1)

    def value_windows(self, store: dict, rows: jax.Array) -> jax.Array:
        kw = self.key_words
        return jnp.moveaxis(
            store["slots"][rows][:, :, kw:kw + self.value_words], -1, 1)

    def write_slot(self, store: dict, row, lane, key_vec: jax.Array,
                   value_vec: jax.Array) -> dict:
        slot = jnp.concatenate([key_vec, value_vec])
        return {"slots": store["slots"].at[row, lane, :].set(slot)}

    def write_value(self, store: dict, row, lane, value_vec: jax.Array) -> dict:
        return {"slots": store["slots"].at[row, lane,
                                           self.key_words:].set(value_vec)}

    def scatter_key_word(self, store: dict, rows: jax.Array, lanes: jax.Array,
                         word: np.uint32) -> dict:
        fill = jnp.full(rows.shape, word, dtype=_U)
        slots = store["slots"]
        for w in range(self.key_words):
            slots = slots.at[rows, lanes, w].set(fill, mode="drop")
        return {"slots": slots}

    def tombstone_where(self, store: dict, mask2d: jax.Array) -> dict:
        tomb = jnp.asarray(TOMBSTONE_KEY, _U)
        slots = store["slots"]
        words = slots.shape[-1]
        is_key = jnp.arange(words) < self.key_words
        sel = mask2d[:, :, None] & is_key[None, None, :]
        return {"slots": jnp.where(sel, tomb, slots)}

    def scatter_values(self, store: dict, rows: jax.Array, lanes: jax.Array,
                       values: jax.Array) -> dict:
        slots = store["slots"]
        for w in range(values.shape[1]):
            slots = slots.at[rows, lanes, self.key_words + w].set(
                values[:, w], mode="drop")
        return {"slots": slots}

    def scatter_keys(self, store: dict, rows: jax.Array, lanes: jax.Array,
                     keys: jax.Array) -> dict:
        slots = store["slots"]
        for w in range(keys.shape[1]):
            slots = slots.at[rows, lanes, w].set(keys[:, w], mode="drop")
        return {"slots": slots}

    def scatter_batch(self, store: dict, rows: jax.Array, lanes: jax.Array,
                      keys: jax.Array, vals: jax.Array,
                      key_mask: jax.Array) -> dict:
        oor = _U(self.num_rows)
        store = self.scatter_values(store, rows, lanes, vals)
        krow = jnp.where(key_mask, rows, oor)
        return self.scatter_keys(store, krow, lanes, keys)

    def arena_values(self, store: dict, slots: jax.Array) -> jax.Array:
        kw = self.key_words
        rows = slots // self.window
        lanes = slots % self.window
        return store["slots"][rows, lanes, kw:kw + self.value_words]


@dataclasses.dataclass(frozen=True)
class PackedOps(AosOps):
    kind = "packed"

    def __post_init__(self):
        if self.key_words != 1 or self.value_words != 1:
            raise ValueError("packed layout requires 1-word keys and values")


_KINDS = {"soa": SoaOps, "aos": AosOps, "packed": PackedOps,
          "bucketed": BucketedOps, "bucketedq": BucketedOps}


@functools.lru_cache(maxsize=None)
def make_ops(kind: str, num_rows: int, window: int, key_words: int,
             value_words: int) -> StoreOps:
    """Resolve a layout name to its (cached) geometry-bound protocol object."""
    if kind not in _KINDS:
        raise ValueError(f"layout {kind!r} not in {LAYOUTS}")
    kw = {}
    if kind == "bucketedq":
        kw["quotient"] = True
    return _KINDS[kind](num_rows=num_rows, window=window, key_words=key_words,
                        value_words=value_words, **kw)


def create(kind: str, num_rows: int, window: int, key_words: int,
           value_words: int) -> dict:
    """Convenience: build an empty store for ``kind`` (table constructors)."""
    return make_ops(kind, num_rows, window, key_words, value_words).create()
