"""Memory-layout abstraction: SOA / AOS / packed (paper §IV-A.1, Fig. 1).

A *store* is a dict of arrays holding ``key_words + value_words`` uint32
words per slot, arranged as (num_rows, window) slots:

- ``soa``    — one (words, p, W) plane-major array per kind; vector loads of a
               probe window touch only key words.  **Default on TPU** (the
               paper itself notes SOA wins when only keys are probed, and the
               VPU is 32-bit native — DESIGN.md §2).
- ``aos``    — a single (p, W, key_words + value_words) slot-major array;
               key+value of one slot are adjacent (paper: better when both are
               always touched).
- ``packed`` — AOS restricted to key_words == value_words == 1, the analogue
               of the paper's 64-bit packed-AOS.  On GPU its point is single-
               CAS atomicity; on TPU atomicity is moot (ownership
               partitioning), so it is AOS with an enforced width.

All writes are functional (returns a new store).  64-bit keys/values use two
u32 words (hi, lo ordering: word 0 is the PRIMARY plane carrying sentinels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY

_U = jnp.uint32

LAYOUTS = ("soa", "aos", "packed")


def _check(kind: str, key_words: int, value_words: int) -> None:
    if kind not in LAYOUTS:
        raise ValueError(f"layout {kind!r} not in {LAYOUTS}")
    if kind == "packed" and (key_words != 1 or value_words != 1):
        raise ValueError("packed layout requires 1-word keys and values")


def create(kind: str, num_rows: int, window: int, key_words: int,
           value_words: int) -> dict:
    _check(kind, key_words, value_words)
    if kind == "soa":
        return {
            "keys": jnp.full((key_words, num_rows, window), EMPTY_KEY, dtype=_U),
            "values": jnp.zeros((value_words, num_rows, window), dtype=_U),
        }
    words = key_words + value_words
    slots = jnp.zeros((num_rows, window, words), dtype=_U)
    slots = slots.at[:, :, :key_words].set(EMPTY_KEY)
    return {"slots": slots}


def key_planes(kind: str, store: dict, key_words: int) -> jax.Array:
    """All key words as a (key_words, p, W) view."""
    if kind == "soa":
        return store["keys"]
    return jnp.moveaxis(store["slots"][:, :, :key_words], -1, 0)


def value_planes(kind: str, store: dict, key_words: int, value_words: int) -> jax.Array:
    if kind == "soa":
        return store["values"]
    return jnp.moveaxis(store["slots"][:, :, key_words:key_words + value_words], -1, 0)


def key_windows(kind: str, store: dict, rows: jax.Array, key_words: int) -> jax.Array:
    """Gather probe windows for a batch of rows -> (n, key_words, W)."""
    if kind == "soa":
        return jnp.moveaxis(store["keys"][:, rows, :], 0, 1)
    return jnp.moveaxis(store["slots"][rows][:, :, :key_words], -1, 1)


def value_windows(kind: str, store: dict, rows: jax.Array, key_words: int,
                  value_words: int) -> jax.Array:
    if kind == "soa":
        return jnp.moveaxis(store["values"][:, rows, :], 0, 1)
    return jnp.moveaxis(store["slots"][rows][:, :, key_words:key_words + value_words], -1, 1)


def write_slot(kind: str, store: dict, row, lane, key_vec: jax.Array,
               value_vec: jax.Array, key_words: int) -> dict:
    """Functionally write one slot (key + value words)."""
    if kind == "soa":
        return {
            "keys": store["keys"].at[:, row, lane].set(key_vec),
            "values": store["values"].at[:, row, lane].set(value_vec),
        }
    slot = jnp.concatenate([key_vec, value_vec])
    return {"slots": store["slots"].at[row, lane, :].set(slot)}


def write_value(kind: str, store: dict, row, lane, value_vec: jax.Array,
                key_words: int) -> dict:
    if kind == "soa":
        return {"keys": store["keys"], "values": store["values"].at[:, row, lane].set(value_vec)}
    return {"slots": store["slots"].at[row, lane, key_words:].set(value_vec)}


def scatter_key_word(kind: str, store: dict, rows: jax.Array, lanes: jax.Array,
                     word: np.uint32, key_words: int, num_rows: int) -> dict:
    """Scatter a constant key word into all key planes at (rows, lanes).

    Out-of-range rows (== num_rows) are dropped — used to mask inactive
    elements in vectorized erase.
    """
    fill = jnp.full(rows.shape, word, dtype=_U)
    if kind == "soa":
        keys = store["keys"]
        for w in range(key_words):
            keys = keys.at[w, rows, lanes].set(fill, mode="drop")
        return {"keys": keys, "values": store["values"]}
    slots = store["slots"]
    for w in range(key_words):
        slots = slots.at[rows, lanes, w].set(fill, mode="drop")
    return {"slots": slots}


def tombstone_where(kind: str, store: dict, mask2d: jax.Array,
                    key_words: int) -> dict:
    """Write TOMBSTONE into every key word of the slots where mask2d (p, W).

    The bulk-erase apply: one dense vectorized select over the key planes
    instead of a scatter per probe window — the slot mask comes from the
    fused retrieval walk's match arena.
    """
    tomb = jnp.asarray(TOMBSTONE_KEY, _U)
    if kind == "soa":
        keys = jnp.where(mask2d[None, :, :], tomb, store["keys"])
        return {"keys": keys, "values": store["values"]}
    slots = store["slots"]
    words = slots.shape[-1]
    is_key = jnp.arange(words) < key_words
    sel = mask2d[:, :, None] & is_key[None, None, :]
    return {"slots": jnp.where(sel, tomb, slots)}


def scatter_values(kind: str, store: dict, rows: jax.Array, lanes: jax.Array,
                   values: jax.Array, key_words: int) -> dict:
    """Scatter per-element value vectors (n, value_words) at (rows, lanes); OOR dropped."""
    if kind == "soa":
        vals = store["values"]
        for w in range(values.shape[1]):
            vals = vals.at[w, rows, lanes].set(values[:, w], mode="drop")
        return {"keys": store["keys"], "values": vals}
    slots = store["slots"]
    for w in range(values.shape[1]):
        slots = slots.at[rows, lanes, key_words + w].set(values[:, w], mode="drop")
    return {"slots": slots}


def scatter_keys(kind: str, store: dict, rows: jax.Array, lanes: jax.Array,
                 keys: jax.Array) -> dict:
    """Scatter per-element key vectors (n, key_words) at (rows, lanes); OOR dropped.

    Masked writes via out-of-range rows replace lax.cond/switch branches:
    conditionals returning whole stores defeat XLA's in-place buffer reuse
    (each branch copies the table), while a dropped scatter is O(1)."""
    if kind == "soa":
        ks = store["keys"]
        for w in range(keys.shape[1]):
            ks = ks.at[w, rows, lanes].set(keys[:, w], mode="drop")
        return {"keys": ks, "values": store["values"]}
    slots = store["slots"]
    for w in range(keys.shape[1]):
        slots = slots.at[rows, lanes, w].set(keys[:, w], mode="drop")
    return {"slots": slots}
