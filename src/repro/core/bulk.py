"""Vectorized bulk-build engine — scatter-arbitration inserts.

The paper's headline number is *build* throughput (up to 1.6 G inserts/s);
the scan reference path in ``single_value`` / ``multi_value`` serializes the
batch with ``lax.scan`` (one probe walk per key, n sequential steps).  This
module replaces that with a constant number of **whole-batch vectorized
sweeps**, the bulk-synchronous build style of WarpSpeed (McCoy & Pandey
2025) and the NUMA pre-aggregation of Tripathy & Green (2021), mapped onto
the repo's single-writer-per-shard model (no CAS — all conflict resolution
happens *before* any store write):

1. **Dedup** — intra-batch duplicate keys are resolved in plain vector ops:
   sort-by-key groups equal keys, a segment-combine pre-aggregates the RMW
   operands (or picks the last writer for plain upsert), and exactly one
   *representative* per distinct key survives — the group's first live
   occurrence, carrying the group's combined operand.
2. **Probe** — representatives run one vectorized ``_locate``-style COPS
   walk against the (immutable, pre-batch) store.  Matches are final here:
   the batch inserts only keys *distinct* from every representative, so no
   store write can create or destroy a match.  Non-matches become
   *claimers*.  Building into an empty table — the paper's bulk-build
   benchmark — skips the walk entirely.
3. **Arbitrate** — claimers are placed by a *virtual-fill fixpoint* over a
   precomputed per-row free-lane count: claimers targeting a row are ranked
   by original batch position (scatter-min arbitration generalized from one
   slot to a whole probe window) and the k-th lowest-priority claimer takes
   the k-th lowest EMPTY/TOMBSTONE lane — exactly what k consecutive
   sequential inserts do to a window.  Claimers ranked past the row's free
   lanes are *bumped*: they advance their probe cursor to the next
   candidate row of their own probe sequence and re-enter the next sweep
   (possibly ousting a higher-priority tentative occupant there).  The
   fixpoint is the deferred-acceptance argument: by induction over
   priority, each claimer ends exactly where the sequential scan would have
   placed it.  Claimers that exhaust ``max_probes`` rows report FULL, like
   the scan.
4. **Apply** — one batched write phase: matched slots gather-old / fold /
   scatter (RMW) or scatter the pre-combined value (upsert); placed
   claimers scatter key + value.  Assignments are distinct by construction
   — (row, rank) pairs are unique — which the parity suite cross-checks
   with an explicit scatter-min arena (``arbitrate``).

Build complexity drops from n sequential probe walks to ~max_bump_chain
vectorized sweeps over a (num_rows,) count table, after a single
vectorized probe.

**Fast and general lanes.**  XLA's CPU sort has a fast payload-free form,
so the hot path (1-word keys) runs entirely in the original batch order:
group ids come from a bare key sort + ``searchsorted``, segment combines
are scatter-reductions (``.at[gid].add/min/max``) keyed by a per-word
combiner *spec* (e.g. ``("min", "add")``), and the per-sweep rank sort
packs (row, priority) into one u32.  Wide keys — u64 two-plane AND
composite ``key_words >= 2`` multi-column keys (``hashing.pack_columns``)
— and arbitrary user combiner *callables* take the general lane: one
stable MULTI-PLANE LEXICOGRAPHIC sort by (masked, key plane_{kw-1} ..
plane_0, batch index) (``_sort_batch``), with group segments bounded by
the all-plane adjacent-equality compare (``_group_structure``) — never a
single-plane compare, so composite keys differing only in a high word
occupy distinct groups.  Both lanes share probe / placement / apply
(which are plane-count agnostic: the probe word is the
``key_hash_word`` fold of every plane) and are bit-identical.

**Parity.**  The engine is bit-exact against the ``backend="scan"``
reference — same claimed slots, same table state, same per-element STATUS
codes — provided the RMW combine is associative and matches the sequential
fold (see ``update_single``).  ``tests/test_bulk.py`` asserts this across
duplicates, tombstone reuse, masks, near-full tables and u64 keys.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat
from repro.core import probing
from repro.core.common import (
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_UPDATED,
    TOMBSTONE_KEY,
)

_U = jnp.uint32
_I = jnp.int32


def _tstatic(table):
    """(store protocol, scheme, seed, max_probes) — the engines' static tuple.

    ``max_probes`` is the COVERAGE-CLAMPED budget (``probing.
    effective_probes``): a walk never spends attempts revisiting rows its
    scheme cannot leave (quadratic reaches only (p+1)/2 distinct rows,
    bucketed exactly 2).  Identity for cops/linear with the default
    budget; for quadratic it is the spurious-FULL bugfix — revisited rows
    re-entered the claim fixpoint as fresh candidates and burned the
    budget the sequential reference spent on distinct rows.
    """
    return (table.ops, table.scheme, table.seed,
            probing.effective_probes(table.scheme, table.max_probes,
                                     table.ops.num_rows))


# ---------------------------------------------------------------------------
# combiner specs — scatter-reducible segment combines for the fast lane
# ---------------------------------------------------------------------------

#: per-word reducers usable as combiner specs: name -> (identity, pairwise)
COMBINE_OPS = {
    "add": (np.uint32(0), lambda a, b: a + b),
    "min": (np.uint32(0xFFFFFFFF), jnp.minimum),
    "max": (np.uint32(0), jnp.maximum),
    "or": (np.uint32(0), jnp.bitwise_or),
    "and": (np.uint32(0xFFFFFFFF), jnp.bitwise_and),
    "xor": (np.uint32(0), jnp.bitwise_xor),
}

#: specs with no native scatter-reduce method; folded via bit planes
_BITWISE = ("or", "and", "xor")


def combine_callable(spec: Sequence[str]) -> Callable:
    """Lift a per-word combiner spec into the general lane's callable form."""
    ops = [COMBINE_OPS[s][1] for s in spec]
    return lambda a, b: jnp.stack([op(a[w], b[w])
                                   for w, op in enumerate(ops)])


def _bitwise_scatter(name, gid, col, contrib, n):
    """Per-group bitwise or/and/xor of ``col[contrib]`` via ONE scatter-add.

    ``jnp.ndarray.at`` has no bitwise reducers, but every bitwise fold is a
    per-bit-plane popcount question: decompose the operands into a (n, 32)
    bit matrix, scatter-add it per group alongside the contributor count,
    and read each bit back as any (or), all (and) or parity (xor) of its
    plane.  Zero-contributor groups fall out as the op's identity (0 for
    or/xor, 0xFFFFFFFF for and) automatically.
    """
    shifts = jnp.arange(32, dtype=_U)
    bits = ((col[:, None] >> shifts[None, :]) & _U(1)).astype(_I)
    bits = jnp.where(contrib[:, None], bits, 0)
    acc = jnp.zeros((n, 32), _I).at[gid].add(bits)
    if name == "xor":
        plane = (acc & 1) > 0
    elif name == "or":
        plane = acc > 0
    else:  # and: every contributor set the bit
        cnt = jnp.zeros((n,), _I).at[gid].add(contrib.astype(_I))
        plane = acc == cnt[:, None]
    word = jnp.sum(jnp.where(plane, _U(1) << shifts[None, :], _U(0)), axis=1)
    return word[gid]


def _scatter_combine(spec, gid, vals, contrib):
    """Per-group combine of ``vals[contrib]`` via scatter-reduce -> (n, vw).

    Non-contributing elements scatter the op's identity, so each group cell
    holds exactly the fold over its contributors (the fast-lane rendering
    of the general lane's segmented scan).  add/min/max map directly onto
    ``.at[]`` reducers; the bitwise specs run the bit-plane scatter-add.
    """
    n = gid.shape[0]
    out = []
    for w, name in enumerate(spec):
        if name in _BITWISE:
            out.append(_bitwise_scatter(name, gid, vals[:, w], contrib, n))
            continue
        ident, _ = COMBINE_OPS[name]
        v = jnp.where(contrib, vals[:, w], ident)
        arena = jnp.full((n,), ident, _U)
        arena = getattr(arena.at[gid], name)(v)   # .add / .min / .max
        out.append(arena[gid])
    return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# dedup — fast lane (1-word keys, original batch order)
# ---------------------------------------------------------------------------

def _group_fast(keys1, live):
    """Group structure for 1-word keys without any payload sort.

    A bare value sort (XLA's fast path) + ``searchsorted`` yields a group
    id per element; first/last live occurrences come from scatter-min/max
    arenas, and both are skipped entirely when the sorted run has no
    adjacent duplicates.  Masked elements sort as EMPTY_KEY (no user key
    collides with a sentinel) and so never join a live group.

    Returns (is_rep, rep_of, lww_of, gid, has_dups) — all in batch order;
    ``rep_of``/``lww_of`` map every element to its group's first/last live
    element (itself when duplicate-free).
    """
    n = keys1.shape[0]
    idx = jnp.arange(n, dtype=_U)
    k = jnp.where(live, keys1, EMPTY_KEY)
    sk = jnp.sort(k)
    has_dups = jnp.any((sk[1:] == sk[:-1]) & (sk[:-1] != EMPTY_KEY))

    def with_dups(_):
        gid = jnp.searchsorted(sk, k).astype(_U)
        rep = jnp.full((n,), _U(n)).at[gid].min(jnp.where(live, idx, n))
        lww = jnp.zeros((n,), _U).at[gid].max(jnp.where(live, idx, 0))
        return gid, rep[gid], lww[gid]

    def without(_):
        return idx, idx, idx

    gid, rep_of, lww_of = jax.lax.cond(has_dups, with_dups, without, None)
    is_rep = live & (rep_of == idx)
    return is_rep, rep_of, lww_of, gid, has_dups


# ---------------------------------------------------------------------------
# dedup — general lane (wide keys / arbitrary combiners; sorted domain)
# ---------------------------------------------------------------------------

def _sort_batch(keys, mask, payload_cols):
    """Stable sort by (masked, key words, batch index).

    Masked elements cluster at the end (they never merge with live groups);
    within a live group elements keep batch order, so "first live
    occurrence" and "last writer" are positional.  Returns the sorted
    (masked_flag, key_words, orig_idx, payload_cols) tuple.

    **Packed u64 lane**: two-word keys (u64 two-plane and composite kw=2)
    fuse their planes into one ``plane0 << 32 | plane1`` sort word when
    XLA sorts genuine uint64 on this config (``compat.supports_u64_sort``
    — requires x64), cutting the comparator from 4 sort keys to 3.  The
    packed word compares exactly like the (plane0, plane1) lexicographic
    pair, and the planes are split back out of the sorted word, so the
    group structure and every downstream output are bit-identical to the
    two-plane path (asserted by ``tests/test_packed_sort.py``).
    """
    n = mask.shape[0]
    flag = (~mask).astype(_U)
    idx = jnp.arange(n, dtype=_U)
    kw = keys.shape[1]
    if kw == 2 and compat.supports_u64_sort():
        u64 = jnp.uint64
        word = (keys[:, 0].astype(u64) << u64(32)) | keys[:, 1].astype(u64)
        out = jax.lax.sort(tuple([flag, word, idx] + list(payload_cols)),
                           num_keys=3)
        sw = out[1]
        skeys = jnp.stack([(sw >> u64(32)).astype(_U),
                           (sw & u64(0xFFFFFFFF)).astype(_U)], axis=1)
        return out[0], skeys, out[2], out[3:]
    ops = [flag] + [keys[:, w] for w in range(kw)] + [idx] + list(payload_cols)
    out = jax.lax.sort(tuple(ops), num_keys=kw + 2)
    return out[0], jnp.stack(out[1:1 + kw], axis=1), out[1 + kw], out[2 + kw:]


def _group_structure(flag, skeys):
    """Segment layout of the sorted batch.

    Returns (live, is_rep, first_pos, last_pos): segments are maximal runs
    of equal live keys (each masked element is its own singleton segment,
    never read), ``is_rep`` marks the first live element of each live
    group, and first/last_pos give, per element, the sorted positions
    bounding its segment.
    """
    n = flag.shape[0]
    live = flag == 0
    same_key = jnp.all(skeys[1:] == skeys[:-1], axis=1)
    cont = jnp.concatenate([jnp.zeros((1,), bool),
                            same_key & live[1:] & live[:-1]])
    runstart = ~cont
    is_rep = live & runstart
    pos = jnp.arange(n, dtype=_I)
    first_pos = jax.lax.cummax(jnp.where(runstart, pos, -1))
    nxt = jnp.concatenate([runstart[1:], jnp.ones((1,), bool)])
    last_pos = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(nxt, pos, n))))
    return live, is_rep, first_pos, last_pos


def _segmented_combine(vals, reset, combine):
    """Inclusive segmented scan of ``vals`` (n, vw) with ``combine``.

    ``reset`` marks positions where accumulation restarts; the value at a
    segment's last position is the combine over [last reset .. last].
    """
    cmb = jax.vmap(combine)

    def op(a, b):
        fa, va = a
        fb, vb = b
        f = fa | fb
        v = jnp.where(fb[:, None], vb, cmb(va, vb))
        return f, v

    _, out = jax.lax.associative_scan(op, (reset, vals))
    return out


# ---------------------------------------------------------------------------
# step 2 — vectorized probe walk (batch version of _probe_for_insert)
# ---------------------------------------------------------------------------

def probe_matches(tstatic, store, keys, words, active, count=None,
                  stats=False):
    """One COPS walk for every active element against the current store.

    Returns (matched, row, lane) — the position of each key already
    present.  The walk stops at a match or a window containing EMPTY
    (absence proof), exactly like ``_locate``; candidate slots are NOT
    chosen here — claims are placed by the virtual-fill fixpoint, which
    owns the write-order semantics.  When ``count`` is given and zero (the
    bulk-build-from-fresh case), the walk is skipped: an empty table can
    hold no match even if erases left tombstones behind.

    ``stats`` (static) additionally carries a per-element probe-length
    counter — windows examined before the element's walk stopped — and
    returns it as a fourth output.  When False (default) the traced graph
    is exactly the three-output walk (byte-identical HLO).
    """
    ops, scheme, seed, max_probes = tstatic
    num_rows, w = ops.num_rows, ops.window
    n = keys.shape[0]
    row0 = probing.initial_row(words, num_rows, seed, ops.quotient)
    step = probing.row_step(scheme, words, num_rows, seed, ops.quotient)

    def empty(_):
        out = (jnp.zeros((n,), bool), row0, jnp.zeros((n,), _U))
        return out + ((jnp.zeros((n,), _I),) if stats else ())

    def walk(_):
        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~jnp.all(done))

        def body(st):
            if stats:
                attempt, row, done, mrow, mlane, matched, plen = st
                plen = plen + (~done).astype(_I)
            else:
                attempt, row, done, mrow, mlane, matched = st
            win = ops.key_windows(store, row)
            has_empty = probing.vote_any(win[:, 0, :] == EMPTY_KEY)
            if ops.quotient:
                # quotient stores hold q*2 + choice: the compare target is
                # attempt-dependent (choice == attempt on the bucketed walk)
                tgt = probing.match_word(words, num_rows, attempt,
                                         quotient=True)
                match = win[:, 0, :] == tgt[:, None]
            else:
                match = jnp.all(win == keys[:, :, None], axis=1)
            m_lane = probing.vote_lowest(match)
            hit = (m_lane < w) & ~done
            mrow = jnp.where(hit, row, mrow)
            mlane = jnp.where(hit, m_lane.astype(_U), mlane)
            matched = matched | hit
            done = done | hit | has_empty
            nrow = probing.advance_row(scheme, row, step, attempt, num_rows)
            out = (attempt + 1, jnp.where(done, row, nrow), done, mrow,
                   mlane, matched)
            return out + ((plen,) if stats else ())

        z = jnp.zeros((n,), _U)
        st = (jnp.zeros((), _I), row0, ~active, z, z, jnp.zeros((n,), bool))
        if stats:
            st = st + (jnp.zeros((n,), _I),)
        if max_probes <= probing.UNROLL_PROBES:
            # static <= 2-window budget (the bucketed walk): unroll so the
            # walk costs the same at every load factor; body is a no-op
            # once an element is done, so outputs are identical
            res = st
            for _ in range(max_probes):
                res = body(res)
        else:
            res = jax.lax.while_loop(cond, body, st)
        matched, mrow, mlane = res[5], res[3], res[4]
        if stats:
            return matched, mrow, mlane, res[6]
        return matched, mrow, mlane

    if count is None:
        return walk(None)
    return jax.lax.cond(count == 0, empty, walk, None)


# ---------------------------------------------------------------------------
# step 3 — virtual-fill fixpoint (claim placement)
# ---------------------------------------------------------------------------

def _rank_by_row(row, prio, alive, num_rows, prio_is_iota):
    """Rank each alive claimer among same-row claimers by priority.

    Fast form: pack (row, prio) into one u32 and run XLA's payload-free
    sort; the element is recovered from the priority half of the packed
    word.  Falls back to a two-key sort when num_rows * n overflows u32.
    ``prio_is_iota`` (static) marks the batch-order case where the
    priority IS the element index, skipping the final permutation gather.
    """
    n = prio.shape[0]
    pos = jnp.arange(n, dtype=_I)
    if int(num_rows) * n < 2 ** 32:
        sent = _U(2 ** 32 - 1)
        packed = jnp.where(alive, row * _U(n) + prio, sent)
        sp = jnp.sort(packed)
        srow = sp // _U(n)
        tgt = jnp.where(sp == sent, _U(n), sp % _U(n))   # element id (prio)
    else:
        grp = jnp.where(alive, row, _U(num_rows))
        srow, sprio, _ = jax.lax.sort(
            (grp, prio, jnp.arange(n, dtype=_U)), num_keys=2)
        tgt = jnp.where(srow == _U(num_rows), _U(n), sprio)
    newrow = jnp.concatenate([jnp.ones((1,), bool), srow[1:] != srow[:-1]])
    rank_sorted = pos - jax.lax.cummax(jnp.where(newrow, pos, -1))
    by_prio = jnp.zeros((n,), _I).at[tgt].set(rank_sorted, mode="drop")
    return by_prio if prio_is_iota else by_prio[prio]


def _nth_set_lane(mask32, rank, window):
    """Lane index of the ``rank``-th set bit of a per-element u32 candidate
    bitmask — a 5-step popcount binary search, all (n,)-elementwise ops
    (the vector analogue of __fns on a ballot mask).  Requires W <= 32."""
    lane = jnp.zeros(rank.shape, _I)
    cur = mask32
    r = rank
    for shift in (16, 8, 4, 2, 1):
        if shift >= window:
            continue
        low = cur & _U((1 << shift) - 1)
        c = jax.lax.population_count(low).astype(_I)
        hi = r >= c
        r = r - jnp.where(hi, c, 0)
        lane = lane + jnp.where(hi, shift, 0)
        cur = jnp.where(hi, cur >> shift, low)
    return lane


def place_claims(tstatic, store, words, claim, prio, prio_is_iota=False,
                 stats=False):
    """Assign every claimer a slot — or FULL — via the virtual-fill fixpoint.

    Per sweep, claimers targeting a row are ranked by ``prio`` (original
    batch position = sequential insert order); rank k takes the k-th lowest
    free lane, ranks past the row's free-lane count bump to the next
    candidate row of their own probe sequence.  A bumped claimer may oust a
    higher-priority tentative occupant of its new row in the following
    sweep, so the fixpoint converges to the priority-greedy (= sequential)
    assignment.  Returns (placed, row, lane, full).

    ``stats`` (static) appends two telemetry outputs — the per-element
    final probe attempt (rows examined, = the claimer's probe length) and
    the number of fixpoint sweeps run — without touching the stats-off
    graph (the per-element attempt is already in the carry; only the sweep
    counter is added, gated on the python flag).
    """
    ops, scheme, seed, max_probes = tstatic
    num_rows, w = ops.num_rows, ops.window
    n = prio.shape[0]
    kp0 = ops.key_planes(store)[0]                            # (p, W)
    cand = (kp0 == EMPTY_KEY) | (kp0 == TOMBSTONE_KEY)
    if w <= 32:
        # pack each row's candidate lanes into one u32 ballot mask
        bits = jax.lax.broadcasted_iota(_U, cand.shape, 1)
        cmask = jnp.sum(jnp.where(cand, _U(1) << bits, _U(0)), axis=1)
        n_cand = jax.lax.population_count(cmask).astype(_I)   # (p,)
    else:
        cmask = None
        n_cand = jnp.sum(cand.astype(_I), axis=1)             # (p,)
    row0 = probing.initial_row(words, num_rows, seed, ops.quotient)
    step = probing.row_step(scheme, words, num_rows, seed, ops.quotient)

    def advance(attempt, row, move, full):
        """Advance bumped claimers to their next row with any free lane."""
        def cond(st):
            attempt, row, pending = st
            return jnp.any(pending)

        def body(st):
            attempt, row, pending = st
            # attempt is 1-based (examined rows); advance_row wants the
            # 0-based index of the row being left (quadratic increments).
            nrow = probing.advance_row(scheme, row, step, attempt - 1,
                                       num_rows)
            row = jnp.where(pending, nrow, row)
            attempt = attempt + pending.astype(_I)
            pending = pending & (attempt < max_probes) & (n_cand[row] == 0)
            return attempt, row, pending

        attempt, row, _ = jax.lax.while_loop(cond, body,
                                             (attempt, row, move & ~full))
        # a claimer may sit at attempt == max_probes (the scan examines
        # exactly max_probes rows); past that, or stranded on a
        # candidate-free row, it is FULL.
        full = full | (move & ((attempt > max_probes) | (n_cand[row] == 0)))
        return attempt, row, full

    def cond(st):
        attempt, row, full, rank, over = st[:5]
        return jnp.any(over)

    def body(st):
        if stats:
            attempt, row, full, rank, over, sweeps = st
        else:
            attempt, row, full, rank, over = st
        attempt, row, full = advance(attempt, row, over, full)
        alive = claim & ~full
        rank = _rank_by_row(row, prio, alive, num_rows, prio_is_iota)
        over = alive & (rank >= n_cand[row])
        out = (attempt, row, full, rank, over)
        return out + ((sweeps + 1,) if stats else ())

    attempt0 = jnp.ones((n,), _I)
    full0 = claim & (max_probes < 1)
    rank0 = _rank_by_row(row0, prio, claim & ~full0, num_rows, prio_is_iota)
    over0 = claim & ~full0 & (rank0 >= n_cand[row0])
    st = (attempt0, row0, full0, rank0, over0)
    if stats:
        st = st + (jnp.zeros((), _I),)
    res = jax.lax.while_loop(cond, body, st)
    attempt, row, full, rank = res[0], res[1], res[2], res[3]
    placed = claim & ~full
    # rank-th lowest free lane of the assigned row
    if cmask is not None:
        lane = _nth_set_lane(cmask[row], rank, w)
    else:
        crow = cand[row]                                      # (n, W)
        crank = jnp.cumsum(crow.astype(_I), axis=1) - 1
        lanes = jax.lax.broadcasted_iota(_I, crow.shape, 1)
        lane = jnp.min(jnp.where(crow & (crank == rank[:, None]), lanes,
                                 _I(w)), axis=1)
    out = (placed, row, jnp.where(placed, lane, 0).astype(_U), full)
    if stats:
        return out + (jnp.clip(attempt, 0, max_probes), res[5])
    return out


def arbitrate(row, lane, claim, prio, num_rows, window):
    """Scatter-min slot arbitration: at most one claimer wins each
    (row, lane) slot.  Virtual-fill assignments are distinct by
    construction — (row, rank) pairs are unique — so this arena is the
    cross-check the parity suite runs over every placement, rather than a
    hot-path pass."""
    cap = num_rows * window
    slot = jnp.where(claim, row.astype(_I) * window + lane.astype(_I), cap)
    arena = jnp.full((cap + 1,), EMPTY_KEY, _U).at[slot].min(prio)
    return claim & (arena[slot] == prio)


# ---------------------------------------------------------------------------
# step 4 — batched apply
# ---------------------------------------------------------------------------

def _apply(table, keys, matched, mrow, mlane, placed, crow, clane,
           matched_vals, claim_vals):
    """One write phase: matched value scatters + placed key/value scatters.

    The batched scatter itself lives in the store protocol
    (``StoreOps.scatter_batch``): SOA scatters flattened planes (XLA's 1-D
    fast path), AOS composes the per-kind scatters.
    """
    oor = _U(table.num_rows)
    row = jnp.where(matched, mrow, crow)
    lane = jnp.where(matched, mlane, clane)
    vals = jnp.where(matched[:, None], matched_vals, claim_vals)
    vrow = jnp.where(matched | placed, row, oor)
    store = table.ops.scatter_batch(table.store, vrow, lane, keys, vals,
                                    placed)
    return store, jnp.sum(placed, dtype=_I)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _write_keys(table, keys, words, crow):
    """Key planes to scatter for placed claimers.

    Raw keys for every store except quotient, which writes the encoded
    remainder ``q*2 + choice``; ``choice`` falls out of the claim row
    (claim_row != first bucket — the bucketed walk has only two rows).
    """
    if not table.ops.quotient:
        return keys
    p = table.ops.num_rows
    row0 = probing.initial_row(words, p, table.seed, quotient=True)
    choice = (crow != row0)
    return probing.stored_word(words, p, choice, quotient=True)[:, None]


def _walk_plen(matched, probe_plen, claim_attempt, max_probes):
    """Per-element walk length: match-walk windows for matched elements,
    final placement attempt for claimers (clipped to max_probes)."""
    return jnp.where(matched, probe_plen,
                     jnp.clip(claim_attempt, 0, max_probes))


def _build_stats(table, status, plen, active, sweeps):
    """Assemble the in-graph TableStats for a build op (post-op table)."""
    from repro.obs import metrics
    return metrics.table_stats(table.ops, table.store, status=status,
                               plen=plen, active=active,
                               fixpoint_iters=sweeps)


def _finish_fast(table, keys, live, is_rep, rep_of, matched, mrow, mlane,
                 placed, crow, clane, matched_vals, claim_vals):
    """Shared tail of the fast lane: apply + statuses in batch order."""
    store, claimed = _apply(table, keys, matched, mrow, mlane, placed, crow,
                            clane, matched_vals, claim_vals)
    rep_ok = (matched | placed)[rep_of]
    status = jnp.where(
        ~live, _I(STATUS_MASKED),
        jnp.where(matched, _I(STATUS_UPDATED),
                  jnp.where(placed, _I(STATUS_INSERTED),
                            jnp.where(is_rep, _I(STATUS_FULL),
                                      jnp.where(rep_ok, _I(STATUS_UPDATED),
                                                _I(STATUS_FULL))))))
    return dataclasses.replace(table, store=store,
                               count=table.count + claimed), status


def insert_single(table, keys, values, mask=None, stats=False):
    """Bulk path for ``single_value.insert`` (plain upsert, LWW dedup).

    ``stats=True`` (static) returns ``(table, status, TableStats)`` with
    the telemetry accumulated inside the same graph; the default graph is
    untouched."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    values = sv.normalize_words(values, table.value_words, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    tstat = _tstatic(table)
    if table.key_words != 1:
        return _insert_general(table, tstat, keys, values, mask, stats=stats)
    is_rep, rep_of, lww_of, _, _ = _group_fast(keys[:, 0], mask)
    words = sv.probe_words(table, keys)
    pm = probe_matches(tstat, table.store, keys, words, is_rep, table.count,
                       stats=stats)
    matched, mrow, mlane = pm[:3]
    pc = place_claims(tstat, table.store, words, is_rep & ~matched,
                      jnp.arange(n, dtype=_U), prio_is_iota=True, stats=stats)
    placed, crow, clane = pc[0], pc[1], pc[2]
    lww = values[lww_of]                         # group's last live writer
    wkeys = _write_keys(table, keys, words, crow)
    out = _finish_fast(table, wkeys, mask, is_rep, rep_of, matched, mrow,
                       mlane, placed, crow, clane, lww, lww)
    if not stats:
        return out
    ntable, status = out
    plen = _walk_plen(matched, pm[3], pc[4], tstat[3])
    return ntable, status, _build_stats(ntable, status, plen, is_rep, pc[5])


def update_single(table, keys, update_fn, combine, init, values, mask=None,
                  stats=False):
    """Bulk path for ``single_value.update_values`` (RMW upsert).

    ``combine`` must be the associative pre-aggregation of the operand
    stream: ``update_fn(update_fn(x, k, a), k, b) ==
    update_fn(x, k, combine(a, b))`` — sum/min/max/saturating-count all
    qualify.  A per-word spec tuple (e.g. ``("min", "add")``) runs the
    scatter-reduce fast lane; a callable runs the general sorted lane.
    Groups fold their operands before any store access; absent keys write
    ``update_fn(init_first, k, tail)`` exactly as the sequential chain
    would.
    """
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    tstat = _tstatic(table)
    is_spec = not callable(combine)
    if table.key_words != 1 or not is_spec:
        cmb = combine_callable(combine) if is_spec else combine
        return _update_general(table, tstat, keys, update_fn, cmb, init,
                               values, mask, stats=stats)
    spec = tuple(combine)
    vw = table.value_words
    vfold = jax.vmap(update_fn)
    is_rep, rep_of, lww_of, gid, has_dups = _group_fast(keys[:, 0], mask)
    words = sv.probe_words(table, keys)
    pm = probe_matches(tstat, table.store, keys, words, is_rep, table.count,
                       stats=stats)
    matched, mrow, mlane = pm[:3]
    pc = place_claims(tstat, table.store, words, is_rep & ~matched,
                      jnp.arange(n, dtype=_U), prio_is_iota=True, stats=stats)
    placed, crow, clane = pc[0], pc[1], pc[2]

    def folded(_):
        # agg_all = fold of every live operand (applied to the stored value
        # on match); agg_tail = fold of all but the first (applied to the
        # first element's init on claim: sequentially the claim writes init
        # and later duplicates fold into it).
        agg_all = _scatter_combine(spec, gid, values, mask)
        agg_tail = _scatter_combine(spec, gid, values, mask & ~is_rep)
        has_tail = lww_of != rep_of
        claim_vals = jnp.where(has_tail[:, None],
                               vfold(init[rep_of], keys, agg_tail),
                               init[rep_of])
        return agg_all, claim_vals

    def plain(_):
        return values, init

    agg_all, claim_vals = jax.lax.cond(has_dups, folded, plain, None)
    old = table.ops.value_windows(table.store, mrow)           # (n, vw, W)
    old = jnp.take_along_axis(
        old, mlane.astype(_I)[:, None, None], axis=2)[:, :, 0]
    matched_vals = vfold(old, keys, agg_all)
    wkeys = _write_keys(table, keys, words, crow)
    out = _finish_fast(table, wkeys, mask, is_rep, rep_of, matched, mrow,
                       mlane, placed, crow, clane, matched_vals, claim_vals)
    if not stats:
        return out
    ntable, status = out
    plen = _walk_plen(matched, pm[3], pc[4], tstat[3])
    return ntable, status, _build_stats(ntable, status, plen, is_rep, pc[5])


def insert_multi(table, keys, values, mask=None, stats=False):
    """Bulk path for ``multi_value.insert`` (append; no dedup — every live
    element is a claimer, duplicates of a key contend for slots and the
    fixpoint resolves them in batch order)."""
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    values = sv.normalize_words(values, table.value_words, "values")
    n = keys.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    words = sv.key_hash_word(keys)
    tstat = _tstatic(table)
    pc = place_claims(tstat, table.store, words, mask,
                      jnp.arange(n, dtype=_U), prio_is_iota=True, stats=stats)
    placed, row, lane = pc[0], pc[1], pc[2]
    wrow = jnp.where(placed, row, _U(table.num_rows))
    store = table.ops.scatter_batch(table.store, wrow, lane, keys, values,
                                    placed)
    status = jnp.where(~mask, _I(STATUS_MASKED),
                       jnp.where(placed, _I(STATUS_INSERTED),
                                 _I(STATUS_FULL)))
    ntable = dataclasses.replace(
        table, store=store, count=table.count + jnp.sum(placed, dtype=_I))
    if not stats:
        return ntable, status
    plen = jnp.clip(pc[4], 0, tstat[3])
    return ntable, status, _build_stats(ntable, status, plen, mask, pc[5])


# ---------------------------------------------------------------------------
# general lane (u64 two-plane keys, arbitrary combiner callables)
# ---------------------------------------------------------------------------

def _statuses_sorted(n, live, is_rep, first_pos, matched, placed, sidx):
    """Fast-lane statuses, but in the sorted domain + unsort scatter."""
    rep_ok = (matched | placed)[first_pos]
    rep_status = jnp.where(matched, _I(STATUS_UPDATED),
                           jnp.where(placed, _I(STATUS_INSERTED),
                                     _I(STATUS_FULL)))
    dup_status = jnp.where(rep_ok, _I(STATUS_UPDATED), _I(STATUS_FULL))
    status = jnp.where(~live, _I(STATUS_MASKED),
                       jnp.where(is_rep, rep_status, dup_status))
    return jnp.zeros((n,), _I).at[sidx].set(status)


def _insert_general(table, tstat, keys, values, mask, stats=False):
    from repro.core import single_value as sv
    n = keys.shape[0]
    vw = table.value_words
    flag, skeys, sidx, vcols = _sort_batch(
        keys, mask, [values[:, w] for w in range(vw)])
    svals = (jnp.stack(vcols, axis=1) if vw else jnp.zeros((n, 0), _U))
    live, is_rep, first_pos, last_pos = _group_structure(flag, skeys)
    lww = svals[last_pos]
    swords = sv.key_hash_word(skeys)
    pm = probe_matches(tstat, table.store, skeys, swords, is_rep,
                       table.count, stats=stats)
    matched, mrow, mlane = pm[:3]
    pc = place_claims(tstat, table.store, swords, is_rep & ~matched, sidx,
                      stats=stats)
    placed, crow, clane = pc[0], pc[1], pc[2]
    store, claimed = _apply(table, skeys, matched, mrow, mlane, placed,
                            crow, clane, lww, lww)
    status = _statuses_sorted(n, live, is_rep, first_pos, matched, placed,
                              sidx)
    ntable = dataclasses.replace(table, store=store,
                                 count=table.count + claimed)
    if not stats:
        return ntable, status
    plen = _walk_plen(matched, pm[3], pc[4], tstat[3])
    return ntable, status, _build_stats(ntable, status, plen, is_rep, pc[5])


def _update_general(table, tstat, keys, update_fn, combine, init, values,
                    mask, stats=False):
    from repro.core import single_value as sv
    n = keys.shape[0]
    vw = table.value_words
    cols = ([values[:, w] for w in range(vw)]
            + [init[:, w] for w in range(vw)])
    flag, skeys, sidx, scols = _sort_batch(keys, mask, cols)
    svals = jnp.stack(scols[:vw], axis=1) if vw else jnp.zeros((n, 0), _U)
    sinit = jnp.stack(scols[vw:], axis=1) if vw else jnp.zeros((n, 0), _U)
    live, is_rep, first_pos, last_pos = _group_structure(flag, skeys)
    swords = sv.key_hash_word(skeys)
    vfold = jax.vmap(update_fn)

    runstart = jnp.arange(n, dtype=_I) == first_pos
    rank1 = jnp.concatenate([jnp.zeros((1,), bool), runstart[:-1]]) & ~runstart
    agg_all = _segmented_combine(svals, runstart, combine)[last_pos]
    agg_tail = _segmented_combine(svals, rank1, combine)[last_pos]
    group_m = last_pos - first_pos + 1
    claim_vals = jnp.where((group_m >= 2)[:, None],
                           vfold(sinit, skeys, agg_tail), sinit)
    claim_vals = claim_vals[first_pos]

    pm = probe_matches(tstat, table.store, skeys, swords, is_rep,
                       table.count, stats=stats)
    matched, mrow, mlane = pm[:3]
    pc = place_claims(tstat, table.store, swords, is_rep & ~matched, sidx,
                      stats=stats)
    placed, crow, clane = pc[0], pc[1], pc[2]
    old = table.ops.value_windows(table.store, mrow)
    old = jnp.take_along_axis(
        old, mlane.astype(_I)[:, None, None], axis=2)[:, :, 0]
    matched_vals = vfold(old, skeys, agg_all)
    store, claimed = _apply(table, skeys, matched, mrow, mlane, placed,
                            crow, clane, matched_vals, claim_vals)
    status = _statuses_sorted(n, live, is_rep, first_pos, matched, placed,
                              sidx)
    ntable = dataclasses.replace(table, store=store,
                                 count=table.count + claimed)
    if not stats:
        return ntable, status
    plen = _walk_plen(matched, pm[3], pc[4], tstat[3])
    return ntable, status, _build_stats(ntable, status, plen, is_rep, pc[5])
