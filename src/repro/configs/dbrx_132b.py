"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752, num_shared=0),
    norm_type="layernorm", mlp_kind="swiglu",
    source="hf:databricks/dbrx-base; unverified",
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, num_shared=0),
    norm_type="layernorm", mlp_kind="swiglu",
)
