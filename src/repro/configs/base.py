"""Config system: ModelConfig + input-shape cells + registry.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published configuration) and ``SMOKE`` (a reduced
same-family variant for CPU tests).  ``repro.configs.registry`` maps
``--arch <id>`` to them.

Shape cells (assigned set, applies to every LM arch):
  train_4k     seq 4096   global_batch 256   train_step
  prefill_32k  seq 32768  global_batch 32    prefill (inference forward)
  decode_32k   seq 32768  global_batch 128   serve_step, 1 token + 32k cache
  long_500k    seq 524288 global_batch 1     serve_step; sub-quadratic only
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    nope_dim: int = 128
    rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | audio | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1           # jamba: one attention layer per this many
    moe_every: int = 1            # jamba: MoE each this-many sublayers, dense MLP else
    encoder_layers: int = 0       # whisper
    frontend: Optional[str] = None  # 'audio_frames' | 'vit_patches' (stubs)
    frontend_len: int = 0         # frames/patches per example
    norm_type: str = "rmsnorm"
    mlp_kind: str = "swiglu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # RWKV
    rwkv_heads: int = 0
    # activation rematerialization: none | block | dots (checkpoint policy
    # applied to each scanned block during training)
    remat: str = "none"
    # sequence parallelism: shard the (B, S, D) residual stream's S dim over
    # the model axis between blocks (Megatron-SP).  Divides the per-chip
    # saved-activation footprint by the TP width; GSPMD inserts the
    # all-gather before attention and the reduce-scatter after.
    seq_shard_activations: bool = False
    # pad attention heads to this TP width so the head dim shards over the
    # model axis (0 = off).  Critical when num_heads % TP != 0 — otherwise
    # attention replicates across all TP columns (see §Perf cell 1).
    attn_tp_pad: int = 0
    # source + verification tier from the assignment table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode step (none encoder-only)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for 6ND math."""
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if self.mla is not None:
            m = self.mla
            qh = m.nope_dim + m.rope_dim
            per_layer_attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qh
                              + d * m.kv_lora_rank + d * m.rope_dim
                              + m.kv_lora_rank * self.num_heads * (m.nope_dim + m.v_head_dim)
                              + self.num_heads * m.v_head_dim * d)
        elif self.family == "ssm":
            per_layer_attn = 0
        else:
            per_layer_attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                              + self.num_heads * hd * d)
        # ffn
        if self.moe is not None:
            e = self.moe
            ffn = (e.num_experts + e.num_shared) * 3 * d * e.d_ff_expert
        elif self.mlp_kind == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.family == "ssm":
            di = d * 2
            per_layer_attn = d * 2 * di + 2 * d * d * 0  # rwkv approximated below
            per_layer_attn = 6 * d * d                    # r,k,v,g,o + decay loras
            ffn = 2 * d * self.d_ff
        if self.family == "hybrid" and self.ssm is not None:
            # attn_every layers share: 1 attention + (attn_every-1) mamba
            di = self.ssm.expand * d
            mamba = (d * 2 * di + di * (max(1, d // 16) + 2 * self.ssm.d_state)
                     + max(1, d // 16) * di + di * d)
            frac_attn = 1.0 / self.attn_every
            per_layer_attn = per_layer_attn * frac_attn + mamba * (1 - frac_attn)
            if self.moe is not None and self.moe_every > 1:
                # MoE on 1/moe_every of sublayers, dense swiglu on the rest
                e = self.moe
                moe_ffn = (e.num_experts + e.num_shared) * 3 * d * e.d_ff_expert
                dense_ffn = 3 * d * self.d_ff
                f = 1.0 / self.moe_every
                ffn = moe_ffn * f + dense_ffn * (1 - f)
        total = emb + l * (per_layer_attn + ffn)
        if self.encoder_layers:
            total += self.encoder_layers * (per_layer_attn + ffn)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        full_ffn = (e.num_experts + e.num_shared) * 3 * self.d_model * e.d_ff_expert
        act_ffn = (e.top_k + e.num_shared) * 3 * self.d_model * e.d_ff_expert
        n_moe_layers = self.num_layers // self.moe_every
        return int(self.param_count() - n_moe_layers * (full_ffn - act_ffn))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for sub-quadratic families (full-attention skip is
    recorded in DESIGN.md §4 and EXPERIMENTS.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        out.append("long_500k")
    return out
