"""jamba-1.5-large-398b — hybrid Mamba+attention (1 attn per 8 layers) with
16-expert top-2 MoE [arXiv:2403.19887; hf].  Sub-quadratic: runs long_500k."""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, num_shared=0),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8, moe_every=2,        # MoE every other sublayer (398B/94B active)
    norm_type="rmsnorm", mlp_kind="swiglu",
    source="arXiv:2403.19887; hf",
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, num_shared=0),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    attn_every=4, moe_every=2,
    norm_type="rmsnorm", mlp_kind="swiglu",
)
