"""mistral-large-123b — dense GQA decoder [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    norm_type="rmsnorm", mlp_kind="swiglu",
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke", family="dense",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=224, vocab_size=256, head_dim=16,
    norm_type="rmsnorm", mlp_kind="swiglu",
)
