"""Architecture config registry: ``--arch <id>`` -> ModelConfig."""

from repro.configs import (
    base,
    dbrx_132b,
    deepseek_v2_236b,
    internvl2_1b,
    jamba_1_5_large_398b,
    mistral_large_123b,
    olmo_1b,
    rwkv6_3b,
    smollm_360m,
    starcoder2_3b,
    warpcore,
    whisper_small,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeCell, applicable_shapes

_MODULES = {
    "smollm-360m": smollm_360m,
    "mistral-large-123b": mistral_large_123b,
    "starcoder2-3b": starcoder2_3b,
    "olmo-1b": olmo_1b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "dbrx-132b": dbrx_132b,
    "whisper-small": whisper_small,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "rwkv6-3b": rwkv6_3b,
    "internvl2-1b": internvl2_1b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeCell",
           "applicable_shapes", "get_config", "get_smoke_config", "base",
           "warpcore"]
