"""olmo-1b — dense LM with non-parametric LayerNorm [arXiv:2402.00838; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50304, head_dim=128,
    norm_type="nonparametric_ln", mlp_kind="swiglu", tie_embeddings=True,
    source="arXiv:2402.00838; hf",
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256, head_dim=16,
    norm_type="nonparametric_ln", mlp_kind="swiglu", tie_embeddings=True,
)
