"""smollm-360m — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, head_dim=64,
    norm_type="rmsnorm", mlp_kind="swiglu", tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    norm_type="rmsnorm", mlp_kind="swiglu", tie_embeddings=True,
)
