"""whisper-small — encoder-decoder [arXiv:2212.04356; unverified].

Conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed (B, 1500, d_model) frame embeddings; only the transformer
backbone is modeled.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_layers=12, frontend="audio_frames", frontend_len=1500,
    norm_type="layernorm", mlp_kind="gelu",
    source="arXiv:2212.04356; unverified",
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    encoder_layers=2, frontend="audio_frames", frontend_len=32,
    norm_type="layernorm", mlp_kind="gelu",
)
