"""starcoder2-3b — GQA + RoPE code LM [arXiv:2402.19173; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    norm_type="layernorm", mlp_kind="gelu",
    source="arXiv:2402.19173; hf",
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=16,
    norm_type="layernorm", mlp_kind="gelu",
)
