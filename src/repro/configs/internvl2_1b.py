"""internvl2-1b — InternViT + InternLM2/Qwen2-0.5B backbone [arXiv:2404.16821; hf].

The ViT frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed (B, 256, d_model) patch embeddings prepended to the token
sequence; only the LM backbone is modeled.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    frontend="vit_patches", frontend_len=256,
    norm_type="rmsnorm", mlp_kind="swiglu", rope_theta=1000000.0,
    source="arXiv:2404.16821; hf",
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    frontend="vit_patches", frontend_len=8,
    norm_type="rmsnorm", mlp_kind="swiglu",
)
