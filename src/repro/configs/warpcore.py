"""warpcore — the paper's own workload configs (§V benchmarks).

Not an LM architecture: these parameterize the hash-table benchmark and
example drivers (table capacities, load factors, key multiplicities,
bucket-list growth), scaled for the CPU container with the paper's 2^28
GPU-scale numbers recorded alongside for reference.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class TableBenchConfig:
    name: str
    n_pairs: int                   # batch size of the bulk op
    densities: tuple               # target storage densities (paper x-axis)
    window: int = 32               # probe window (CG-size analogue)
    multiplicities: tuple = (1, 2, 4, 8, 16, 32, 64)   # Fig 7 r values
    bl_growth_default: tuple = (1.1, 1)                # (lambda, s0) "BL (1)"
    # paper scale, for the derived-throughput comparison in benchmarks
    paper_n_pairs: int = 2 ** 28


# CPU-container scale (pure-algorithm validity; perf numbers are derived
# per-op and compared in shape, not magnitude, to the paper's GV100 curves)
CONFIG = TableBenchConfig(
    name="warpcore-bench",
    n_pairs=2 ** 14,
    densities=(0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 0.97),
)

SMOKE = TableBenchConfig(
    name="warpcore-smoke",
    n_pairs=2 ** 10,
    densities=(0.5, 0.8),
)
