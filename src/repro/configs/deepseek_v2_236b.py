"""deepseek-v2-236b — MLA (kv_lora=512) + 160-expert top-6 MoE with 2 shared
experts [arXiv:2405.04434; hf].

d_ff=1536 is the per-expert FFN width (the assignment's d_ff field); the
spec's kv=128 reflects MLA exposing one latent per head pre-compression.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400, head_dim=128,
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, nope_dim=128,
                  rope_dim=64, v_head_dim=128),
    norm_type="rmsnorm", mlp_kind="swiglu",
    source="arXiv:2405.04434; hf",
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, num_shared=1),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, nope_dim=16,
                  rope_dim=8, v_head_dim=16),
    norm_type="rmsnorm", mlp_kind="swiglu",
)
