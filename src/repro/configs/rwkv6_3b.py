"""rwkv6-3b — Finch: attention-free, data-dependent decay [arXiv:2404.05892; hf].

Sub-quadratic (O(1) decode state): runs long_500k.  The hash-table KV-cache
serving feature is inapplicable to this family (no KV cache) — noted in
DESIGN.md §4.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=8960, vocab_size=65536,
    rwkv_heads=40,                      # head size 64
    norm_type="layernorm", mlp_kind="relu2",
    source="arXiv:2404.05892; hf",
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=128, vocab_size=256,
    rwkv_heads=4,
    norm_type="layernorm", mlp_kind="relu2",
)
