"""Render EXPERIMENTS.md tables from dry-run JSONL records.

Besides the dry-run/roofline tables this renders two telemetry sections:

- ``table_metrics_section`` — the table-walk metrics a BENCH_*.json row
  carries when the benchmark ran its op with ``stats=True``
  (``probe_len_p50/p99``, ``load_factor``, ``bytes_moved``,
  ``pct_of_roofline`` — see ``benchmarks.util.table_metric_extras``);
- ``trace_section`` — span latency percentiles from a trace JSONL file
  written by ``obs.trace.Tracer`` (the schema is shared: ``EVENT_FIELDS``).

Input files may interleave record kinds (a dry-run sweep appending trace
events to the same JSONL, partial reruns missing ``roofline`` because the
census step was skipped): ``load`` keeps only well-formed dry-run records
and every table guards the optional fields instead of KeyError-ing.
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict

_DRYRUN_KEYS = ("arch", "shape", "mesh")


def load(path: str) -> list[dict]:
    """Dry-run records from a JSONL file (latest per (arch, shape, mesh)).

    Lines that are not dry-run records — trace events (``obs.trace``
    schema) or malformed partials missing the identity keys — are skipped,
    not fatal."""
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns supersede
    seen = {}
    for r in out:
        if all(k in r for k in _DRYRUN_KEYS):
            seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def meshes(recs: list[dict]) -> list[str]:
    """Distinct meshes present in the records, smallest first."""
    def key(m: str):
        try:
            return ([int(x) for x in m.split("x")], m)
        except ValueError:
            return ([1 << 30], m)
    return sorted({r["mesh"] for r in recs}, key=key)


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    rows = ["| arch | shape | mesh | kind | compile s | temp GiB/chip | "
            "args GiB/chip | FLOPs/dev | HBM bytes/dev | wire bytes/dev | "
            "dominant collective |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r.get("roofline")
        chips = max(r.get("chips", 1), 1)
        if rl:
            coll = rl.get("collectives", {}).get("bytes", {})
            dom = max(coll, key=coll.get) if coll else "none"
            census = (f"{rl['flops_per_device']:.2e} | "
                      f"{rl['bytes_per_device']:.2e} | "
                      f"{rl['wire_bytes']:.2e} | {dom}")
        else:
            census = "— | — | — | —"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('kind', '?')} | {r.get('compile_s', '—')} | "
            f"{fmt_bytes(r.get('temp_size_in_bytes', 0) / chips)} | "
            f"{fmt_bytes(r.get('argument_size_in_bytes', 0) / chips)} | "
            f"{census} |")
    return "\n".join(rows)


def roofline_table(recs: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPS | useful ratio | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted((r for r in recs
                     if r["mesh"] == mesh and r.get("roofline")),
                    key=lambda r: (r["arch"], r["shape"])):
        rl = r["roofline"]
        note = _note(rl)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['bottleneck']}** | {rl['model_flops']:.2e} | "
            f"{rl['useful_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def _note(rl: dict) -> str:
    b = rl["bottleneck"]
    if b == "memory":
        return "cut HBM traffic: fuse/remat-policy/layout"
    if b == "collective":
        coll = rl.get("collectives", {}).get("bytes", {})
        dom = max(coll, key=coll.get) if coll else "?"
        return f"dominant {dom}: reshard to shrink it"
    if rl["useful_ratio"] < 0.3:
        return "redundant compute: fix replication/remat"
    return "near-roofline compute"


# ---------------------------------------------------------------------------
# telemetry sections
# ---------------------------------------------------------------------------

_METRIC_COLS = ("probe_len_p50", "probe_len_p99", "load_factor",
                "pct_of_roofline", "spread")


def table_metrics_section(bench_path: str) -> str:
    """Table-walk metrics of a BENCH_*.json: one row per benchmark row
    that carried stats extras (others are omitted, not an error)."""
    with open(bench_path) as f:
        bench = json.load(f)
    rows = ["| figure | row | Mops/s | p50 probe | p99 probe | load | "
            "% roofline | spread |",
            "|---|---|---|---|---|---|---|---|"]
    found = 0
    for fig, entries in bench.items():
        for e in entries:
            if not any(c in e for c in _METRIC_COLS):
                continue
            found += 1
            def g(c, fmt="{:.3g}"):
                return fmt.format(e[c]) if c in e else "—"
            mops = (f"{e['ops_per_s'] / 1e6:.2f}"
                    if "ops_per_s" in e else "—")
            noisy = " (noisy)" if e.get("noisy") else ""
            rows.append(
                f"| {fig} | {e['name']} | {mops} | {g('probe_len_p50')} | "
                f"{g('probe_len_p99')} | {g('load_factor')} | "
                f"{g('pct_of_roofline')} | {g('spread')}{noisy} |")
    if not found:
        return f"(no table-metric rows in {bench_path})"
    return "\n".join(rows)


def trace_section(trace_path: str) -> str:
    """Latency percentiles per span name from a Tracer JSONL file."""
    from repro.obs import trace as _trace
    events = _trace.load_events(trace_path)
    by_name: dict[str, list[float]] = defaultdict(list)
    for e in events:
        by_name[e["event"]].append(float(e["dur_s"]))
    import numpy as np
    rows = ["| span | n | p50 ms | p95 ms | p99 ms | total s |",
            "|---|---|---|---|---|---|"]
    for name in sorted(by_name):
        d = np.asarray(by_name[name])
        rows.append(
            f"| {name} | {d.size} | {np.percentile(d, 50) * 1e3:.3f} | "
            f"{np.percentile(d, 95) * 1e3:.3f} | "
            f"{np.percentile(d, 99) * 1e3:.3f} | {d.sum():.3f} |")
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", nargs="?", default="results/dryrun_all.jsonl",
                    help="dry-run JSONL records")
    ap.add_argument("--bench", metavar="PATH",
                    help="BENCH_*.json to render as a table-metrics section")
    ap.add_argument("--trace", metavar="PATH",
                    help="obs.trace JSONL to render as a latency section")
    args = ap.parse_args(argv)
    import os
    recs = load(args.jsonl) if os.path.exists(args.jsonl) else []
    print(f"## Dry-run records: {len(recs)}\n")
    if recs:
        for mesh in meshes(recs):
            print(f"### Roofline ({mesh})\n")
            print(roofline_table(recs, mesh))
            print()
        print("### Full dry-run table\n")
        print(dryrun_table(recs))
    if args.bench:
        print("\n### Table metrics (roofline-normalized)\n")
        print(table_metrics_section(args.bench))
    if args.trace:
        print("\n### Span latencies\n")
        print(trace_section(args.trace))


if __name__ == "__main__":
    main()
