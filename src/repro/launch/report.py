"""Render EXPERIMENTS.md tables from dry-run JSONL records."""

from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns supersede
    seen = {}
    for r in out:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    rows = ["| arch | shape | mesh | kind | compile s | temp GiB/chip | "
            "args GiB/chip | FLOPs/dev | HBM bytes/dev | wire bytes/dev | "
            "dominant collective |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        chips = r["chips"]
        coll = rl["collectives"]["bytes"]
        dom = max(coll, key=coll.get) if coll else "none"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{r['compile_s']} | "
            f"{fmt_bytes(r.get('temp_size_in_bytes', 0) / chips)} | "
            f"{fmt_bytes(r.get('argument_size_in_bytes', 0) / chips)} | "
            f"{rl['flops_per_device']:.2e} | {rl['bytes_per_device']:.2e} | "
            f"{rl['wire_bytes']:.2e} | {dom} |")
    return "\n".join(rows)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPS | useful ratio | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted((r for r in recs if r["mesh"] == mesh),
                    key=lambda r: (r["arch"], r["shape"])):
        rl = r["roofline"]
        note = _note(rl)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['bottleneck']}** | {rl['model_flops']:.2e} | "
            f"{rl['useful_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def _note(rl: dict) -> str:
    b = rl["bottleneck"]
    if b == "memory":
        return "cut HBM traffic: fuse/remat-policy/layout"
    if b == "collective":
        coll = rl["collectives"]["bytes"]
        dom = max(coll, key=coll.get) if coll else "?"
        return f"dominant {dom}: reshard to shrink it"
    if rl["useful_ratio"] < 0.3:
        return "redundant compute: fix replication/remat"
    return "near-roofline compute"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_all.jsonl"
    recs = load(path)
    print(f"## Dry-run records: {len(recs)}\n")
    print("### Single-pod roofline (16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n### Multi-pod roofline (2x16x16)\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n### Full dry-run table\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
