import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN structure at production scale: the
distributed hash table (multisplit + all-to-all + COPS insert, §IV-E)
lowered and compiled for the 256-chip and 512-chip meshes.

Each chip owns one table shard (ownership partitioning — the correctness
mechanism that replaces atomicCAS on TPU, DESIGN.md §2); a global bulk
insert/retrieve batch is routed by hash_owner over the full mesh via
all-to-all.  This is the hash-table analogue of the LM dry-run: proof that
the paper's communication pattern compiles, fits, and what it costs.

    PYTHONPATH=src python -m repro.launch.dryrun_table --mesh both \
        --log-batch 24 --log-capacity 22
"""

import argparse
import sys
import time

import jax

from repro.core.compat import set_mesh_compat, shard_map_compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed as dist
from repro.core import single_value as sv
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh


def table_specs(mesh, capacity_per_shard: int, window: int):
    """ShapeDtypeStruct pytree for a 1-table-shard-per-chip table."""
    num = int(mesh.devices.size)

    def mk():
        t = sv.create(capacity_per_shard, window=window)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (num,) + x.shape), t)

    template = jax.eval_shape(mk)
    axes = tuple(mesh.axis_names)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, P(axes, *([None] * (len(s.shape) - 1)))),
        template)
    return template, shardings, axes


def lower_table_ops(multi_pod: bool, log_batch: int, log_capacity: int,
                    window: int, slack: float = 2.0):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    n = 1 << log_batch
    template, shardings, axes = table_specs(mesh, 1 << log_capacity, window)
    keys = jax.ShapeDtypeStruct((n,), jnp.uint32)
    vals = jax.ShapeDtypeStruct((n,), jnp.uint32)
    batch_sh = NamedSharding(mesh, P(axes))
    spec = jax.tree.map(lambda _: P(axes), template)

    def ins(t, k, v):
        tl = dist._local(t)
        tl, st, ov = dist.insert_distributed(tl, k, v, axes, slack)
        return dist._relift(tl), st, ov[None]

    def ret(t, k):
        v, f, ov = dist.retrieve_distributed(dist._local(t), k, axes, slack)
        return v, f, ov[None]

    results = {}
    with set_mesh_compat(mesh):
        fins = jax.jit(
            shard_map_compat(ins, mesh,
                             in_specs=(spec, P(axes), P(axes)),
                             out_specs=(spec, P(axes), P(axes))),
            in_shardings=(shardings, batch_sh, batch_sh),
            donate_argnums=(0,))
        t0 = time.time()
        compiled = fins.lower(template, keys, vals).compile()
        results["insert"] = (compiled, time.time() - t0)

        fret = jax.jit(
            shard_map_compat(ret, mesh, in_specs=(spec, P(axes)),
                             out_specs=(P(axes), P(axes), P(axes))),
            in_shardings=(shardings, batch_sh))
        t0 = time.time()
        compiled = fret.lower(template, keys).compile()
        results["retrieve"] = (compiled, time.time() - t0)
    return mesh, chips, n, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--log-batch", type=int, default=24,
                    help="log2 global keys per bulk op (2^24 = 16.7M)")
    ap.add_argument("--log-capacity", type=int, default=22,
                    help="log2 slots per shard (2^22 x 8B = 33MB/chip)")
    ap.add_argument("--window", type=int, default=32)
    args = ap.parse_args(argv)

    failures = 0
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        tag = "2x16x16" if mp else "16x16"
        try:
            mesh, chips, n, results = lower_table_ops(
                mp, args.log_batch, args.log_capacity, args.window)
            for op, (compiled, dt) in results.items():
                mem = compiled.memory_analysis()
                rl = roofline.analyze(compiled, chips=chips,
                                      model_flops=float(n))
                per_key_bytes = rl.wire_bytes * chips / n
                print(f"PASS table.{op} x {tag}: compile={dt:.1f}s "
                      f"temp/chip={mem.temp_size_in_bytes / chips / 2**20:.1f}MiB "
                      f"memory={rl.memory_s * 1e3:.2f}ms "
                      f"coll={rl.collective_s * 1e3:.2f}ms "
                      f"wire/key={per_key_bytes:.1f}B "
                      f"bottleneck={rl.bottleneck}", flush=True)
        except Exception as e:
            failures += 1
            import traceback
            print(f"FAIL table x {tag}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
