"""Serving driver: batched prefill + decode with the model facade.

Runs greedy/temperature generation for a batch of synthetic prompts on the
available devices, reporting per-phase throughput.  The paged-KV-cache path
(hash-table page table, DESIGN.md §3.3) is exercised by
``examples/paged_serving.py``; this driver uses the dense serve_step that
the dry-run lowers.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 32 --max-new 32
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.core.compat import set_mesh_compat
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as zoo
from repro.serving import serve_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = zoo.build(cfg)
    mesh = make_host_mesh()

    with set_mesh_compat(mesh):
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        gen = jax.jit(lambda p, pr: serve_loop.generate(
            model, p, pr, args.max_new, temperature=args.temperature))
        t0 = time.time()
        out = jax.block_until_ready(gen(params, prompts))
        t_first = time.time() - t0
        t0 = time.time()
        out = jax.block_until_ready(gen(params, prompts))
        t_steady = time.time() - t0
        total_new = args.batch * args.max_new
        print(f"generated {out.shape} tokens; compile+run {t_first:.2f}s, "
              f"steady {t_steady:.2f}s = {total_new / t_steady:.1f} tok/s",
              flush=True)
        print("sample:", out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
