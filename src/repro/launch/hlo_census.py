"""Loop-nest-aware HLO census: FLOPs, memory traffic, collective bytes.

XLA:CPU's ``compiled.cost_analysis()`` counts each while-loop body ONCE —
useless for scan-over-layers programs where >95% of work lives inside loops.
This module parses the optimized HLO text instead and weights every
instruction by the product of its enclosing loops' trip counts, which XLA
conveniently records as ``backend_config={"known_trip_count":{"n":...}}``
on every ``while`` op.

Census rules (per device — the module is the post-SPMD per-device program):

- FLOPs      : ``dot`` ops contribute 2 * prod(result_shape) * K where K is
               the product of the lhs contracting dims (resolved through a
               global name -> shape map).  Elementwise flops are ignored
               (<2% for transformer workloads).
- Memory     : every instruction in a non-fusion computation contributes
               result_bytes * 2 (one write + one read by its consumer) —
               fusion-internal producers stay in registers and are skipped,
               which is exactly what fusion means.  dynamic-update-slice
               (and fusions rooted in one) counts only the UPDATE operand's
               bytes: in-loop DUS aliases its buffer and writes one slice,
               so counting the full result would bill e.g. a whole 88-layer
               KV cache once per scanned layer (88x inflation, observed on
               the mistral decode cell).
- Collectives: all-gather / all-reduce / reduce-scatter / all-to-all /
               collective-permute result bytes with ring wire factors
               ((g-1)/g, doubled for all-reduce), times the loop multiplier.

Used by launch.roofline; validated against 6*N*D analytics in tests.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterator

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_DEF_RE = re.compile(r"^(?:ENTRY )?(%[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?(%[\w.\-]+) = (\([^()]*\)|[\w\[\],{}\d]+) ([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALLS_RE = re.compile(r"(?:body|calls)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_NAME_RE = re.compile(r"%[\w.\-]+")


def _operand_names(operands: str) -> list[str]:
    """Operand names of an instruction, tolerating both HLO text shapes.

    Older XLA prints bare names (``dot(%a, %b)``); 0.4.x-era XLA prefixes
    each operand with its type (``dot(f32[8,64]{1,0} %a, ...)``), where a
    naive comma split breaks on the dims inside ``[...]``.  Extracting the
    ``%name`` tokens handles both.
    """
    return _OPERAND_NAME_RE.findall(operands)


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) over all array components of an HLO type string."""
    elems = total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


@dataclasses.dataclass
class Census:
    flops: float = 0.0
    bytes_moved: float = 0.0
    wire_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)

    def dominant_collective(self) -> str:
        if not self.coll_bytes:
            return "none"
        return max(self.coll_bytes, key=self.coll_bytes.get)


_LINE_START_RE = re.compile(r"^\s*(?:ROOT )?%[\w.\-]+ = ")

# ---------------------------------------------------------------------------
# input/output aliasing (buffer donation audit)
# ---------------------------------------------------------------------------

#: one alias entry inside the HloModule header's input_output_alias={...}:
#:   {out_idx}: (param_number, {param_idx}, may-alias|must-alias)
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d, ]*)\}:\s*\((\d+),\s*\{([\d, ]*)\},\s*(may-alias|must-alias)\)")


def _idx_tuple(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x.strip())


def input_output_aliases(hlo_text: str) -> list[dict]:
    """Parse the compiled module's ``input_output_alias`` header.

    Buffer donation (``jit(..., donate_argnums=...)``) materializes as
    alias entries on the ``HloModule`` line — one per donated leaf buffer:
    ``{output_index}: (param_number, {param_index}, may-alias)``.  Returns
    one dict per entry: ``output_index`` / ``param_index`` (shape-index
    tuples into the tupled output/parameter), ``param_number`` and
    ``kind``.  Empty list == nothing aliased == every "donated" buffer is
    actually copied — the streaming engine's tests assert this list is
    non-empty and covers the table carry.
    """
    block = ""
    for line in hlo_text.splitlines():
        if "input_output_alias=" in line:
            block = line.split("input_output_alias=", 1)[1]
            break
    return [{"output_index": _idx_tuple(o), "param_number": int(p),
             "param_index": _idx_tuple(pi), "kind": kind}
            for o, p, pi, kind in _ALIAS_ENTRY_RE.findall(block)]


def donated_param_numbers(hlo_text: str) -> set[int]:
    """Parameter numbers with at least one aliased (donated) buffer."""
    return {a["param_number"] for a in input_output_aliases(hlo_text)}


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_DEF_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            # big tuple types/operand lists wrap across lines (e.g. 256-way
            # all-to-all) — merge continuations into the instruction line
            if comps[cur] and not _LINE_START_RE.match(line):
                comps[cur][-1] += " " + line.strip()
            else:
                comps[cur].append(line)
    return comps


def census(hlo_text: str, default_group: int = 1) -> Census:
    comps = _split_computations(hlo_text)

    # name -> result type (for dot contracting-dim resolution)
    name_type: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if m:
                name_type[m.group(1)] = m.group(2)
            else:
                mp = re.match(r"^\s*(?:ROOT )?(%[\w.\-]+) = "
                              r"(\([^()]*\)|[\w\[\],{}\d]+) parameter", ln)
                if mp:
                    name_type[mp.group(1)] = mp.group(2)

    # root instruction of each computation (for fusion-root inspection)
    root_of: dict[str, tuple[str, str, str]] = {}
    for comp, lines in comps.items():
        for ln in lines:
            if ln.lstrip().startswith("ROOT "):
                m = re.match(r"\s*ROOT (%[\w.\-]+) = (\([^()]*\)|[\w\[\],{}\d]+)"
                             r" ([\w\-]+)\((.*?)\)", ln)
                if m:
                    root_of[comp] = (m.group(3), m.group(4), m.group(2))

    def _dus_update_bytes(operands: str) -> int | None:
        """Bytes of the update operand (arg 1) of a dynamic-update-slice."""
        args = _operand_names(operands)
        if len(args) >= 2 and args[1] in name_type:
            return _shape_elems_bytes(name_type[args[1]])[1]
        return None

    # call graph: computation -> [(child_comp, multiplier_factor)]
    children: dict[str, list[tuple[str, int]]] = {c: [] for c in comps}
    fusion_bodies: set[str] = set()
    trip_of_body: dict[str, int] = {}
    for comp, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                body = _CALLS_RE.search(ln)
                trip = _TRIP_RE.search(ln)
                cond = _COND_RE.search(ln)
                n = int(trip.group(1)) if trip else 1
                if body:
                    children[comp].append((body.group(1), n))
                    trip_of_body[body.group(1)] = n
                if cond:
                    children[comp].append((cond.group(1), n))
            elif " fusion(" in ln or " call(" in ln or "conditional(" in ln:
                for callee in _CALLS_RE.findall(ln):
                    children[comp].append((callee, 1))
                    if " fusion(" in ln:
                        fusion_bodies.add(callee)
                for callee in re.findall(
                        r"(?:true_computation|false_computation|branch_computations)="
                        r"\{?(%[\w.\-]+)", ln):
                    children[comp].append((callee, 1))

    # multipliers via BFS from entry (last computation is ENTRY by convention;
    # find it: computation never referenced as a child)
    referenced = {c for kids in children.values() for c, _ in kids}
    roots = [c for c in comps if c not in referenced]
    mult: dict[str, float] = {c: 0.0 for c in comps}
    for r in roots:
        mult[r] = 1.0
    # propagate (computations form a DAG)
    changed = True
    iters = 0
    while changed and iters < 100:
        changed = False
        iters += 1
        for comp, kids in children.items():
            for child, factor in kids:
                if child not in mult:
                    continue
                new = mult[comp] * factor
                # a computation can be called from several sites; accumulate
                # by the max path (avoids double-count of shared cond/body)
                if new > mult[child]:
                    mult[child] = new
                    changed = True

    out = Census()
    out.loops = sorted(trip_of_body.values(), reverse=True)

    for comp, lines in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp in fusion_bodies
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            name, rtype, op = im.groups()
            elems, nbytes = _shape_elems_bytes(rtype)
            if op == "dot":
                ops_m = re.search(r"dot\((.*?)\), ", ln + ", ")
                dot_args = _operand_names(ops_m.group(1)) if ops_m else []
                k = 1
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                if dot_args and cd and dot_args[0] in name_type:
                    lhs_dims = _SHAPE_RE.search(name_type[dot_args[0]])
                    if lhs_dims:
                        dims = [int(d) for d in lhs_dims.group(2).split(",") if d]
                        for i in cd.group(1).split(","):
                            if i and int(i) < len(dims):
                                k *= dims[int(i)]
                out.flops += 2.0 * elems * k * m
            if not in_fusion and op not in ("parameter", "constant", "tuple",
                                            "get-tuple-element", "bitcast"):
                eff = nbytes
                if op == "dynamic-update-slice":
                    im2 = re.match(
                        r"\s*(?:ROOT )?%[\w.\-]+ = [^ ]+ "
                        r"dynamic-update-slice\((.*?)\)", ln)
                    if im2:
                        ub = _dus_update_bytes(im2.group(1))
                        if ub is not None:
                            eff = ub
                elif op == "fusion":
                    callee = _CALLS_RE.search(ln)
                    if callee and root_of.get(callee.group(1), ("",))[0] \
                            == "dynamic-update-slice":
                        ub = _dus_update_bytes(root_of[callee.group(1)][1])
                        if ub is not None:
                            eff = ub
                out.bytes_moved += 2.0 * eff * m
            base = op.replace("-start", "")
            if base in _COLL_KINDS and not op.endswith("-done"):
                g = default_group
                gm = _GROUPS_IOTA_RE.search(ln)
                if gm:
                    g = int(gm.group(2))
                else:
                    gm = _GROUPS_RE.search(ln)
                    if gm:
                        g = len(gm.group(1).split(","))
                if g <= 1:
                    continue
                frac = (g - 1) / g
                wire = (2 * nbytes * frac if base == "all-reduce"
                        else nbytes if base == "collective-permute"
                        else nbytes * frac)
                out.wire_bytes += wire * m
                out.coll_bytes[base] = out.coll_bytes.get(base, 0) + nbytes * m
                out.coll_counts[base] = out.coll_counts.get(base, 0) + m
    return out
