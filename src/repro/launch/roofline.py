"""Roofline analysis from AOT-compiled artifacts (no hardware execution).

Three terms per (arch x shape x mesh), in seconds (DESIGN §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective = wire_bytes_per_device / ICI_link_bandwidth

All three terms come from the loop-nest-aware HLO census
(``launch.hlo_census``) over the *partitioned per-device* program:
XLA:CPU's ``cost_analysis()`` counts while-loop bodies once, so it cannot
be used for scanned-layer programs.  Collective wire bytes use the standard
ring formulas: all-gather / reduce-scatter / all-to-all bytes*(g-1)/g,
all-reduce doubled, collective-permute as-is.  The useful_ratio
(MODEL_FLOPS / census_FLOPs*chips) cross-checks the per-device convention.

Hardware constants: TPU v5e-class chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes: float
    model_flops: float
    useful_ratio: float       # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str
    collectives: dict

    def table_row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, *, chips: int, model_flops: float,
            hlo_text: str | None = None) -> Roofline:
    """Roofline terms from the compiled per-device HLO.

    Uses the loop-nest-aware census (launch.hlo_census) rather than
    ``cost_analysis()``: XLA:CPU's cost analysis counts while-loop bodies
    once, which under-reports scanned-layer programs by >10x.  The raw
    cost_analysis numbers are still recorded by the dry-run for reference.
    """
    from repro.launch import hlo_census
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cen = hlo_census.census(text, default_group=chips)
    flops = cen.flops
    nbytes = cen.bytes_moved
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = cen.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        flops_per_device=flops, bytes_per_device=nbytes,
        wire_bytes=cen.wire_bytes, model_flops=model_flops,
        useful_ratio=useful, bottleneck=bottleneck,
        collectives={"bytes": cen.coll_bytes, "counts": cen.coll_counts,
                     "loops": cen.loops[:12]})


def table_walk_bytes(n_ops: float, probe_len_mean: float, *, window: int,
                     key_words: int = 1, value_words: int = 1,
                     value_ops: float = 1.0) -> float:
    """Bytes-per-batch model for a hash-table probe walk.

    Each of ``n_ops`` walking elements reads ``probe_len_mean`` windows of
    ``window`` lanes x ``key_words`` u32 key planes; value traffic is one
    ``value_words`` vector per value op (1 read or write per element for
    insert/retrieve, the join multiplicity r for multi-value gathers —
    callers pass ``value_ops`` accordingly).  This is the minimum HBM
    traffic the walk must move, so

        pct_of_roofline(table_walk_bytes(...), seconds)

    reads as "fraction of peak memory bandwidth this op achieved" — the
    paper's probes-per-second curves normalized to hardware instead of to
    a rival implementation.
    """
    key_bytes = n_ops * probe_len_mean * window * key_words * 4.0
    value_bytes = n_ops * value_ops * value_words * 4.0
    return key_bytes + value_bytes


def pct_of_roofline(bytes_moved: float, seconds: float) -> float:
    """Achieved bytes/s as a percentage of HBM bandwidth."""
    return 100.0 * (bytes_moved / max(seconds, 1e-12)) / HBM_BW


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS: 6ND (train), 2ND (forward/prefill), 2N per token (decode),
    with N = active params (MoE-aware)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        if cfg.family == "audio":
            # audio prefill runs the ENCODER over frontend_len frames; the
            # decoder (and its share of N) is exercised by the decode cells
            enc_frac = cfg.encoder_layers / (cfg.encoder_layers
                                             + cfg.num_layers)
            return 2.0 * n * enc_frac * cfg.frontend_len * cell.global_batch
        return 2.0 * n * cell.seq_len * cell.global_batch
    return 2.0 * n * cell.global_batch       # one decoded token per sequence
