"""Elastic restart demonstration: train -> kill -> resume on a DIFFERENT
mesh shape.

Simulates the production failure story (DESIGN.md §5): a job training on N
shards checkpoints, "loses" devices, and resumes on M != N shards — the
checkpoint manager re-places every leaf with the new mesh's NamedShardings,
and the deterministic (step, shard)-keyed data pipeline makes the resumed
loss path bitwise-independent of the interruption point.

Run standalone (uses host devices; set
XLA_FLAGS=--xla_force_host_platform_device_count=8 for a real reshard):

    PYTHONPATH=src python -m repro.launch.elastic --steps 30 --kill-at 15
"""

from __future__ import annotations

import argparse
import sys
import tempfile

import jax

from repro.core.compat import set_mesh_compat
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline as dp
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as zoo
from repro.training import checkpoint as ckpt_mod
from repro.training import optimizer as opt_mod
from repro.training import train_loop as tl


def train_segment(arch: str, mesh, steps: range, dcfg, ckpt_dir: str,
                  resume: bool):
    cfg = configs.get_smoke_config(arch)
    model = zoo.build(cfg)
    ocfg = opt_mod.OptConfig(lr=3e-3, warmup_steps=2, total_steps=steps.stop)
    step_fn = jax.jit(tl.make_train_step(model, ocfg), donate_argnums=(0,))
    manager = ckpt_mod.CheckpointManager(ckpt_dir)
    with set_mesh_compat(mesh):
        state = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        state_sh = sharding.tree_shardings(state, mesh)
        if resume:
            state, extra = manager.restore(jax.eval_shape(lambda: state),
                                           shardings=state_sh)
            print(f"  resumed at step {extra['step']} on "
                  f"{mesh.devices.size} devices")
        else:
            state = jax.device_put(state, state_sh)
        losses = []
        for step in steps:
            # cycle a tiny batch set so loss visibly decreases within the
            # short demo; batches stay keyed by step (determinism story)
            state, metrics = step_fn(state, dp.get_batch(dcfg, step % 4))
            losses.append(float(metrics["loss"]))
        manager.save(steps.stop, state, {"step": steps.stop})
        manager.wait()
    return losses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--kill-at", type=int, default=15)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="elastic_")
    n_dev = len(jax.devices())
    cfg = configs.get_smoke_config(args.arch)
    dcfg = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=8)

    mesh_a = make_host_mesh(n_dev)                     # full fleet
    print(f"phase 1: {n_dev} devices, steps 0..{args.kill_at}")
    l1 = train_segment(args.arch, mesh_a, range(0, args.kill_at), dcfg,
                       ckpt_dir, resume=False)

    n_b = max(1, n_dev // 2)                           # "lost half the fleet"
    mesh_b = make_host_mesh(n_b)
    print(f"phase 2 (elastic): {n_b} devices, steps "
          f"{args.kill_at}..{args.steps}")
    l2 = train_segment(args.arch, mesh_b, range(args.kill_at, args.steps),
                       dcfg, ckpt_dir, resume=True)

    print(f"loss: start {l1[0]:.4f} -> pre-kill {l1[-1]:.4f} -> "
          f"post-resume {l2[0]:.4f} -> end {l2[-1]:.4f}")
    assert l2[-1] < l1[0], "training did not progress across the reshard"
    print("elastic restart OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
