"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips over DCI.

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device initialization; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax use.
"""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(num_devices: int | None = None, axis: str = "data"):
    """1-D mesh over whatever devices exist (tests, examples, benchmarks)."""
    n = num_devices or len(jax.devices())
    return make_mesh_compat((n,), (axis,))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
