import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

This is the scale proof: for the production meshes — (16,16)=256 chips
single-pod and (2,16,16)=512 chips multi-pod — every assigned architecture's
train/prefill/serve step must lower and compile against ShapeDtypeStruct
inputs (no allocation).  ``compiled.memory_analysis()`` proves the per-chip
footprint fits; ``cost_analysis()`` + HLO collective parsing feed the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two XLA_FLAGS lines above MUST stay the first statements in this module
(jax locks the device count at first init).
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.core.compat import set_mesh_compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, applicable_shapes
from repro.distributed import sharding
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo
from repro.training import optimizer as opt_mod
from repro.training import train_loop as tl


# Per-arch training settings for the BASELINE dry-run.  These are the
# paper-neutral defaults; §Perf hillclimbs override them per cell.
@dataclasses.dataclass(frozen=True)
class TrainSettings:
    optimizer: str = "adamw"
    accum_steps: int = 4
    remat: str = "block"
    seq_shard: bool = False
    attn_tp_pad: int = 0          # pad heads to TP width (0 = off)


# Post-hillclimb defaults (§Perf): attn_tp_pad for every arch whose head
# count doesn't divide the 16-wide model axis (else attention replicates
# across TP); seq_shard for all full-attention transformers (big collective
# + activation-memory win); adafactor + deep accumulation for >100B params.
TRAIN_SETTINGS = {
    "smollm-360m": TrainSettings(seq_shard=True, attn_tp_pad=16),      # 15 H
    "starcoder2-3b": TrainSettings(seq_shard=True, attn_tp_pad=16),    # 24 H
    "olmo-1b": TrainSettings(),   # 16 H ok; seq_shard REGRESSED here (coll 2.7->10.9 s: at d_model=2048 the SP boundary gathers cost more than the boundary ARs they replace)
    "whisper-small": TrainSettings(accum_steps=2, attn_tp_pad=16),     # 12 H
    "internvl2-1b": TrainSettings(seq_shard=True, attn_tp_pad=16),     # 14 H
    "rwkv6-3b": TrainSettings(),                   # time-scan: no seq shard
    "mistral-large-123b": TrainSettings(optimizer="adafactor", accum_steps=16,
                                        seq_shard=True),               # 96 H ok
    "deepseek-v2-236b": TrainSettings(optimizer="adafactor", accum_steps=16,
                                      seq_shard=True),   # MLA constraints
    "dbrx-132b": TrainSettings(optimizer="adafactor", accum_steps=16,
                               seq_shard=True),                        # 48 H ok
    "jamba-1.5-large-398b": TrainSettings(optimizer="adafactor",
                                          accum_steps=16),  # mamba time-scan
}


def tuned_config(arch: str, kind: str, overrides: dict | None = None):
    cfg = configs.get_config(arch)
    ts = TRAIN_SETTINGS[arch]
    o = overrides or {}
    if kind == "train":
        cfg = dataclasses.replace(
            cfg,
            remat=o.get("remat", ts.remat),
            seq_shard_activations=o.get("seq_shard", ts.seq_shard),
        )
    if kind in ("train", "prefill"):
        # head padding helps any full-sequence pass; sequence sharding is
        # train-only — on the forward-only prefill it REGRESSED mistral
        # (compute 8->52 s: SP boundaries force per-chunk halo gathers with
        # no remat savings to pay for them; §Perf notes)
        cfg = dataclasses.replace(
            cfg, attn_tp_pad=o.get("attn_tp_pad", ts.attn_tp_pad))
    if "capacity_factor" in o and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=o["capacity_factor"]))
    return cfg


def _opt_config(arch: str, overrides: dict | None = None) -> opt_mod.OptConfig:
    ts = TRAIN_SETTINGS[arch]
    o = overrides or {}
    return opt_mod.OptConfig(name=o.get("optimizer", ts.optimizer))


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

def _prefill_forward(cfg, model):
    """Uniform prefill step: full forward, last-position logits only."""
    from repro.models import transformer as tf
    from repro.models import encdec as ed
    from repro.models.layers import embed, linear, unembed, apply_norm

    if cfg.family == "audio":
        def fn(params, batch):
            enc = ed.encode(cfg, params, batch["frames"])
            return enc[:, -1]
        return fn

    def fn(params, batch):
        h = embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm":
            pe = linear(params["frontend_proj"],
                        batch["patches"].astype(h.dtype))
            h = jnp.concatenate([pe, h], axis=1)
        h, _ = tf.lm_hidden(cfg, params, h)
        h = h[:, -1:]
        if cfg.tie_embeddings:
            return unembed(params["embed"], h)
        return linear(params["lm_head"], h).astype(jnp.float32)
    return fn


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell. Returns (lowered, compiled, meta)."""
    cell = SHAPES[shape_name]
    cfg = tuned_config(arch, cell.kind, overrides)
    model = zoo.build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    o = overrides or {}

    batch_shape = zoo.input_specs(cfg, cell)
    batch_sh = sharding.batch_shardings(batch_shape, mesh)

    with set_mesh_compat(mesh):
        if cell.kind == "train":
            ocfg = _opt_config(arch, overrides)
            accum = o.get("accum_steps", TRAIN_SETTINGS[arch].accum_steps)
            step_fn = tl.make_train_step(model, ocfg, accum_steps=accum)
            state_shape = jax.eval_shape(
                lambda: tl.init_state(model, ocfg, jax.random.PRNGKey(0)))
            state_sh = sharding.tree_shardings(state_shape, mesh)
            fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(state_shape, batch_shape)
        elif cell.kind == "prefill":
            params_shape = zoo.param_specs(cfg)
            params_sh = sharding.tree_shardings(params_shape, mesh)
            fwd = _prefill_forward(cfg, model)
            fn = jax.jit(fwd, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(params_shape, batch_shape)
        else:  # decode
            params_shape = zoo.param_specs(cfg)
            # NOTE: mode="serve" (full-mesh TP weights, no FSDP gathers) was
            # tried and REFUTED for this mesh: batch-sharded activations vs
            # 2D-sharded weights reshard every layer (coll 579->1117 ms).
            # FSDP weight-gathers amortized over the 128-way decode batch
            # remain the better trade (§Perf cell 3, iter 3).
            params_sh = sharding.tree_shardings(
                params_shape, mesh,
                mode=o.get("param_mode", "train") if o else "train")
            cache_shape = zoo.cache_specs(cfg, cell)
            cache_sh = sharding.cache_shardings(cache_shape, mesh, cfg, cell)
            pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
            pos_sh = NamedSharding(mesh, P())

            def serve_step(params, cache, batch, pos):
                return model.decode_step(params, cache, batch["tokens"], pos)

            fn = jax.jit(serve_step,
                         in_shardings=(params_sh, cache_sh, batch_sh, pos_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_shape, cache_shape, batch_shape,
                               pos_shape)
        compiled = lowered.compile()

    meta = {"arch": arch, "shape": shape_name, "kind": cell.kind,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "overrides": o}
    return lowered, compiled, meta, cfg, cell


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, save_hlo: str | None = None) -> dict:
    t0 = time.time()
    lowered, compiled, meta, cfg, cell = lower_cell(arch, shape_name,
                                                    multi_pod, overrides)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    rec = dict(meta)
    rec["compile_s"] = round(t_compile, 1)
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    hlo_text = compiled.as_text()
    rl = roofline.analyze(compiled, chips=meta["chips"],
                          model_flops=roofline.model_flops_for(cfg, cell),
                          hlo_text=hlo_text)
    rec["roofline"] = dataclasses.asdict(rl)
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        tag = f"{arch}__{shape_name}__{rec['mesh']}"
        with open(os.path.join(save_hlo, tag + ".hlo.txt"), "w") as f:
            f.write(hlo_text)
    return rec


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for shape in applicable_shapes(cfg):
            out.append((arch, shape))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--override", default=None,
                    help='JSON dict, e.g. {"accum_steps": 32}')
    args = ap.parse_args(argv)

    cells = (all_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = json.loads(args.override) if args.override else None

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, mp, overrides, args.save_hlo)
                rl = rec["roofline"]
                print(f"PASS {tag}: compile={rec['compile_s']}s "
                      f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                      f"bottleneck={rl['bottleneck']} "
                      f"compute={rl['compute_s']*1e3:.1f}ms "
                      f"memory={rl['memory_s']*1e3:.1f}ms "
                      f"coll={rl['collective_s']*1e3:.1f}ms "
                      f"useful={rl['useful_ratio']:.2f}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            except Exception:
                failures += 1
                print(f"FAIL {tag}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
