"""End-to-end training driver with fault tolerance.

Trains any ``--arch`` (full or smoke config) on the available devices,
with: sharded+async checkpointing, SIGTERM/SIGINT preemption save, resume
from latest checkpoint, deterministic per-(step, shard) data (a replacement
host recomputes identical batches — straggler/elastic safety), optional
cross-pod int8 gradient compression, and hash-table n-gram dedup in the
data path.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
    PYTHONPATH=src python -m repro.launch.train ... --resume
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax

from repro.core.compat import set_mesh_compat
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline as dp
from repro.distributed import collectives, sharding
from repro.launch.mesh import make_host_mesh
from repro.models import model_zoo as zoo
from repro.training import checkpoint as ckpt_mod
from repro.training import compression as comp
from repro.training import optimizer as opt_mod
from repro.training import train_loop as tl


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--dedup", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = zoo.build(cfg)
    mesh = make_host_mesh()
    ocfg = opt_mod.OptConfig(name=args.optimizer, lr=args.lr,
                             warmup_steps=max(2, args.steps // 20),
                             total_steps=args.steps)
    dcfg = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)

    grad_sync = collectives.make_grad_sync(
        mesh, comp.CompressionConfig(kind=args.grad_compression))
    step_fn = tl.make_train_step(model, ocfg, accum_steps=args.accum,
                                 grad_transform=grad_sync)

    with set_mesh_compat(mesh):
        state = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        state_sh = sharding.tree_shardings(state, mesh)
        state = jax.device_put(state, state_sh)

        manager = None
        start_step = 0
        if args.ckpt_dir:
            manager = ckpt_mod.CheckpointManager(args.ckpt_dir)
            if args.resume and manager.latest_step() is not None:
                state, extra = manager.restore(
                    jax.eval_shape(lambda: state), shardings=state_sh)
                start_step = int(extra.get("step", manager.latest_step()))
                print(f"resumed from step {start_step}", flush=True)

        # preemption safety: save on SIGTERM/SIGINT, then exit cleanly
        preempted = {"flag": False}

        def _handler(signum, frame):
            preempted["flag"] = True

        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGINT, _handler)

        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        dedup_table = None
        if args.dedup:
            from repro.core import counting
            dedup_table = counting.create(1 << 16)

        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = dp.get_batch(dcfg, step)
            if dedup_table is not None:
                dedup_table, keep = dp.dedup_filter(dedup_table,
                                                    batch["tokens"])
                batch["loss_mask"] = jnp.broadcast_to(
                    keep[:, None], batch["labels"].shape)
            state, metrics = jit_step(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                tok_s = (args.batch * args.seq * (step - start_step + 1)
                         / (time.time() - t0))
                print(f"step {step} loss {loss:.4f} lr "
                      f"{float(metrics['lr']):.2e} tok/s {tok_s:.0f}",
                      flush=True)
            if manager and (step + 1) % args.ckpt_every == 0:
                manager.save_async(step + 1, state, {"step": step + 1})
            if preempted["flag"]:
                if manager:
                    manager.save(step + 1, state, {"step": step + 1,
                                                   "preempted": True})
                    print(f"preempted: saved step {step + 1}", flush=True)
                return 0
        if manager:
            manager.save(args.steps, state, {"step": args.steps})
            manager.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
