"""Pallas TPU kernels for the paper's compute hot spots.

- ``cops``    — COPS probing: insert (single-/multi-value) + lookup over a
                VMEM-resident table shard (paper §IV-B); u32 keys and
                2-plane u64 keys (the beyond-32-bit claim, DESIGN.md §2).
- ``bloom``   — blocked bloom filter insert/query on packed u32 words.
- ``minhash`` — canonical k-mer extraction + hashing for the metagenomics
                use case (paper §V-C).
- ``flash``   — flash-attention forward with VMEM-resident online softmax
                (the LM substrate's hot spot per §Roofline).

Each kernel package has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted wrapper + padding/dispatch), and ref.py (pure-jnp oracle used by the
allclose test sweeps).  Kernels run in interpret mode off-TPU.
"""
