"""Pallas TPU flash-attention (forward) kernel.

The §Roofline analysis shows the XLA-composed chunked attention still
round-trips every (Tq, Tk) probability tile through HBM (fusion boundaries
at each einsum).  This kernel keeps the whole online-softmax state in VMEM:
one (Tq, hd) query tile is resident per grid step, K/V stream through in
(Tk, hd) tiles, and only the final (Tq, hd) output block leaves the core —
the canonical O(S) memory attention.

Grid: (batch*heads, S/Tq).  BlockSpecs give the kernel a (Tq, hd) q tile
and the full (S, hd) K/V panels of its (b, h) — at S=4096, hd=128, bf16
that is 2 x 1 MiB of VMEM, well under budget; tiles are MXU-aligned
(Tq, Tk, hd multiples of 128 recommended).

Forward-only: training uses the jax.checkpoint'd XLA path (attention.py);
wiring a custom_vjp backward kernel is the next §Perf step on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_F = jnp.float32

DEFAULT_TQ = 128
DEFAULT_TK = 128
NEG_INF = np.float32(-1e30)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, tq, tk, seq_k, causal,
                  scale):
    iq = pl.program_id(1)
    q = q_ref[0].astype(_F) * scale                     # (Tq, hd)
    nk = seq_k // tk

    def kv_step(ik, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.ds(ik * tk, tk), :].astype(_F)  # (Tk, hd)
        vb = v_ref[0, pl.ds(ik * tk, tk), :].astype(_F)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=_F)  # (Tq, Tk)
        if causal:
            qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                 preferred_element_type=_F)
        acc_new = acc * corr[:, None] + pv
        return m_new, l_new, acc_new

    hd = q_ref.shape[-1]
    m0 = jnp.full((tq,), NEG_INF, _F)
    l0 = jnp.zeros((tq,), _F)
    a0 = jnp.zeros((tq, hd), _F)
    # causal: kv blocks beyond this q block's diagonal are fully masked —
    # bound the loop at the diagonal instead of scanning them
    if causal:
        nk_eff = jnp.minimum(nk, (iq + 1) * tq // tk + (tq // tk > 0))
        nk_eff = jnp.minimum(nk_eff, nk)
    else:
        nk_eff = nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, kv_step, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def flash_call(q, k, v, *, causal=True, tq=DEFAULT_TQ, tk=DEFAULT_TK,
               interpret=True):
    """q, k, v: (BH, S, hd) -> (BH, S, hd)."""
    bh, seq, hd = q.shape
    seq_k = k.shape[1]
    tq = min(tq, seq)
    tk = min(tk, seq_k)
    assert seq % tq == 0 and seq_k % tk == 0, (seq, tq, seq_k, tk)
    scale = 1.0 / float(np.sqrt(hd))
    kern = functools.partial(_flash_kernel, tq=tq, tk=tk, seq_k=seq_k,
                             causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, seq // tq),
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_k, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq_k, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
