"""Jitted wrapper for the flash attention kernel: GQA-aware (B, S, H, hd)
interface matching repro.models.attention conventions."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cops.ops import should_interpret
from repro.kernels.flash import kernel as K


@functools.partial(jax.jit, static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention(q, k, v, *, causal=True, tq=K.DEFAULT_TQ, tk=K.DEFAULT_TK,
                    interpret=True):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) with H % Hkv == 0.

    Returns (B, S, H, hd).  GQA is handled by repeating K/V head panels
    (index-gather, not materialized copies, under XLA).
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], hd)
    out = K.flash_call(qf, kf, vf, causal=causal, tq=tq, tk=tk,
                       interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def flash_attention_auto(q, k, v, **kw):
    return flash_attention(q, k, v, interpret=should_interpret(), **kw)
