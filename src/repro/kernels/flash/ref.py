"""Pure-jnp oracle for the flash attention kernel: naive full-matrix
softmax attention in fp32."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention(q, k, v, *, causal=True):
    """q, k, v: (BH, S, hd) -> (BH, S, hd)."""
    _, sq, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
