"""Pure-jnp oracle for the COPS kernel.

The reference semantics are the sequential-scan implementation in
``repro.core.single_value`` / ``repro.core.multi_value`` (backend="scan")
— a completely separate code path from the Pallas kernel (lax.scan over
the batch + gather-based windows vs. in-kernel fori_loop over VMEM refs)
and from the default vectorized bulk engine (repro.core.bulk), which is
itself parity-tested against the same scan.  Tests assert the kernel's
table state and outputs match this oracle bit-for-bit across
shape/width/load-factor sweeps.
"""

from __future__ import annotations

import dataclasses

from repro.core import multi_value as mv
from repro.core import single_value as sv


def _as_jax(table):
    return dataclasses.replace(table, backend="scan")


def insert(table, keys, values, mask=None):
    return sv.insert(_as_jax(table), keys, values, mask)


def insert_multi(table, keys, values, mask=None):
    return mv.insert(_as_jax(table), keys, values, mask)


def retrieve(table, keys):
    return sv.retrieve(_as_jax(table), keys)


def retrieve_multi(table, keys, out_capacity):
    return mv.retrieve_all(_as_jax(table), keys, out_capacity)
