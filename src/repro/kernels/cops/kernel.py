"""Pallas TPU kernel for COPS probing (paper §IV-B.2, Fig. 2).

TPU mapping of the warp-cooperative scheme (DESIGN.md §2):

- The table shard lives entirely in VMEM for the duration of the kernel:
  BlockSpec maps the full (p, W) key/value planes with a constant index_map,
  so the pipeline loads them once and revisits them across grid steps.  This
  is the TPU analogue of "all probes of a group hit one cache line" — probes
  cost VMEM-latency row slices, never HBM round trips.
- One probe window = one (1, W) row slice; the warp vote becomes a vector
  compare + iota-min over the W lanes.
- The key batch streams through the grid in (1, T) tiles.  Keys are
  processed *sequentially* inside each tile (fori_loop) and tiles execute
  sequentially on the core (TPU grid semantics) — the single-writer
  serialization that replaces atomicCAS under ownership partitioning.
- Slot claims are read-modify-write of the whole row (vector-aligned store),
  not a scalar lane store.

The kernel supports the single-value upsert (claim-or-update), the
multi-value append (claim-only), lookup, the fused group-by RMW, the
fused multi-value retrieval walk, and the bucket-list chain walk.  u32
keys / u32 values, SOA layout; 2-plane composite/u64 keys have dedicated
``*64`` variants (insert64 / lookup64 / retrieve_multi64) whose window
match ANDs both planes — wider configurations take the pure-JAX path
(see the dispatchers' eligibility checks in ``ops.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.common import (
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_UPDATED,
    TOMBSTONE_KEY,
)
from repro.core import hashing

_U = jnp.uint32
_I = jnp.int32

DEFAULT_TILE = 256


def _win_vote(mask_row):
    """Lowest set lane in a (W,) bool row, W if none — the group vote."""
    w = mask_row.shape[0]
    lanes = jax.lax.broadcasted_iota(_I, (1, w), 1)[0]
    return jnp.min(jnp.where(mask_row, lanes, _I(w)))


def _probe_setup(k, num_rows, seed, scheme):
    row0 = hashing.hash_rows(k, num_rows, seed)
    if scheme in ("cops", "bucketed"):
        # bucketed IS the cops walk truncated to its two buckets — the
        # dispatch layer clamps max_probes to 2 (probing.effective_probes),
        # so the bucket tile reuses the double-hashing step unchanged
        step = hashing.hash_step(k, num_rows, seed)
    else:  # "linear" baseline
        step = _U(1)
    return row0, step


# ---------------------------------------------------------------------------
# insert (single-value upsert OR multi-value append)
# ---------------------------------------------------------------------------

def _insert_kernel(keys_ref, vals_ref, mask_ref, tk_in_ref, tv_in_ref,
                   tk_ref, tv_ref, status_ref,
                   *, num_rows, window, seed, max_probes, scheme, multi_value):
    # tk_ref/tv_ref are the OUTPUT refs, aliased onto tk_in_ref/tv_in_ref —
    # all reads and writes go through the output refs (single buffer).
    del tk_in_ref, tv_in_ref
    tile = keys_ref.shape[1]

    def one_key(j, _):
        k = keys_ref[0, j]
        v = vals_ref[0, j]
        m = mask_ref[0, j] != 0

        row0, step = _probe_setup(k, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            (attempt, row, done, crow, clane, have_cand, mrow, mlane,
             matched) = st
            win = tk_ref[pl.ds(row.astype(_I), 1), :][0]           # (W,)
            empty = win == EMPTY_KEY
            tomb = win == TOMBSTONE_KEY
            cand = empty | tomb
            c_lane = _win_vote(cand)
            has_empty = jnp.any(empty)
            if multi_value:
                hit = jnp.zeros((), bool)
                m_lane = _I(window)
            else:
                match = win == k
                m_lane = _win_vote(match)
                hit = m_lane < window
            new_cand = jnp.logical_and(~have_cand, c_lane < window)
            crow = jnp.where(new_cand, row, crow)
            clane = jnp.where(new_cand, c_lane, clane)
            have_cand = have_cand | (c_lane < window)
            mrow = jnp.where(hit, row, mrow)
            mlane = jnp.where(hit, m_lane, mlane)
            matched = matched | hit
            if multi_value:
                done = have_cand                      # first candidate wins
            else:
                done = hit | has_empty                # match or absence proof
            nrow = (row + step) % _U(num_rows)
            return (attempt + 1, jnp.where(done, row, nrow), done, crow,
                    clane, have_cand, mrow, mlane, matched)

        zu = jnp.zeros((), _U)
        zi = jnp.zeros((), _I)
        st = (zi, row0, jnp.zeros((), bool), zu, zi, jnp.zeros((), bool),
              zu, zi, jnp.zeros((), bool))
        (_, _, _, crow, clane, have_cand, mrow, mlane, matched) = \
            jax.lax.while_loop(cond, body, st)

        do_update = m & matched & (not multi_value)
        do_claim = m & ~matched & have_cand
        row = jnp.where(matched, mrow, crow).astype(_I)
        lane = jnp.where(matched, mlane, clane)
        write = do_update | do_claim

        @pl.when(write)
        def _():
            lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
            sel = lanes == lane
            vrow = tv_ref[pl.ds(row, 1), :][0]
            tv_ref[pl.ds(row, 1), :] = jnp.where(sel, v, vrow)[None, :]

        @pl.when(do_claim)
        def _():
            lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
            sel = lanes == lane
            krow = tk_ref[pl.ds(row, 1), :][0]
            tk_ref[pl.ds(row, 1), :] = jnp.where(sel, k, krow)[None, :]

        status = jnp.where(~m, _I(STATUS_MASKED),
                           jnp.where(do_update, _I(STATUS_UPDATED),
                                     jnp.where(do_claim, _I(STATUS_INSERTED),
                                               _I(STATUS_FULL))))
        status_ref[0, j] = status
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def insert_call(table_keys, table_vals, keys2d, vals2d, mask2d, *, seed,
                max_probes, scheme="cops", multi_value=False, interpret=True):
    """keys2d/vals2d/mask2d: (G, T). Returns (table_keys, table_vals, status2d)."""
    num_rows, window = table_keys.shape
    g, tile = keys2d.shape
    kern = functools.partial(
        _insert_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme, multi_value=multi_value)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, row_tile, full, full],
        out_specs=[full, full, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(keys2d, vals2d, mask2d, table_keys, table_vals)


# ---------------------------------------------------------------------------
# fused RMW tile — group-by aggregation without leaving VMEM
# ---------------------------------------------------------------------------
#
# The group-by table is a SingleValueHashTable with two value planes
# (plane 0 = aggregate accumulator, plane 1 = group cardinality).  The scan
# reference folds one element at a time through update_values; this kernel
# fuses probe + fold + store per key while the whole table shard stays
# resident in VMEM — the ROADMAP "group-by on the Pallas kernel path" item.
# ``agg`` is a static switch: sum/mean accumulate, min/max clamp, count
# ignores the operand; claims seed the accumulator exactly like the scan
# path's init write.

AGG_KINDS = ("sum", "mean", "min", "max", "count")


def _update_kernel(keys_ref, vals_ref, mask_ref, tk_in, tv0_in, tv1_in,
                   tk_ref, tv0_ref, tv1_ref, status_ref,
                   *, num_rows, window, seed, max_probes, scheme, agg):
    del tk_in, tv0_in, tv1_in
    tile = keys_ref.shape[1]

    def one_key(j, _):
        k = keys_ref[0, j]
        v = vals_ref[0, j]
        m = mask_ref[0, j] != 0
        row0, step = _probe_setup(k, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            (attempt, row, done, crow, clane, have_cand, mrow, mlane,
             matched) = st
            win = tk_ref[pl.ds(row.astype(_I), 1), :][0]
            empty = win == EMPTY_KEY
            cand = empty | (win == TOMBSTONE_KEY)
            c_lane = _win_vote(cand)
            has_empty = jnp.any(empty)
            m_lane = _win_vote(win == k)
            hit = m_lane < window
            new_cand = jnp.logical_and(~have_cand, c_lane < window)
            crow = jnp.where(new_cand, row, crow)
            clane = jnp.where(new_cand, c_lane, clane)
            have_cand = have_cand | (c_lane < window)
            mrow = jnp.where(hit, row, mrow)
            mlane = jnp.where(hit, m_lane, mlane)
            matched = matched | hit
            done = hit | has_empty
            nrow = (row + step) % _U(num_rows)
            return (attempt + 1, jnp.where(done, row, nrow), done, crow,
                    clane, have_cand, mrow, mlane, matched)

        zu = jnp.zeros((), _U)
        zi = jnp.zeros((), _I)
        st = (zi, row0, jnp.zeros((), bool), zu, zi, jnp.zeros((), bool),
              zu, zi, jnp.zeros((), bool))
        (_, _, _, crow, clane, have_cand, mrow, mlane, matched) = \
            jax.lax.while_loop(cond, body, st)

        do_update = m & matched
        do_claim = m & ~matched & have_cand
        row = jnp.where(matched, mrow, crow).astype(_I)
        lane = jnp.where(matched, mlane, clane)
        lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
        sel = lanes == lane

        operand = _U(1) if agg == "count" else v

        @pl.when(do_update | do_claim)
        def _():
            acc_row = tv0_ref[pl.ds(row, 1), :][0]
            cnt_row = tv1_ref[pl.ds(row, 1), :][0]
            acc = jnp.max(jnp.where(sel, acc_row, _U(0)))
            if agg in ("sum", "mean", "count"):
                folded = acc + operand
            elif agg == "min":
                folded = jnp.minimum(acc, operand)
            else:  # max
                folded = jnp.maximum(acc, operand)
            cnt = jnp.max(jnp.where(sel, cnt_row, _U(0)))
            new_acc = jnp.where(do_update, folded, operand)
            new_cnt = jnp.where(do_update, cnt + _U(1), _U(1))
            tv0_ref[pl.ds(row, 1), :] = jnp.where(sel, new_acc, acc_row)[None, :]
            tv1_ref[pl.ds(row, 1), :] = jnp.where(sel, new_cnt, cnt_row)[None, :]

        @pl.when(do_claim)
        def _():
            krow = tk_ref[pl.ds(row, 1), :][0]
            tk_ref[pl.ds(row, 1), :] = jnp.where(sel, k, krow)[None, :]

        status_ref[0, j] = jnp.where(
            ~m, _I(STATUS_MASKED),
            jnp.where(do_update, _I(STATUS_UPDATED),
                      jnp.where(do_claim, _I(STATUS_INSERTED),
                                _I(STATUS_FULL))))
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def update_call(tk, tv0, tv1, keys2d, vals2d, mask2d, *, seed, max_probes,
                scheme="cops", agg="sum", interpret=True):
    """Fused group-by RMW: keys2d/vals2d/mask2d (G, T).

    Returns (tk, tv0, tv1, status2d) with tv0/tv1 the aggregate/count
    planes updated in place (input/output aliased).
    """
    num_rows, window = tk.shape
    g, tile = keys2d.shape
    kern = functools.partial(
        _update_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme, agg=agg)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, row_tile, full, full, full],
        out_specs=[full, full, full, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        input_output_aliases={3: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(keys2d, vals2d, mask2d, tk, tv0, tv1)


# ---------------------------------------------------------------------------
# fused retrieve tile — multi-value counts + match arena in one walk
# ---------------------------------------------------------------------------
#
# The TPU rendering of the bulk-retrieval engine's fused walk
# (repro.core.bulk_retrieve): per query the tile walks the probe sequence
# ONCE, accumulating the match count and stamping (query index, walk-order
# rank) into two slot-shaped arena planes held in VMEM alongside the key
# plane.  The host-side compaction (`bulk_retrieve._emit`) then turns
# counts + arena into the paper's (values, offsets, counts) layout — so
# the kernel replaces both the counting pass and the gather re-probe with
# a single pass over the store, mirroring how `update_call` fuses the
# group-by RMW.  Queries are pre-deduped by the caller (mask selects the
# group representatives), so arena writes never collide.

def _retrieve_kernel(keys_ref, mask_ref, tk_ref, qa_in, ra_in,
                     qa_ref, ra_ref, cnt_ref,
                     *, num_rows, window, seed, max_probes, scheme, collect):
    del qa_in, ra_in
    tile = keys_ref.shape[1]
    i = pl.program_id(0)

    def one_key(j, _):
        k = keys_ref[0, j]
        m = mask_ref[0, j] != 0
        qidx = i * tile + j
        row0, step = _probe_setup(k, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, seen = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            attempt, row, done, seen = st
            ri = row.astype(_I)
            win = tk_ref[pl.ds(ri, 1), :][0]
            match = win == k
            nm = jnp.sum(match.astype(_I))
            has_empty = jnp.any(win == EMPTY_KEY)

            if collect:
                rank = jnp.cumsum(match.astype(_I)) - 1 + seen

                @pl.when(nm > 0)
                def _():
                    qrow = qa_ref[pl.ds(ri, 1), :][0]
                    qa_ref[pl.ds(ri, 1), :] = jnp.where(match, qidx,
                                                        qrow)[None, :]
                    rrow = ra_ref[pl.ds(ri, 1), :][0]
                    ra_ref[pl.ds(ri, 1), :] = jnp.where(match, rank,
                                                        rrow)[None, :]

            seen = seen + nm
            done = has_empty
            nrow = (row + step) % _U(num_rows)
            return attempt + 1, jnp.where(done, row, nrow), done, seen

        st = (jnp.zeros((), _I), row0, ~m, jnp.zeros((), _I))
        _, _, _, seen = jax.lax.while_loop(cond, body, st)
        cnt_ref[0, j] = seen
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def retrieve_multi_call(tk, qa0, ra0, keys2d, mask2d, *, seed, max_probes,
                        scheme="cops", collect=True, interpret=True):
    """Fused retrieval walk: keys2d/mask2d (G, T); qa0/ra0 the sentinel-
    initialized (p, W) arena planes (aliased in/out) — pass (1, 1) dummies
    with ``collect=False`` for the counts-only walk (no arena writes).

    Returns (qarena, rank_arena, counts2d).
    """
    num_rows, window = tk.shape
    g, tile = keys2d.shape
    kern = functools.partial(
        _retrieve_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme, collect=collect)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    arena = pl.BlockSpec(qa0.shape, lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, full, arena, arena],
        out_specs=[arena, arena, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct(qa0.shape, _I),
            jax.ShapeDtypeStruct(ra0.shape, _I),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(keys2d, mask2d, tk, qa0, ra0)


# ---------------------------------------------------------------------------
# bucket-walk tile — the bucket-list chain walk over the pool slot arena
# ---------------------------------------------------------------------------
#
# Mirrors the fused retrieve tile above, with the bucket store's chain in
# place of the probe sequence: per query the tile walks its bucket list
# tail -> head (handles are pre-probed by the caller — counts are O(1)
# from the handle, so only the arena walk runs on-core), reading each
# bucket in fixed-width chunks and stamping (query index, head-first value
# rank) into two pool-shaped arena planes held in VMEM next to the pool.
# The host-side compaction (`bulk_retrieve._emit`) is shared with the jax
# engine, exactly like `retrieve_multi_call`.  Distinct queries own
# disjoint chains, so arena writes never collide.  The arena planes carry
# `chunk` slots of padding: a chunked window may run past a bucket's tail
# (masked lanes re-write their current contents), and the last bucket may
# end at the pool's edge.

BUCKET_CHUNK = 128


def _bucket_walk_kernel(ptr_ref, cnt_ref, bidx_ref, act_ref, sizes_ref,
                        cum_ref, pool_ref, qa_in, ra_in, qa_ref, ra_ref,
                        *, chunk):
    del qa_in, ra_in
    tile = ptr_ref.shape[1]
    i = pl.program_id(0)

    def one_query(jq, _):
        act = act_ref[0, jq] != 0
        cnt = cnt_ref[0, jq]
        qidx = i * tile + jq
        lanes = jax.lax.broadcasted_iota(_I, (1, chunk), 1)[0]

        def cond(st):
            j, p = st
            return j >= 0

        def body(st):
            j, p = st
            bsize = sizes_ref[0, j]
            base = cum_ref[0, j]
            has_link = j > 0
            data_start = p.astype(_I) + jnp.where(has_link, 1, 0)
            valid = jnp.minimum(cnt - base, bsize)      # tail partially filled

            def ccond(c):
                return c * chunk < valid

            def cbody(c):
                start = data_start + c * chunk
                ok = c * chunk + lanes < valid
                cur_q = qa_ref[0, pl.ds(start, chunk)]
                qa_ref[0, pl.ds(start, chunk)] = jnp.where(ok, qidx, cur_q)
                cur_r = ra_ref[0, pl.ds(start, chunk)]
                ra_ref[0, pl.ds(start, chunk)] = jnp.where(
                    ok, base + c * chunk + lanes, cur_r)
                return c + 1

            jax.lax.while_loop(ccond, cbody, jnp.zeros((), _I))
            link = pool_ref[0, p.astype(_I)]
            p = jnp.where(has_link, link, p)
            return j - 1, p

        j0 = jnp.where(act, bidx_ref[0, jq], _I(-1))
        jax.lax.while_loop(cond, body, (j0, ptr_ref[0, jq]))
        return 0

    jax.lax.fori_loop(0, tile, one_query, 0)


def bucket_walk_call(pool, qa0, ra0, ptr2d, cnt2d, bidx2d, act2d, sizes, cum,
                     *, chunk=BUCKET_CHUNK, interpret=True):
    """Bucket-list chain walk: ptr2d/cnt2d/bidx2d/act2d (G, T) pre-probed
    handle planes; qa0/ra0 the sentinel-initialized (1, pool_cap + chunk)
    arena planes (aliased in/out); sizes/cum the (1, L) growth schedule.

    Returns (qarena, rank_arena) — flat pool-slot arenas incl. padding.
    """
    g, tile = ptr2d.shape
    kern = functools.partial(_bucket_walk_kernel, chunk=chunk)
    full = lambda x: pl.BlockSpec(x.shape, lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, row_tile, row_tile, full(sizes),
                  full(cum), full(pool), full(qa0), full(ra0)],
        out_specs=[full(qa0), full(ra0)],
        out_shape=[
            jax.ShapeDtypeStruct(qa0.shape, _I),
            jax.ShapeDtypeStruct(ra0.shape, _I),
        ],
        input_output_aliases={7: 0, 8: 1},
        interpret=interpret,
    )(ptr2d, cnt2d, bidx2d, act2d, sizes, cum, pool, qa0, ra0)


# ---------------------------------------------------------------------------
# 64-bit keys: two u32 planes (hi, lo) — DESIGN.md §2.  The window match is
# two vector compares ANDed; sentinels live on plane 0.  This is the kernel
# path for the paper's "beyond 32-bit" claim (WarpDrive was 32-bit-only).
# ---------------------------------------------------------------------------

def _insert64_kernel(k0_ref, k1_ref, vals_ref, mask_ref, tk0_in, tk1_in,
                     tv_in, tk0_ref, tk1_ref, tv_ref, status_ref,
                     *, num_rows, window, seed, max_probes, scheme,
                     multi_value):
    del tk0_in, tk1_in, tv_in
    tile = k0_ref.shape[1]

    def one_key(j, _):
        k0 = k0_ref[0, j]                 # primary plane (sentinels)
        k1 = k1_ref[0, j]
        v = vals_ref[0, j]
        m = mask_ref[0, j] != 0
        word = hashing.combine_planes(k1, k0)
        row0, step = _probe_setup(word, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            (attempt, row, done, crow, clane, have_cand, mrow, mlane,
             matched) = st
            win0 = tk0_ref[pl.ds(row.astype(_I), 1), :][0]
            win1 = tk1_ref[pl.ds(row.astype(_I), 1), :][0]
            empty = win0 == EMPTY_KEY
            tomb = win0 == TOMBSTONE_KEY
            cand = empty | tomb
            c_lane = _win_vote(cand)
            has_empty = jnp.any(empty)
            if multi_value:
                hit = jnp.zeros((), bool)
                m_lane = _I(window)
            else:
                match = (win0 == k0) & (win1 == k1)
                m_lane = _win_vote(match)
                hit = m_lane < window
            new_cand = jnp.logical_and(~have_cand, c_lane < window)
            crow = jnp.where(new_cand, row, crow)
            clane = jnp.where(new_cand, c_lane, clane)
            have_cand = have_cand | (c_lane < window)
            mrow = jnp.where(hit, row, mrow)
            mlane = jnp.where(hit, m_lane, mlane)
            matched = matched | hit
            done = have_cand if multi_value else (hit | has_empty)
            nrow = (row + step) % _U(num_rows)
            return (attempt + 1, jnp.where(done, row, nrow), done, crow,
                    clane, have_cand, mrow, mlane, matched)

        zu = jnp.zeros((), _U)
        zi = jnp.zeros((), _I)
        st = (zi, row0, jnp.zeros((), bool), zu, zi, jnp.zeros((), bool),
              zu, zi, jnp.zeros((), bool))
        (_, _, _, crow, clane, have_cand, mrow, mlane, matched) = \
            jax.lax.while_loop(cond, body, st)

        do_update = m & matched & (not multi_value)
        do_claim = m & ~matched & have_cand
        row = jnp.where(matched, mrow, crow).astype(_I)
        lane = jnp.where(matched, mlane, clane)
        write = do_update | do_claim
        lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
        sel = lanes == lane

        @pl.when(write)
        def _():
            vrow = tv_ref[pl.ds(row, 1), :][0]
            tv_ref[pl.ds(row, 1), :] = jnp.where(sel, v, vrow)[None, :]

        @pl.when(do_claim)
        def _():
            krow0 = tk0_ref[pl.ds(row, 1), :][0]
            tk0_ref[pl.ds(row, 1), :] = jnp.where(sel, k0, krow0)[None, :]
            krow1 = tk1_ref[pl.ds(row, 1), :][0]
            tk1_ref[pl.ds(row, 1), :] = jnp.where(sel, k1, krow1)[None, :]

        status_ref[0, j] = jnp.where(
            ~m, _I(STATUS_MASKED),
            jnp.where(do_update, _I(STATUS_UPDATED),
                      jnp.where(do_claim, _I(STATUS_INSERTED),
                                _I(STATUS_FULL))))
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def insert64_call(tk0, tk1, tv, k0_2d, k1_2d, vals2d, mask2d, *, seed,
                  max_probes, scheme="cops", multi_value=False,
                  interpret=True):
    num_rows, window = tk0.shape
    g, tile = k0_2d.shape
    kern = functools.partial(
        _insert64_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme, multi_value=multi_value)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, row_tile, row_tile, full, full, full],
        out_specs=[full, full, full, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((num_rows, window), _U),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2},
        interpret=interpret,
    )(k0_2d, k1_2d, vals2d, mask2d, tk0, tk1, tv)


def _lookup64_kernel(k0_ref, k1_ref, tk0_ref, tk1_ref, tv_ref, vals_ref,
                     found_ref, *, num_rows, window, seed, max_probes, scheme):
    tile = k0_ref.shape[1]

    def one_key(j, _):
        k0 = k0_ref[0, j]
        k1 = k1_ref[0, j]
        word = hashing.combine_planes(k1, k0)
        row0, step = _probe_setup(word, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            attempt, row, done, frow, flane, found = st
            win0 = tk0_ref[pl.ds(row.astype(_I), 1), :][0]
            win1 = tk1_ref[pl.ds(row.astype(_I), 1), :][0]
            match = (win0 == k0) & (win1 == k1)
            m_lane = _win_vote(match)
            hit = m_lane < window
            has_empty = jnp.any(win0 == EMPTY_KEY)
            frow = jnp.where(hit, row, frow)
            flane = jnp.where(hit, m_lane, flane)
            found = found | hit
            done = hit | has_empty
            nrow = (row + step) % _U(num_rows)
            return attempt + 1, jnp.where(done, row, nrow), done, frow, flane, found

        zu = jnp.zeros((), _U)
        zi = jnp.zeros((), _I)
        st = (zi, row0, jnp.zeros((), bool), zu, zi, jnp.zeros((), bool))
        _, _, _, frow, flane, found = jax.lax.while_loop(cond, body, st)
        vrow = tv_ref[pl.ds(frow.astype(_I), 1), :][0]
        lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
        val = jnp.max(jnp.where(lanes == flane, vrow, _U(0)))
        vals_ref[0, j] = jnp.where(found, val, _U(0))
        found_ref[0, j] = found.astype(_I)
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def lookup64_call(tk0, tk1, tv, k0_2d, k1_2d, *, seed, max_probes,
                  scheme="cops", interpret=True):
    num_rows, window = tk0.shape
    g, tile = k0_2d.shape
    kern = functools.partial(
        _lookup64_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, full, full, full],
        out_specs=[row_tile, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((g, tile), _U),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        interpret=interpret,
    )(k0_2d, k1_2d, tk0, tk1, tv)


def _retrieve64_kernel(k0_ref, k1_ref, mask_ref, tk0_ref, tk1_ref,
                       qa_in, ra_in, qa_ref, ra_ref, cnt_ref,
                       *, num_rows, window, seed, max_probes, scheme,
                       collect):
    """Two-plane fused retrieval walk (composite / u64 keys).

    Mirrors ``_retrieve_kernel`` with the window match ANDed over both
    key planes: one walk emits per-query counts and stamps (query, rank)
    into the slot arena.  Probe row/step come from the same
    ``combine_planes`` fold the host engines use, so the walk visits
    exactly the rows the jax path visits.
    """
    del qa_in, ra_in
    tile = k0_ref.shape[1]
    i = pl.program_id(0)

    def one_key(j, _):
        k0 = k0_ref[0, j]                 # primary plane (sentinels)
        k1 = k1_ref[0, j]
        m = mask_ref[0, j] != 0
        qidx = i * tile + j
        word = hashing.combine_planes(k1, k0)
        row0, step = _probe_setup(word, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, seen = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            attempt, row, done, seen = st
            ri = row.astype(_I)
            win0 = tk0_ref[pl.ds(ri, 1), :][0]
            win1 = tk1_ref[pl.ds(ri, 1), :][0]
            match = (win0 == k0) & (win1 == k1)
            nm = jnp.sum(match.astype(_I))
            has_empty = jnp.any(win0 == EMPTY_KEY)

            if collect:
                rank = jnp.cumsum(match.astype(_I)) - 1 + seen

                @pl.when(nm > 0)
                def _():
                    qrow = qa_ref[pl.ds(ri, 1), :][0]
                    qa_ref[pl.ds(ri, 1), :] = jnp.where(match, qidx,
                                                        qrow)[None, :]
                    rrow = ra_ref[pl.ds(ri, 1), :][0]
                    ra_ref[pl.ds(ri, 1), :] = jnp.where(match, rank,
                                                        rrow)[None, :]

            seen = seen + nm
            done = has_empty
            nrow = (row + step) % _U(num_rows)
            return attempt + 1, jnp.where(done, row, nrow), done, seen

        st = (jnp.zeros((), _I), row0, ~m, jnp.zeros((), _I))
        _, _, _, seen = jax.lax.while_loop(cond, body, st)
        cnt_ref[0, j] = seen
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def retrieve_multi64_call(tk0, tk1, qa0, ra0, k0_2d, k1_2d, mask2d, *, seed,
                          max_probes, scheme="cops", collect=True,
                          interpret=True):
    """Two-plane ``retrieve_multi_call``: k0/k1/mask (G, T), qa0/ra0 the
    sentinel-initialized (p, W) arena planes (aliased in/out).  Returns
    (qarena, rank_arena, counts2d)."""
    num_rows, window = tk0.shape
    g, tile = k0_2d.shape
    kern = functools.partial(
        _retrieve64_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme, collect=collect)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    arena = pl.BlockSpec(qa0.shape, lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, row_tile, full, full, arena, arena],
        out_specs=[arena, arena, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct(qa0.shape, _I),
            jax.ShapeDtypeStruct(ra0.shape, _I),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(k0_2d, k1_2d, mask2d, tk0, tk1, qa0, ra0)


# ---------------------------------------------------------------------------
# lookup
# ---------------------------------------------------------------------------

def _lookup_kernel(keys_ref, tk_ref, tv_ref, vals_ref, found_ref,
                   *, num_rows, window, seed, max_probes, scheme):
    tile = keys_ref.shape[1]

    def one_key(j, _):
        k = keys_ref[0, j]
        row0, step = _probe_setup(k, num_rows, seed, scheme)

        def cond(st):
            attempt, row, done, *_ = st
            return jnp.logical_and(attempt < max_probes, ~done)

        def body(st):
            attempt, row, done, frow, flane, found = st
            win = tk_ref[pl.ds(row.astype(_I), 1), :][0]
            match = win == k
            m_lane = _win_vote(match)
            hit = m_lane < window
            has_empty = jnp.any(win == EMPTY_KEY)
            frow = jnp.where(hit, row, frow)
            flane = jnp.where(hit, m_lane, flane)
            found = found | hit
            done = hit | has_empty
            nrow = (row + step) % _U(num_rows)
            return attempt + 1, jnp.where(done, row, nrow), done, frow, flane, found

        zu = jnp.zeros((), _U)
        zi = jnp.zeros((), _I)
        st = (zi, row0, jnp.zeros((), bool), zu, zi, jnp.zeros((), bool))
        _, _, _, frow, flane, found = jax.lax.while_loop(cond, body, st)

        vrow = tv_ref[pl.ds(frow.astype(_I), 1), :][0]
        lanes = jax.lax.broadcasted_iota(_I, (1, window), 1)[0]
        val = jnp.max(jnp.where(lanes == flane, vrow, _U(0)))
        vals_ref[0, j] = jnp.where(found, val, _U(0))
        found_ref[0, j] = found.astype(_I)
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def lookup_call(table_keys, table_vals, keys2d, *, seed, max_probes,
                scheme="cops", interpret=True):
    """keys2d: (G, T). Returns (vals2d, found2d)."""
    num_rows, window = table_keys.shape
    g, tile = keys2d.shape
    kern = functools.partial(
        _lookup_kernel, num_rows=num_rows, window=window, seed=seed,
        max_probes=max_probes, scheme=scheme)
    full = pl.BlockSpec((num_rows, window), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, full, full],
        out_specs=[row_tile, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((g, tile), _U),
            jax.ShapeDtypeStruct((g, tile), _I),
        ],
        interpret=interpret,
    )(keys2d, table_keys, table_vals)
