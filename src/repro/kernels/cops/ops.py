"""Jitted wrappers dispatching table ops to the COPS Pallas kernel.

Handles batch padding/tiling, table-struct plumbing, and the
interpret-mode switch (interpret=True everywhere except on real TPU).
Kernel path restrictions (the ``*_ok`` eligibility checks below): SOA
layout (``ops.planar``), 1-word values, and 1- or 2-plane keys — the
2-plane composite/u64 key variants ride the ``*64`` tiles for insert,
lookup and the fused retrieval walk.  Wider configurations (key_words >
2, multi-word values, group-by on composite keys) fall back to the
pure-JAX engines in repro.core, which handle any plane count.
"""

from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp

from repro.core import probing
from repro.core.common import EMPTY_KEY, STATUS_INSERTED, STATUS_MASKED
from repro.kernels.cops import kernel as K

_U = jnp.uint32
_I = jnp.int32

#: schemes the kernel tiles understand (bucketed = cops truncated to two
#: rows via the clamped budget; quotient stores change the compare target
#: per attempt and stay on the jax engines)
_KERNEL_SCHEMES = ("cops", "linear", "bucketed")


def should_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] != "0"
    return jax.default_backend() != "tpu"


def _probes(table) -> int:
    """Coverage-clamped probe budget for the kernel walks (the same
    ``probing.effective_probes`` clamp the jax/scan engines apply)."""
    return probing.effective_probes(table.scheme, table.max_probes,
                                    table.num_rows)


def _kernel_ok(table) -> bool:
    # the kernels take bare (p, W) planes: any plane-addressable protocol
    return (table.ops.planar and table.key_words in (1, 2)
            and table.value_words == 1
            and table.scheme in _KERNEL_SCHEMES
            and not table.ops.quotient)


def _tile_batch(x, tile, fill):
    n = x.shape[0]
    g = max(1, -(-n // tile))
    pad = g * tile - n
    x = jnp.pad(x, ((0, pad),), constant_values=fill)
    return x.reshape(g, tile), n


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme", "tile", "multi_value", "interpret"))
def _insert_jit(tk, tv, keys, vals, mask, *, seed, max_probes, scheme, tile,
                multi_value, interpret):
    k2, n = _tile_batch(keys, tile, EMPTY_KEY)
    v2, _ = _tile_batch(vals, tile, 0)
    m2, _ = _tile_batch(mask.astype(_I), tile, 0)
    tk, tv, st2 = K.insert_call(tk, tv, k2, v2, m2, seed=seed,
                                max_probes=max_probes, scheme=scheme,
                                multi_value=multi_value, interpret=interpret)
    return tk, tv, st2.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme", "tile", "multi_value", "interpret"))
def _insert64_jit(tk0, tk1, tv, k0, k1, vals, mask, *, seed, max_probes,
                  scheme, tile, multi_value, interpret):
    k0_2, n = _tile_batch(k0, tile, EMPTY_KEY)
    k1_2, _ = _tile_batch(k1, tile, 0)
    v2, _ = _tile_batch(vals, tile, 0)
    m2, _ = _tile_batch(mask.astype(_I), tile, 0)
    tk0, tk1, tv, st2 = K.insert64_call(
        tk0, tk1, tv, k0_2, k1_2, v2, m2, seed=seed, max_probes=max_probes,
        scheme=scheme, multi_value=multi_value, interpret=interpret)
    return tk0, tk1, tv, st2.reshape(-1)[:n]


def _insert_dispatch(table, keys, values, mask, multi_value):
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    values = sv.normalize_words(values, 1, "values")[:, 0]
    if mask is None:
        mask = jnp.ones(values.shape, bool)
    interp = should_interpret()
    tile = min(K.DEFAULT_TILE, values.shape[0])
    if table.key_words == 2:
        tk0, tk1 = table.store["keys"][0], table.store["keys"][1]
        tv = table.store["values"][0]
        tk0, tk1, tv, status = _insert64_jit(
            tk0, tk1, tv, keys[:, 0], keys[:, 1], values, mask,
            seed=table.seed, max_probes=_probes(table), scheme=table.scheme,
            tile=tile, multi_value=multi_value, interpret=interp)
        store = {"keys": jnp.stack([tk0, tk1]), "values": tv[None]}
    else:
        tk = table.store["keys"][0]
        tv = table.store["values"][0]
        tk, tv, status = _insert_jit(
            tk, tv, keys[:, 0], values, mask, seed=table.seed,
            max_probes=_probes(table), scheme=table.scheme, tile=tile,
            multi_value=multi_value, interpret=interp)
        store = {"keys": tk[None], "values": tv[None]}
    count = table.count + jnp.sum(status == STATUS_INSERTED, dtype=_I)
    return dataclasses.replace(table, store=store, count=count), status


def insert(table, keys, values, mask=None):
    """SingleValueHashTable upsert via the Pallas kernel (u32 or 2-plane u64
    keys — the paper's beyond-32-bit claim on the kernel path)."""
    from repro.core import single_value as sv
    if not _kernel_ok(table):
        jx = dataclasses.replace(table, backend="jax")
        if table.scheme == "bucketed":
            # bucketed callers wrap THIS function with the cuckoo rescue
            # (sv._insert_bucketed); the fallback must stay rescue-free
            # or the jax fallback would rescue twice and break parity
            from repro.core import bulk
            return bulk.insert_single(jx, keys, values, mask)
        return sv.insert(jx, keys, values, mask)
    return _insert_dispatch(table, keys, values, mask, multi_value=False)


def insert_multi(table, keys, values, mask=None):
    """MultiValueHashTable append via the Pallas kernel."""
    from repro.core import multi_value as mv
    if not _kernel_ok(table):
        jx = dataclasses.replace(table, backend="jax")
        if table.scheme == "bucketed":
            # rescue-free fallback — see insert()
            from repro.core import bulk
            return bulk.insert_multi(jx, keys, values, mask)
        return mv.insert(jx, keys, values, mask)
    return _insert_dispatch(table, keys, values, mask, multi_value=True)


def _groupby_ok(table) -> bool:
    # composite (key_words >= 2) group-bys fall back to the vectorized jax
    # RMW path — no *64 update tile yet (ROADMAP follow-on)
    return (table.ops.planar and table.key_words == 1
            and table.value_words == 2
            and table.scheme in _KERNEL_SCHEMES
            and not table.ops.quotient)


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme",
                                             "tile", "agg", "interpret"))
def _update_jit(tk, tv0, tv1, keys, vals, mask, *, seed, max_probes, scheme,
                tile, agg, interpret):
    k2, n = _tile_batch(keys, tile, EMPTY_KEY)
    v2, _ = _tile_batch(vals, tile, 0)
    m2, _ = _tile_batch(mask.astype(_I), tile, 0)
    tk, tv0, tv1, st2 = K.update_call(tk, tv0, tv1, k2, v2, m2, seed=seed,
                                      max_probes=max_probes, scheme=scheme,
                                      agg=agg, interpret=interpret)
    return tk, tv0, tv1, st2.reshape(-1)[:n]


def update_groupby(table, agg, keys, payload, mask=None):
    """Fused group-by RMW via the Pallas tile (probe + fold + store while
    the table shard stays in VMEM) — the kernel path that replaces the
    update_values scan fallback for aggregates.  ``payload`` is the
    (n, 2) [operand, weight] plane pair built by relational.groupby.
    Wider configurations fall back to the vectorized jax path.
    """
    from repro.core import single_value as sv
    from repro.relational import groupby as gb
    if not _groupby_ok(table):
        jx = dataclasses.replace(table, backend="jax")
        t, status = sv.update_values(jx, keys, gb._fold_fn(agg), payload,
                                     mask=mask, combine=gb._combine_fn(agg))
        return dataclasses.replace(t, backend=table.backend), status
    keys = sv.normalize_key_batch(keys, 1, "keys")[:, 0]
    vals = payload[:, 0]
    if mask is None:
        mask = jnp.ones(keys.shape, bool)
    tile = min(K.DEFAULT_TILE, keys.shape[0])
    tk = table.store["keys"][0]
    tv0, tv1 = table.store["values"][0], table.store["values"][1]
    tk, tv0, tv1, status = _update_jit(
        tk, tv0, tv1, keys, vals, mask, seed=table.seed,
        max_probes=_probes(table), scheme=table.scheme, tile=tile, agg=agg,
        interpret=should_interpret())
    store = {"keys": tk[None], "values": jnp.stack([tv0, tv1])}
    count = table.count + jnp.sum(status == STATUS_INSERTED, dtype=_I)
    return dataclasses.replace(table, store=store, count=count), status


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme", "tile", "interpret"))
def _lookup_jit(tk, tv, keys, *, seed, max_probes, scheme, tile, interpret):
    k2, n = _tile_batch(keys, tile, EMPTY_KEY)
    v2, f2 = K.lookup_call(tk, tv, k2, seed=seed, max_probes=max_probes,
                           scheme=scheme, interpret=interpret)
    return v2.reshape(-1)[:n], f2.reshape(-1)[:n] != 0


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme", "tile", "interpret"))
def _lookup64_jit(tk0, tk1, tv, k0, k1, *, seed, max_probes, scheme, tile,
                  interpret):
    k0_2, n = _tile_batch(k0, tile, EMPTY_KEY)
    k1_2, _ = _tile_batch(k1, tile, 0)
    v2, f2 = K.lookup64_call(tk0, tk1, tv, k0_2, k1_2, seed=seed,
                             max_probes=max_probes, scheme=scheme,
                             interpret=interpret)
    return v2.reshape(-1)[:n], f2.reshape(-1)[:n] != 0


# ---------------------------------------------------------------------------
# fused multi-value retrieval — the walk tile + the engine's compaction
# ---------------------------------------------------------------------------

def _retrieve_ok(table) -> bool:
    # 1-word keys walk the u32 tile, 2-plane composite/u64 keys the *64
    # tile; wider composite keys (key_words > 2) fall back to the jax
    # engine, whose general lane handles any plane count
    return (table.ops.planar and table.key_words in (1, 2)
            and table.scheme in _KERNEL_SCHEMES
            and not table.ops.quotient)


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme",
                                             "tile", "sentinel", "collect",
                                             "interpret"))
def _retrieve_walk_jit(tk, keys, active, *, seed, max_probes, scheme, tile,
                       sentinel, collect, interpret):
    num_rows, window = tk.shape
    k2, n = _tile_batch(keys, tile, EMPTY_KEY)
    m2, _ = _tile_batch(active.astype(_I), tile, 0)
    ashape = (num_rows, window) if collect else (1, 1)
    qa0 = jnp.full(ashape, _I(sentinel), _I)
    ra0 = jnp.zeros(ashape, _I)
    qa, ra, cnt2 = K.retrieve_multi_call(tk, qa0, ra0, k2, m2, seed=seed,
                                         max_probes=max_probes, scheme=scheme,
                                         collect=collect, interpret=interpret)
    return cnt2.reshape(-1)[:n], qa.reshape(-1), ra.reshape(-1)


@functools.partial(jax.jit, static_argnames=("seed", "max_probes", "scheme",
                                             "tile", "sentinel", "collect",
                                             "interpret"))
def _retrieve_walk64_jit(tk0, tk1, k0, k1, active, *, seed, max_probes,
                         scheme, tile, sentinel, collect, interpret):
    num_rows, window = tk0.shape
    k0_2, n = _tile_batch(k0, tile, EMPTY_KEY)
    k1_2, _ = _tile_batch(k1, tile, 0)
    m2, _ = _tile_batch(active.astype(_I), tile, 0)
    ashape = (num_rows, window) if collect else (1, 1)
    qa0 = jnp.full(ashape, _I(sentinel), _I)
    ra0 = jnp.zeros(ashape, _I)
    qa, ra, cnt2 = K.retrieve_multi64_call(tk0, tk1, qa0, ra0, k0_2, k1_2,
                                           m2, seed=seed,
                                           max_probes=max_probes,
                                           scheme=scheme, collect=collect,
                                           interpret=interpret)
    return cnt2.reshape(-1)[:n], qa.reshape(-1), ra.reshape(-1)


def _fused_walk_pallas(table, keys_n, live, collect=True):
    """Dedup front-end + kernel walk; returns (is_rep, rep_of, rcnt, qa, ra).

    Dispatches on ``table.key_words``: 1 -> the u32 walk tile, 2 -> the
    two-plane composite/u64 tile (callers gate wider keys via
    ``_retrieve_ok``).
    """
    from repro.core import bulk_retrieve as br
    n = keys_n.shape[0]
    is_rep, rep_of = br.group_queries(keys_n, live)
    tile = min(K.DEFAULT_TILE, n)
    if table.key_words == 2:
        rcnt, qa, ra = _retrieve_walk64_jit(
            table.store["keys"][0], table.store["keys"][1], keys_n[:, 0],
            keys_n[:, 1], is_rep, seed=table.seed,
            max_probes=_probes(table), scheme=table.scheme, tile=tile,
            sentinel=n, collect=collect, interpret=should_interpret())
    else:
        rcnt, qa, ra = _retrieve_walk_jit(
            table.store["keys"][0], keys_n[:, 0], is_rep, seed=table.seed,
            max_probes=_probes(table), scheme=table.scheme, tile=tile,
            sentinel=n, collect=collect, interpret=should_interpret())
    return is_rep, rep_of, rcnt, qa, ra


def count_multi(table, keys, mask=None):
    """MultiValueHashTable counting pass via the counts-only walk tile
    (no arena planes allocated or written)."""
    from repro.core import bulk_retrieve as br
    from repro.core import single_value as sv
    keys_n = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys_n.shape[0]
    if n == 0 or not _retrieve_ok(table):
        return br.count_multi(table, keys_n, mask)
    live = jnp.ones((n,), bool) if mask is None else mask
    _, rep_of, rcnt, _, _ = _fused_walk_pallas(table, keys_n, live,
                                               collect=False)
    return br._fan_out(rcnt, rep_of, live, n)


def retrieve_all_multi(table, keys, out_capacity, mask=None):
    """MultiValueHashTable retrieve_all: one kernel walk, then the
    bulk-retrieval engine's scatter/gather compaction."""
    from repro.core import bulk_retrieve as br
    from repro.core import single_value as sv
    keys_n = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys_n.shape[0]
    if n == 0 or not _retrieve_ok(table):
        return br.retrieve_all_multi(table, keys_n, out_capacity, mask)
    live = jnp.ones((n,), bool) if mask is None else mask
    is_rep, rep_of, rcnt, qa, ra = _fused_walk_pallas(table, keys_n, live)
    counts = br._fan_out(rcnt, rep_of, live, n)
    out, offsets, counts = br._emit_store(table, out_capacity, counts,
                                          is_rep, rep_of, rcnt, qa, ra)
    if table.value_words == 1:
        return out[:, 0], offsets, counts
    return out, offsets, counts


# ---------------------------------------------------------------------------
# bucket-list retrieval — kernel chain walk + the engine's compaction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("tile", "sentinel", "chunk",
                                             "interpret"))
def _bucket_walk_jit(pool, ptr, cnt, bidx, act, sizes, cum, *, tile,
                     sentinel, chunk, interpret):
    p2, n = _tile_batch(ptr, tile, 0)
    c2, _ = _tile_batch(cnt, tile, 0)
    b2, _ = _tile_batch(bidx, tile, 0)
    a2, _ = _tile_batch(act.astype(_I), tile, 0)
    pool_cap = pool.shape[0]
    # `chunk` slots of arena padding: a chunked window may run past the
    # pool's edge on the last bucket (see the kernel header note)
    qa0 = jnp.full((1, pool_cap + chunk), _I(sentinel), _I)
    ra0 = jnp.zeros((1, pool_cap + chunk), _I)
    qa, ra = K.bucket_walk_call(pool[None, :], qa0, ra0, p2, c2, b2, a2,
                                sizes[None, :], cum[None, :], chunk=chunk,
                                interpret=interpret)
    return qa[0, :pool_cap], ra[0, :pool_cap]


def bucket_retrieve_all(table, keys, out_capacity):
    """BucketListHashTable retrieve_all via the bucket-walk tile.

    Handles are pre-probed host-side (counts are O(1) off the handle, so
    only the chain walk runs on-core); the tile stamps the pool slot arena
    in VMEM and the compaction is shared with the jax engine — mirroring
    how ``retrieve_all_multi`` wraps the fused retrieve tile.
    """
    from repro.core import bucket_list as bl
    from repro.core import bulk_retrieve as br
    from repro.core import single_value as sv
    ks = table.key_store
    keys_n = sv.normalize_key_batch(keys, ks.key_words, "keys")
    n = keys_n.shape[0]
    if n == 0 or not (ks.ops.planar and ks.key_words == 1):
        return bl._retrieve_fused(table, keys_n, out_capacity)
    is_rep, rep_of, found, ptr, rcnt, bidx, counts = bl._handle_probe(
        table, keys_n)
    tile = min(K.DEFAULT_TILE, n)
    chunk = int(min(max(table.sizes), K.BUCKET_CHUNK))
    qa, ra = _bucket_walk_jit(
        table.pool, ptr, rcnt, bidx, found,
        jnp.asarray(table.sizes, _I), jnp.asarray(table.cum, _I),
        tile=tile, sentinel=n, chunk=chunk, interpret=should_interpret())
    out, offsets, counts = br._emit(
        lambda s: table.pool[s][:, None], table.pool_capacity, out_capacity,
        counts, is_rep, rep_of, rcnt, qa, ra)
    return out[:, 0], offsets, counts


def retrieve(table, keys):
    """Batch lookup via the Pallas kernel -> (values, found)."""
    from repro.core import single_value as sv
    if not _kernel_ok(table):
        return sv.retrieve(dataclasses.replace(table, backend="jax"), keys)
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    tile = min(K.DEFAULT_TILE, keys.shape[0])
    if table.key_words == 2:
        return _lookup64_jit(
            table.store["keys"][0], table.store["keys"][1],
            table.store["values"][0], keys[:, 0], keys[:, 1],
            seed=table.seed, max_probes=_probes(table), scheme=table.scheme,
            tile=tile, interpret=should_interpret())
    return _lookup_jit(table.store["keys"][0], table.store["values"][0],
                       keys[:, 0], seed=table.seed,
                       max_probes=_probes(table), scheme=table.scheme,
                       tile=tile, interpret=should_interpret())
