"""Jitted wrappers for the blocked-bloom Pallas kernel (packed u32 words)."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import bloom as bloom_core
from repro.kernels.bloom import kernel as K
from repro.kernels.cops.ops import should_interpret

_U = jnp.uint32
_I = jnp.int32


def _tile(x, tile, fill):
    n = x.shape[0]
    g = max(1, -(-n // tile))
    x = jnp.pad(x, ((0, g * tile - n),), constant_values=fill)
    return x.reshape(g, tile), n


@functools.partial(jax.jit, static_argnames=("k_hashes", "seed", "tile", "interpret"))
def insert_words(filt_words, keys, mask, *, k_hashes, seed, tile=K.DEFAULT_TILE,
                 interpret=True):
    """Insert keys into a packed (num_blocks, words) u32 filter."""
    k2, _ = _tile(keys.astype(_U), tile, 0)
    m2, _ = _tile(mask.astype(_I), tile, 0)
    return K.insert_call(filt_words, k2, m2, k_hashes=k_hashes, seed=seed,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k_hashes", "seed", "tile", "interpret"))
def query_words(filt_words, keys, *, k_hashes, seed, tile=K.DEFAULT_TILE,
                interpret=True):
    k2, n = _tile(keys.astype(_U), tile, 0)
    out = K.query_call(filt_words, k2, k_hashes=k_hashes, seed=seed,
                       interpret=interpret)
    return out.reshape(-1)[:n] != 0


def insert(f: bloom_core.BloomFilter, keys, mask=None) -> bloom_core.BloomFilter:
    """BloomFilter insert via the Pallas kernel (state stays bit-plane typed)."""
    keys = jnp.asarray(keys)
    if mask is None:
        mask = jnp.ones(keys.shape, bool)
    words = bloom_core.pack_words(f)
    words = insert_words(words, keys, mask, k_hashes=f.k, seed=f.seed,
                         interpret=should_interpret())
    return bloom_core.unpack_words(words, f.block_bits, f.k, f.seed)


def contains(f: bloom_core.BloomFilter, keys) -> jax.Array:
    words = bloom_core.pack_words(f)
    return query_words(words, jnp.asarray(keys), k_hashes=f.k, seed=f.seed,
                       interpret=should_interpret())
