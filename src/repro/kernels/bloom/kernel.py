"""Pallas TPU kernel for the blocked bloom filter (paper §IV: BloomFilter).

The filter is (num_blocks, words) packed u32 bit-words; each key touches
exactly one block (one vector row — the "one memory transaction" property of
blocked bloom filters, preserved on TPU as one VMEM row access).  Insert is
a row read-OR-write; since the whole filter is VMEM-resident and grid steps
are sequential, read-modify-write is race-free.  Query needs no
serialization at all but uses the same row-gather structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing

_U = jnp.uint32
_I = jnp.int32

DEFAULT_TILE = 256


def _key_pattern(k, num_blocks, words, k_hashes, seed):
    """(block_row, (words,) u32 OR-pattern) for one key."""
    block = hashing.mix_murmur3(k ^ _U(seed)) % _U(num_blocks)
    h = hashing.mix_xxhash(k ^ _U(seed))
    g = hashing.mix_murmur3(k + _U(0x61C88647))
    bits = words * 32
    word_iota = jax.lax.broadcasted_iota(_U, (1, words), 1)[0]
    pattern = jnp.zeros((words,), _U)
    for i in range(k_hashes):
        pos = (h + _U(i) * g) % _U(bits)
        widx = pos // _U(32)
        bit = pos % _U(32)
        contrib = jnp.where(word_iota == widx,
                            jax.lax.shift_left(_U(1), bit), _U(0))
        pattern = pattern | contrib
    return block, pattern


def _insert_kernel(keys_ref, mask_ref, filt_in_ref, filt_ref,
                   *, num_blocks, words, k_hashes, seed):
    del filt_in_ref
    tile = keys_ref.shape[1]

    def one_key(j, _):
        k = keys_ref[0, j]
        m = mask_ref[0, j] != 0
        block, pattern = _key_pattern(k, num_blocks, words, k_hashes, seed)

        @pl.when(m)
        def _():
            row = filt_ref[pl.ds(block.astype(_I), 1), :][0]
            filt_ref[pl.ds(block.astype(_I), 1), :] = (row | pattern)[None, :]

        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def insert_call(filt, keys2d, mask2d, *, k_hashes, seed, interpret=True):
    num_blocks, words = filt.shape
    g, tile = keys2d.shape
    kern = functools.partial(_insert_kernel, num_blocks=num_blocks, words=words,
                             k_hashes=k_hashes, seed=seed)
    full = pl.BlockSpec((num_blocks, words), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, row_tile, full],
        out_specs=full,
        out_shape=jax.ShapeDtypeStruct((num_blocks, words), _U),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(keys2d, mask2d, filt)


def _query_kernel(keys_ref, filt_ref, out_ref,
                  *, num_blocks, words, k_hashes, seed):
    tile = keys_ref.shape[1]

    def one_key(j, _):
        k = keys_ref[0, j]
        block, pattern = _key_pattern(k, num_blocks, words, k_hashes, seed)
        row = filt_ref[pl.ds(block.astype(_I), 1), :][0]
        hit = jnp.all((row & pattern) == pattern)
        out_ref[0, j] = hit.astype(_I)
        return 0

    jax.lax.fori_loop(0, tile, one_key, 0)


def query_call(filt, keys2d, *, k_hashes, seed, interpret=True):
    num_blocks, words = filt.shape
    g, tile = keys2d.shape
    kern = functools.partial(_query_kernel, num_blocks=num_blocks, words=words,
                             k_hashes=k_hashes, seed=seed)
    full = pl.BlockSpec((num_blocks, words), lambda i: (0, 0))
    row_tile = pl.BlockSpec((1, tile), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[row_tile, full],
        out_specs=row_tile,
        out_shape=jax.ShapeDtypeStruct((g, tile), _I),
        interpret=interpret,
    )(keys2d, filt)
