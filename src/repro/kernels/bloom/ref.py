"""Pure-jnp oracle for the bloom kernel: the bit-plane implementation in
``repro.core.bloom`` (scatter-max over unpacked bits — a different code path
from the kernel's packed-word OR)."""

from __future__ import annotations

from repro.core import bloom as bloom_core

insert = bloom_core.insert
contains = bloom_core.contains
