"""Pure-jnp oracle for the k-mer/minhash kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

_U = jnp.uint32

INVALID = np.uint32(0xFFFFFFFF)


def kmer_hashes(bases: jax.Array, k: int) -> jax.Array:
    """Canonical k-mer hash per window start; (L,) -> (L - k + 1,)."""
    bases = jnp.asarray(bases).astype(_U)
    n = bases.shape[0] - k + 1
    fwd = jnp.zeros((n,), _U)
    rev = jnp.zeros((n,), _U)
    bad = jnp.zeros((n,), bool)
    for j in range(k):
        b = bases[j:j + n]
        bad = bad | (b > _U(3))
        fwd = (fwd << _U(2)) | (b & _U(3))
        rev = rev | ((_U(3) - (b & _U(3))) << _U(2 * j))
    canon = jnp.minimum(fwd, rev)
    return jnp.where(bad, INVALID, hashing.mix_murmur3(canon))


def minhash_sketch(hashes: jax.Array, s: int) -> jax.Array:
    """The s smallest *distinct* valid hashes, INVALID-padded (MetaCache [20])."""
    h = jnp.sort(hashes)
    distinct = jnp.concatenate([jnp.ones((1,), bool), h[1:] != h[:-1]])
    keep = distinct & (h != INVALID)
    # stable-compact the kept entries to the front, then take s
    order = jnp.argsort(~keep, stable=True)
    compacted = jnp.where(keep[order], h[order], INVALID)
    return compacted[:s]
