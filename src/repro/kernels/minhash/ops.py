"""Jitted wrappers for k-mer hashing + minhash sketching.

``sketch_reads`` is the full front half of the paper's metagenomics pipeline
(§V-C): reads -> canonical k-mer hashes (Pallas kernel) -> per-read minhash
sketch (s smallest distinct hashes).  The sketches feed straight into a
MultiValue/BucketList table insert — the same fusion the paper gets from
its device-sided interface.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cops.ops import should_interpret
from repro.kernels.minhash import kernel as K
from repro.kernels.minhash.ref import INVALID, minhash_sketch

_U = jnp.uint32


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def kmer_hashes(bases, *, k, tile=K.DEFAULT_TILE, interpret=True):
    """(L,) base codes -> (L - k + 1,) canonical k-mer hashes via the kernel."""
    bases = jnp.asarray(bases)
    n_out = bases.shape[0] - k + 1
    g = max(1, -(-n_out // tile))
    # build overlapped (G, tile + k - 1) tiles; pad tail with invalid bases
    padded_len = g * tile + k - 1
    bases = jnp.pad(bases, ((0, padded_len - bases.shape[0]),), constant_values=255)
    starts = jnp.arange(g) * tile
    idx = starts[:, None] + jnp.arange(tile + k - 1)[None, :]
    tiles = bases[idx]
    out = K.kmer_hash_call(tiles, k=k, interpret=interpret)
    return out.reshape(-1)[:n_out]


@functools.partial(jax.jit, static_argnames=("k", "s", "interpret"))
def sketch_reads(reads, *, k, s, interpret=True):
    """(R, L) base-code reads -> (R, s) minhash sketches (INVALID-padded)."""
    reads = jnp.asarray(reads)
    hashes = jax.vmap(lambda r: kmer_hashes(r, k=k, interpret=interpret))(reads)
    return jax.vmap(lambda h: minhash_sketch(h, s))(hashes)


def sketch_reads_auto(reads, *, k, s):
    return sketch_reads(reads, k=k, s=s, interpret=should_interpret())
