"""Pallas TPU kernel: canonical k-mer extraction + hashing (paper §V-C).

The metagenomics use case stores minhash-subsampled k-mers in a multi-value
table.  k-mer generation is the bandwidth-bound front half of that pipeline
(the paper ports it to CUDA for the same reason); it is a perfect VPU
workload: per output position, k unrolled shift-or steps over 2-bit base
codes — no gathers, no serialization.

Input: 2-bit base codes (0..3; >=4 marks N/invalid) in overlapped (G, T+k-1)
tiles.  Output: (G, T) u32 hashes of the *canonical* k-mer (min of forward
and reverse-complement encodings, as MetaCache/Kraken do), with INVALID
(0xFFFFFFFF) where the window contains an invalid base or runs off the read.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import hashing

_U = jnp.uint32
_I = jnp.int32

INVALID = np.uint32(0xFFFFFFFF)
DEFAULT_TILE = 512


def _kmer_kernel(bases_ref, out_ref, *, k, tile):
    row = bases_ref[0, :].astype(_U)                  # (tile + k - 1,)
    fwd = jnp.zeros((tile,), _U)
    rev = jnp.zeros((tile,), _U)
    bad = jnp.zeros((tile,), bool)
    for j in range(k):                                # k static, unrolled
        b = jax.lax.dynamic_slice_in_dim(row, j, tile)
        bad = bad | (b > _U(3))
        fwd = (fwd << _U(2)) | (b & _U(3))
        comp = _U(3) - (b & _U(3))
        rev = rev | (comp << _U(2 * j))
    canon = jnp.minimum(fwd, rev)
    h = hashing.mix_murmur3(canon)
    out_ref[0, :] = jnp.where(bad, INVALID, h)


def kmer_hash_call(bases2d, *, k, interpret=True):
    """bases2d: (G, T + k - 1) overlapped tiles -> (G, T) canonical kmer hashes."""
    g, padded = bases2d.shape
    tile = padded - (k - 1)
    kern = functools.partial(_kmer_kernel, k=k, tile=tile)
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, padded), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, tile), _U),
        interpret=interpret,
    )(bases2d)
