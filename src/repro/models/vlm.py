"""VLM wrapper (internvl2): stub ViT frontend + LM backbone.

The assignment models the transformer backbone only; the InternViT frontend
is a STUB whose output — (B, num_patches, d_model) patch embeddings — is an
*input* supplied by ``input_specs()``.  The wrapper projects the patch
embeddings through a learned adapter (``frontend_proj``), prepends them to
the token embeddings, and computes loss only over text positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import Params


def vlm_loss(cfg: ModelConfig, params: Params, batch: dict):
    """batch: patches (B, P, D), tokens (B, S_text), labels (B, S_text)."""
    return tf.lm_loss(cfg, params, {
        "tokens": batch["tokens"],
        "labels": batch["labels"],
        "prefix_embeds": batch["patches"],
        "loss_mask": batch.get("loss_mask"),
    })


def vlm_prefill(cfg: ModelConfig, params: Params, batch: dict, max_seq: int):
    return tf.lm_prefill(cfg, params, batch["tokens"], max_seq,
                         prefix_embeds=batch["patches"])
