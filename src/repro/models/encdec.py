"""Encoder-decoder backbone (whisper-small).

The conv audio frontend is a STUB per the assignment: callers supply
precomputed (B, T_frames, d_model) frame embeddings (``input_specs`` emits
ShapeDtypeStructs for them in the dry-run).  Sinusoidal absolute positions
are used on both sides (whisper's learned decoder table is capped at 448
positions; the assigned decode_32k cell requires 32k, so we substitute
sinusoidal — recorded as a hardware/shape adaptation in DESIGN.md).

Encoder blocks: [ln -> bidirectional MHA -> ln -> gelu MLP], scanned.
Decoder blocks: [ln -> causal self-attn -> ln -> cross-attn -> ln -> MLP].
Decode keeps a self-attn KV cache and per-layer cross K/V computed once from
the encoder output at prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import shardutil
from repro.models.layers import (
    DTYPES,
    Params,
    embed,
    init_embedding,
    init_linear,
    init_mlp,
    layernorm,
    init_layernorm,
    linear,
    mlp,
    sinusoidal_positions,
    softmax_cross_entropy,
    unembed,
)


def _init_enc_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "attn": attn.init_gqa(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.resolved_head_dim, dtype),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, kind=cfg.mlp_kind),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "self_attn": attn.init_gqa(k1, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.resolved_head_dim, dtype),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "cross_attn": attn.init_gqa(k2, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim, dtype),
        "ln3": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype, kind=cfg.mlp_kind),
    }


def init_encdec(cfg: ModelConfig, key) -> Params:
    dtype = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    ek = jax.random.split(ks[0], cfg.encoder_layers)
    dk = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(ek),
        "enc_norm": init_layernorm(cfg.d_model, dtype),
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dk),
        "dec_norm": init_layernorm(cfg.d_model, dtype),
    }


def _cross_attention(p: Params, x: jax.Array, enc: jax.Array, cfg: ModelConfig,
                     ) -> jax.Array:
    """q from decoder states, k/v from encoder output (no rope)."""
    b, sq, _ = x.shape
    se = enc.shape[1]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = h // hkv
    q = linear(p["wq"], x).reshape(b, sq, hkv, rep, hd)
    k = linear(p["wk"], enc).reshape(b, se, hkv, hd)
    v = linear(p["wv"], enc).reshape(b, se, hkv, hd)
    # pad encoder length to a chunkable multiple of 128, masking the padding
    pad = (-se) % 128
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    o = attn.chunked_causal_attention(q, k, v, causal=False,
                                      q_chunk=512, k_chunk=min(1536, se + pad),
                                      kv_valid=se if pad else None)
    o = o.reshape(b, sq, h * hd)
    return linear(p["wo"], o)


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, T, D) stub-frontend embeddings -> encoder states."""
    t = frames.shape[1]
    pos = jnp.asarray(sinusoidal_positions(t, cfg.d_model), frames.dtype)
    h = shardutil.constrain_batch(frames + pos[None])

    def body(h, p):
        hn = layernorm(p["ln1"], h)
        h = h + attn.gqa_train(p["attn"], hn, num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads,
                               head_dim=cfg.resolved_head_dim,
                               rope_theta=cfg.rope_theta, causal=False,
                               use_rope=False)
        hn = layernorm(p["ln2"], h)
        h = h + mlp(p["mlp"], hn, kind=cfg.mlp_kind)
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layernorm(params["enc_norm"], h)


def decode_train(cfg: ModelConfig, params: Params, enc: jax.Array,
                 tokens: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S, V) fp32."""
    b, s = tokens.shape
    h = embed(params["embed"], tokens)
    pos = jnp.asarray(sinusoidal_positions(s, cfg.d_model), h.dtype)
    h = shardutil.constrain_batch(h + pos[None])

    def body(h, p):
        hn = layernorm(p["ln1"], h)
        h = h + attn.gqa_train(p["self_attn"], hn, num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads,
                               head_dim=cfg.resolved_head_dim,
                               rope_theta=cfg.rope_theta, causal=True,
                               use_rope=False)
        hn = layernorm(p["ln2"], h)
        h = h + _cross_attention(p["cross_attn"], hn, enc, cfg)
        hn = layernorm(p["ln3"], h)
        h = h + mlp(p["mlp"], hn, kind=cfg.mlp_kind)
        return h, None

    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    h = layernorm(params["dec_norm"], h)
    return unembed(params["embed"], h)


def encdec_loss(cfg: ModelConfig, params: Params, batch: dict):
    enc = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, enc, batch["tokens"])
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# serving: prefill (encoder + cross-KV) and one-token decode
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      enc_len: int) -> dict:
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    return {
        "self_k": jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, hd),
                            jnp.bfloat16),
        "self_v": jnp.zeros((l, batch, max_seq, cfg.num_kv_heads, hd),
                            jnp.bfloat16),
        "cross_k": jnp.zeros((l, batch, enc_len, cfg.num_kv_heads, hd),
                             jnp.bfloat16),
        "cross_v": jnp.zeros((l, batch, enc_len, cfg.num_kv_heads, hd),
                             jnp.bfloat16),
    }


def encdec_prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
                   cache: dict) -> dict:
    """Run the encoder and fill per-layer cross K/V."""
    enc = encode(cfg, params, frames)
    b, se, _ = enc.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def per_block(p):
        ca = p["cross_attn"]
        k = linear(ca["wk"], enc).reshape(b, se, hkv, hd)
        v = linear(ca["wv"], enc).reshape(b, se, hkv, hd)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    ks, vs = jax.lax.map(per_block, params["dec_blocks"])
    return {**cache, "cross_k": ks, "cross_v": vs}


def encdec_decode_step(cfg: ModelConfig, params: Params, cache: dict,
                       tokens: jax.Array, pos: jax.Array):
    """tokens: (B, 1). Returns (logits (B, 1, V) fp32, cache)."""
    b = tokens.shape[0]
    h = embed(params["embed"], tokens)
    hd = cfg.resolved_head_dim
    hkv = cfg.num_kv_heads
    rep = cfg.num_heads // hkv
    posv = jnp.asarray(sinusoidal_positions(1, cfg.d_model), h.dtype)  # pos 0
    # absolute position: compute sin/cos at `pos` on the fly
    d = cfg.d_model
    dim = jnp.arange(0, d, 2)
    ang = pos.astype(jnp.float32) / (10000 ** (dim / d))
    pe = jnp.zeros((d,), jnp.float32).at[0::2].set(jnp.sin(ang)).at[1::2].set(
        jnp.cos(ang))
    h = shardutil.constrain_batch(h + pe.astype(h.dtype)[None, None, :])

    def body(h, xs):
        p, sk, sv, ck, cv = xs
        hn = layernorm(p["ln1"], h)
        y, sk, sv = attn.gqa_decode(p["self_attn"], hn, sk, sv, pos,
                                    num_heads=cfg.num_heads, num_kv_heads=hkv,
                                    head_dim=hd, rope_theta=cfg.rope_theta,
                                    use_rope=False)
        h = h + y
        hn = layernorm(p["ln2"], h)
        # cross attention against precomputed K/V (full, enc_len is short)
        q = linear(p["cross_attn"]["wq"], hn).reshape(b, hkv, rep, hd)
        s = jnp.einsum("bhrd,bshd->bhrs", q, ck,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhrs,bshd->bhrd", w.astype(cv.dtype), cv)
        o = o.reshape(b, 1, cfg.num_heads * hd).astype(h.dtype)
        h = h + linear(p["cross_attn"]["wo"], o)
        hn = layernorm(p["ln3"], h)
        h = h + mlp(p["mlp"], hn, kind=cfg.mlp_kind)
        return h, (sk, sv)

    h, (sks, svs) = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    h = layernorm(params["dec_norm"], h)
    logits = unembed(params["embed"], h)
    return logits, {**cache, "self_k": sks, "self_v": svs}
