"""RWKV-6 ("Finch", arXiv:2404.05892) — attention-free time mixing with
data-dependent decay.

Per head (size N), the WKV state is an (N, N) outer-product accumulator:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with the decay w_t produced *per token per channel* by a low-rank MLP (the
Finch innovation over RWKV-5's static decay).  Token-shift mixing is also
data-dependent (low-rank lerp).  The sequence is processed by lax.scan with
O(1) state, so long_500k decode is a pure state update — no cache at all
(the hash-table serving path is inapplicable to this family; DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, init_linear, linear, truncated_normal


def init_rwkv_tmix(key, d_model: int, num_heads: int, *, decay_rank: int = 64,
                   mix_rank: int = 32, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 12)
    n = d_model // num_heads
    return {
        "mu": truncated_normal(ks[0], (5, d_model), 0.02, jnp.float32),
        "mix_a": truncated_normal(ks[1], (d_model, mix_rank * 5), 0.02, dtype),
        "mix_b": truncated_normal(ks[2], (5, mix_rank, d_model), 0.02, dtype),
        "wr": init_linear(ks[3], d_model, d_model, dtype),
        "wk": init_linear(ks[4], d_model, d_model, dtype),
        "wv": init_linear(ks[5], d_model, d_model, dtype),
        "wg": init_linear(ks[6], d_model, d_model, dtype),
        "wo": init_linear(ks[7], d_model, d_model, dtype),
        "w0": truncated_normal(ks[8], (d_model,), 0.02, jnp.float32) - 4.0,
        "decay_a": truncated_normal(ks[9], (d_model, decay_rank), 0.02, dtype),
        "decay_b": truncated_normal(ks[10], (decay_rank, d_model), 0.02, dtype),
        "u": truncated_normal(ks[11], (num_heads, n), 0.02, jnp.float32),
        "ln_scale": jnp.ones((d_model,), jnp.float32),
    }


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift: five mixed streams (r, k, v, w, g)."""
    xx = x_prev - x                                           # (B, S, D)
    mix_rank = p["mix_a"].shape[1] // 5
    low = jnp.tanh(x @ p["mix_a"]).reshape(*x.shape[:-1], 5, mix_rank)
    dyn = jnp.einsum("...fr,frd->...fd", low, p["mix_b"])     # (B,S,5,D)
    mu = p["mu"].astype(x.dtype)                              # (5, D)
    lerp = mu[None, None] + dyn                               # (B,S,5,D)
    mixed = x[..., None, :] + xx[..., None, :] * lerp
    return [mixed[..., i, :] for i in range(5)]


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """Per-token per-channel decay in (0, 1): exp(-exp(w0 + lora))."""
    lora = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    logw = p["w0"][None, None, :] + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def _group_norm(scale: jax.Array, x: jax.Array, num_heads: int) -> jax.Array:
    """Per-head layernorm on the WKV output (RWKV's group_norm)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, num_heads, d // num_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y.reshape(b, s, d) * scale[None, None, :]).astype(x.dtype)


def rwkv_tmix_train(p: Params, x: jax.Array, *, num_heads: int) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    n = d // num_heads
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = linear(p["wr"], xr).reshape(b, s, num_heads, n)
    k = linear(p["wk"], xk).reshape(b, s, num_heads, n)
    v = linear(p["wv"], xv).reshape(b, s, num_heads, n)
    g = jax.nn.silu(linear(p["wg"], xg))
    w = _decay(p, xw).reshape(b, s, num_heads, n)             # fp32
    u = p["u"]                                                # (H, N)

    def step(state, inp):
        rt, kt, vt, wt = inp                                  # (B,H,N) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                        vt.astype(jnp.float32))
        o = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                       state + u[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, o

    s0 = jnp.zeros((b, num_heads, n, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    _, os = jax.lax.scan(step, s0, xs)
    o = jnp.moveaxis(os, 0, 1).reshape(b, s, d).astype(x.dtype)
    o = _group_norm(p["ln_scale"], o, num_heads)
    return linear(p["wo"], o * g)


def init_rwkv_cmix(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": truncated_normal(ks[0], (d_model,), 0.02, jnp.float32),
        "wk": init_linear(ks[1], d_model, d_ff, dtype),
        "wv": init_linear(ks[2], d_ff, d_model, dtype),
    }


def rwkv_cmix_train(p: Params, x: jax.Array) -> jax.Array:
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)[None, None, :]
    h = jax.nn.relu(linear(p["wk"], xk))
    return linear(p["wv"], h * h)


# ---------------------------------------------------------------------------
# decode (O(1) recurrent state)
# ---------------------------------------------------------------------------

def init_rwkv_state(batch: int, d_model: int, num_heads: int):
    n = d_model // num_heads
    return {
        "tshift": jnp.zeros((batch, d_model), jnp.bfloat16),
        "cshift": jnp.zeros((batch, d_model), jnp.bfloat16),
        "wkv": jnp.zeros((batch, num_heads, n, n), jnp.float32),
    }


def rwkv_tmix_decode(p: Params, x: jax.Array, state: dict, *, num_heads: int):
    """x: (B, 1, D). Returns (y, state)."""
    b, _, d = x.shape
    n = d // num_heads
    x_prev = state["tshift"].astype(x.dtype)[:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = linear(p["wr"], xr).reshape(b, num_heads, n)
    k = linear(p["wk"], xk).reshape(b, num_heads, n)
    v = linear(p["wv"], xv).reshape(b, num_heads, n)
    g = jax.nn.silu(linear(p["wg"], xg))
    w = _decay(p, xw).reshape(b, num_heads, n)
    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    o = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state["wkv"] + p["u"][None, :, :, None] * kv)
    new_wkv = w[..., None] * state["wkv"] + kv
    o = o.reshape(b, 1, d).astype(x.dtype)
    o = _group_norm(p["ln_scale"], o, num_heads)
    y = linear(p["wo"], o * g)
    return y, {**state, "tshift": x[:, 0].astype(jnp.bfloat16), "wkv": new_wkv}


def rwkv_cmix_decode(p: Params, x: jax.Array, state: dict):
    x_prev = state["cshift"].astype(x.dtype)[:, None, :]
    xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)[None, None, :]
    h = jax.nn.relu(linear(p["wk"], xk))
    y = linear(p["wv"], h * h)
    return y, {**state, "cshift": x[:, 0].astype(jnp.bfloat16)}
