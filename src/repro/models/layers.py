"""Core NN layers — functional JAX, params as nested dicts.

Conventions:
- params are created by ``init_*`` functions from a PRNG key, stored in the
  configured param dtype (bf16 by default);
- compute runs in bf16 with fp32 reductions where it matters (norms,
  softmax, loss);
- layers are plain functions so they vmap/scan/shard transparently.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


def truncated_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
                std: float | None = None) -> Params:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied read-out: logits = x @ table^T (fp32 accumulation)."""
    return jnp.einsum("...d,vd->...v", x, p["table"],
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype, *, elementwise: bool = True) -> Params:
    if not elementwise:
        return {}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm; with empty params it is OLMo's non-parametric LN."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


NORM_INITS = {
    "rmsnorm": lambda d, dt: init_rmsnorm(d, dt),
    "layernorm": lambda d, dt: init_layernorm(d, dt),
    "nonparametric_ln": lambda d, dt: {},
}


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(p, x)
    return layernorm(p, x)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu",
             *, bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"gate": init_linear(ks[0], d_model, d_ff, dtype),
                "up": init_linear(ks[1], d_model, d_ff, dtype),
                "down": init_linear(ks[2], d_ff, d_model, dtype)}
    if kind == "gelu":
        return {"up": init_linear(ks[0], d_model, d_ff, dtype, bias=bias),
                "down": init_linear(ks[1], d_ff, d_model, dtype, bias=bias)}
    if kind == "relu2":   # RWKV-style squared relu
        return {"up": init_linear(ks[0], d_model, d_ff, dtype),
                "down": init_linear(ks[1], d_ff, d_model, dtype)}
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp(p: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
    if kind == "gelu":
        return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))
    if kind == "relu2":
        h = jax.nn.relu(linear(p["up"], x))
        return linear(p["down"], h * h)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / (10000 ** (dim / d_model))
    out = np.zeros((length, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits fp32 (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
