"""Decoder-only LM assembly — dense / moe / hybrid / ssm families.

Layers are *scanned*: parameters are stacked with a leading block dim and the
forward pass is one ``lax.scan`` over blocks, so the traced HLO contains each
block body exactly once.  At 88-layer/123B scale this is what keeps AOT
compilation of the dry-run tractable (and is the production-standard layout
for checkpointing + pipelining).

Block structure per family:
  dense / moe / vlm : [norm -> GQA|MLA -> norm -> MLP|MoE] x L
  hybrid (jamba)    : blocks of ``attn_every`` sub-layers, one attention at
                      the block midpoint, Mamba elsewhere, MoE after each
                      mixer (1:7 attn:mamba at attn_every=8)
  ssm (rwkv6)       : [ln -> time-mix -> ln -> channel-mix] x L

Decode threads per-layer caches through the same scan (caches are scan
xs/ys), so one-token serve_steps stay O(layers) in HLO too.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import shardutil
from repro.models.layers import (
    DTYPES,
    NORM_INITS,
    Params,
    apply_norm,
    embed,
    init_embedding,
    init_linear,
    init_mlp,
    linear,
    mlp,
    softmax_cross_entropy,
    unembed,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mixer(key, cfg: ModelConfig, dtype) -> Params:
    if cfg.mla is not None:
        m = cfg.mla
        return attn.init_mla(key, cfg.d_model, cfg.num_heads,
                             kv_lora_rank=m.kv_lora_rank, q_lora_rank=m.q_lora_rank,
                             nope_dim=m.nope_dim, rope_dim=m.rope_dim,
                             v_head_dim=m.v_head_dim, dtype=dtype)
    return attn.init_gqa(key, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                         cfg.resolved_head_dim, dtype)


def _init_ffn(key, cfg: ModelConfig, dtype) -> Params:
    if cfg.moe is not None:
        e = cfg.moe
        return moe_mod.init_moe(key, cfg.d_model, e.d_ff_expert, e.num_experts,
                                e.num_shared, dtype)
    return init_mlp(key, cfg.d_model, cfg.d_ff, dtype, kind=cfg.mlp_kind)


def _norm_init(cfg: ModelConfig, dtype):
    return NORM_INITS[cfg.norm_type](cfg.d_model, dtype)


def _init_block(key, cfg: ModelConfig, dtype) -> Params:
    if cfg.family == "ssm":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": NORM_INITS["layernorm"](cfg.d_model, dtype),
            "tmix": rwkv_mod.init_rwkv_tmix(k1, cfg.d_model, cfg.rwkv_heads,
                                            dtype=dtype),
            "ln2": NORM_INITS["layernorm"](cfg.d_model, dtype),
            "cmix": rwkv_mod.init_rwkv_cmix(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    if cfg.family == "hybrid":
        e = cfg.attn_every
        n_moe = e // cfg.moe_every
        ks = jax.random.split(key, 5)
        mk = jax.random.split(ks[0], e - 1)
        s = cfg.ssm
        p = {
            "mamba": jax.vmap(lambda k: mamba_mod.init_mamba(
                k, cfg.d_model, d_state=s.d_state, d_conv=s.d_conv,
                expand=s.expand, dtype=dtype))(mk),
            "attn": _init_mixer(ks[2], cfg, dtype),
            "norm1": jnp.ones((e, cfg.d_model), dtype),
            "norm2": jnp.ones((e, cfg.d_model), dtype),
        }
        if n_moe:
            fk = jax.random.split(ks[1], n_moe)
            p["ffn_moe"] = jax.vmap(lambda k: _init_ffn(k, cfg, dtype))(fk)
        if e - n_moe:
            dk = jax.random.split(ks[3], e - n_moe)
            p["ffn_dense"] = jax.vmap(
                lambda k: init_mlp(k, cfg.d_model, cfg.d_ff, dtype,
                                   kind=cfg.mlp_kind))(dk)
        return p
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": _norm_init(cfg, dtype),
        "attn": _init_mixer(k1, cfg, dtype),
        "ffn_norm": _norm_init(cfg, dtype),
        "ffn": _init_ffn(k2, cfg, dtype),
    }


def num_blocks(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        assert cfg.num_layers % cfg.attn_every == 0
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def init_lm(cfg: ModelConfig, key) -> Params:
    dtype = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    bk = jax.random.split(ks[0], num_blocks(cfg))
    params = {
        "embed": init_embedding(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(bk),
        "final_norm": _norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend is not None:
        # stub frontends provide embeddings directly; a learned projection
        # adapts them into the LM residual stream
        params["frontend_proj"] = init_linear(ks[3], cfg.d_model, cfg.d_model,
                                              dtype)
    return params


# ---------------------------------------------------------------------------
# forward blocks (training / prefill, full sequence)
# ---------------------------------------------------------------------------

def _apply_ffn(cfg: ModelConfig, p: Params, h: jax.Array):
    if cfg.moe is not None:
        return moe_mod.moe_ffn(p, h, num_experts=cfg.moe.num_experts,
                               top_k=cfg.moe.top_k,
                               capacity_factor=cfg.moe.capacity_factor)
    return mlp(p, h, kind=cfg.mlp_kind), jnp.zeros((), jnp.float32)


def _apply_mixer_train(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    if cfg.mla is not None:
        m = cfg.mla
        return attn.mla_train(p, h, num_heads=cfg.num_heads,
                              kv_lora_rank=m.kv_lora_rank, nope_dim=m.nope_dim,
                              rope_dim=m.rope_dim, v_head_dim=m.v_head_dim,
                              rope_theta=cfg.rope_theta)
    return attn.gqa_train(p, h, num_heads=cfg.num_heads,
                          num_kv_heads=cfg.num_kv_heads,
                          head_dim=cfg.resolved_head_dim,
                          rope_theta=cfg.rope_theta,
                          tp_pad_heads=cfg.attn_tp_pad)


def _block_train(cfg: ModelConfig, p: Params, h: jax.Array):
    """One scanned block; returns (h, aux)."""
    if cfg.family == "ssm":
        hn = apply_norm("layernorm", p["ln1"], h)
        h = h + rwkv_mod.rwkv_tmix_train(p["tmix"], hn, num_heads=cfg.rwkv_heads)
        hn = apply_norm("layernorm", p["ln2"], h)
        h = h + rwkv_mod.rwkv_cmix_train(p["cmix"], hn)
        return h, jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        e = cfg.attn_every
        attn_pos = e // 2
        aux = jnp.zeros((), jnp.float32)
        mi = di = oi = 0
        for i in range(e):
            hn = _rms(p["norm1"][i], h)
            if i == attn_pos:
                h = h + _apply_mixer_train(cfg, p["attn"], hn)
            else:
                mp = jax.tree.map(lambda x: x[mi], p["mamba"])
                h = h + mamba_mod.mamba_train(mp, hn, d_state=cfg.ssm.d_state)
                mi += 1
            hn = _rms(p["norm2"][i], h)
            if i % cfg.moe_every == cfg.moe_every - 1:
                fp = jax.tree.map(lambda x: x[oi], p["ffn_moe"])
                y, a = _apply_ffn(cfg, fp, hn)
                aux = aux + a
                oi += 1
            else:
                fp = jax.tree.map(lambda x: x[di], p["ffn_dense"])
                y = mlp(fp, hn, kind=cfg.mlp_kind)
                di += 1
            h = h + y
        return h, aux
    # dense / moe / vlm / audio-decoder
    hn = apply_norm(cfg.norm_type, p["attn_norm"], h)
    h = h + _apply_mixer_train(cfg, p["attn"], hn)
    hn = apply_norm(cfg.norm_type, p["ffn_norm"], h)
    y, aux = _apply_ffn(cfg, p["ffn"], hn)
    return h + y, aux


def _rms(scale: jax.Array, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def lm_hidden(cfg: ModelConfig, params: Params, h: jax.Array):
    """Run the scanned block stack over hidden states (B, S, D).

    cfg.remat selects the activation-checkpoint policy applied to each
    scanned block: 'block' saves only block boundaries (recompute inside the
    block on the backward pass), 'dots' additionally saves matmul outputs
    (checkpoint_dots) — the standard memory/compute trade for large models.
    """
    def block(p, h):
        h, a = _block_train(cfg, p, h)
        h = shardutil.constrain_batch(
            h, "model" if cfg.seq_shard_activations else None)
        return h, a

    if cfg.remat == "block":
        block = jax.checkpoint(block)
    elif cfg.remat == "dots":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.checkpoint_dots)

    def body(carry, block_p):
        h, aux = carry
        h, a = block(block_p, h)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return apply_norm(cfg.norm_type, params["final_norm"], h), aux


def lm_logits(cfg: ModelConfig, params: Params, tokens: jax.Array,
              prefix_embeds: jax.Array | None = None):
    """tokens: (B, S). Optional prefix_embeds (B, P, D) (vlm patches).
    Returns (logits fp32 (B, S_total, V), aux)."""
    h = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        pe = linear(params["frontend_proj"], prefix_embeds.astype(h.dtype))
        h = jnp.concatenate([pe, h], axis=1)
    h = shardutil.constrain_batch(h)
    h, aux = lm_hidden(cfg, params, h)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = linear(params["lm_head"], h).astype(jnp.float32)
    return logits, aux


def lm_loss(cfg: ModelConfig, params: Params, batch: dict):
    """batch: tokens (B, S), labels (B, S), optional prefix_embeds/loss_mask."""
    logits, aux = lm_logits(cfg, params, batch["tokens"],
                            batch.get("prefix_embeds"))
    if batch.get("prefix_embeds") is not None:
        logits = logits[:, batch["prefix_embeds"].shape[1]:]
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def lm_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
               max_seq: int, prefix_embeds: jax.Array | None = None):
    """Full-sequence prefill: last-position logits + populated KV cache.

    Supported for the kv-cache families (dense/moe/vlm incl. MLA); hybrid and
    ssm families prefill via their decode recurrence (examples use a token
    scan).  Only the last position is unembedded — at 32k prefill the full
    (B, S, V) logits tensor would dwarf every other buffer.
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError("state-recurrent families prefill via decode")
    h = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        pe = linear(params["frontend_proj"], prefix_embeds.astype(h.dtype))
        h = jnp.concatenate([pe, h], axis=1)
    h = shardutil.constrain_batch(h)
    b, s, _ = h.shape

    def body(carry, block_p):
        h, aux = carry
        hn = apply_norm(cfg.norm_type, block_p["attn_norm"], h)
        if cfg.mla is not None:
            m = cfg.mla
            y, kv = attn.mla_train(
                block_p["attn"], hn, num_heads=cfg.num_heads,
                kv_lora_rank=m.kv_lora_rank, nope_dim=m.nope_dim,
                rope_dim=m.rope_dim, v_head_dim=m.v_head_dim,
                rope_theta=cfg.rope_theta, return_kv=True)
        else:
            y, kv = attn.gqa_train(
                block_p["attn"], hn, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta, return_kv=True)
        h = h + y
        hn = apply_norm(cfg.norm_type, block_p["ffn_norm"], h)
        y, a = _apply_ffn(cfg, block_p["ffn"], hn)
        return (h + y, aux + a), kv

    (h, aux), kvs = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                 params["blocks"])
    h = apply_norm(cfg.norm_type, params["final_norm"], h[:, -1:])
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = linear(params["lm_head"], h).astype(jnp.float32)
    # place prefill K/V into a max_seq cache
    cache = init_lm_cache(cfg, b, max_seq)
    if cfg.mla is not None:
        ckv, kr = kvs
        cache["ckv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(jnp.bfloat16), 0, axis=2)
        cache["kr"] = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr.astype(jnp.bfloat16), 0, axis=2)
    else:
        k, v = kvs
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(jnp.bfloat16), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(jnp.bfloat16), 0, axis=2)
    return logits, cache, aux


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    nb = num_blocks(cfg)
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        st = rwkv_mod.init_rwkv_state(batch, cfg.d_model, cfg.rwkv_heads)
        return {"rwkv": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nb,) + x.shape), st)}
    if cfg.family == "hybrid":
        e = cfg.attn_every
        s = cfg.ssm
        ms = mamba_mod.init_mamba_state(batch, cfg.d_model, d_state=s.d_state,
                                        d_conv=s.d_conv, expand=s.expand)
        return {
            "k": jnp.zeros((nb, batch, max_seq, cfg.num_kv_heads, hd),
                           jnp.bfloat16),
            "v": jnp.zeros((nb, batch, max_seq, cfg.num_kv_heads, hd),
                           jnp.bfloat16),
            "mamba": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (nb, e - 1) + x.shape), ms),
        }
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((nb, batch, max_seq, m.kv_lora_rank), jnp.bfloat16),
            "kr": jnp.zeros((nb, batch, max_seq, m.rope_dim), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((nb, batch, max_seq, cfg.num_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((nb, batch, max_seq, cfg.num_kv_heads, hd), jnp.bfloat16),
    }


def _block_decode(cfg: ModelConfig, p: Params, h: jax.Array, cache_blk: dict,
                  pos: jax.Array):
    """One-token step through one block.

    Returns (h, token_entries): per-layer caches are READ-ONLY here; only
    the current token's K/V (or compressed latent / recurrent state) is
    emitted.  The caller commits all layers' entries with one
    dynamic_update_slice — threading mutated caches through the scan makes
    XLA rewrite the full cache every token (§Perf cell 3).
    """
    if cfg.family == "ssm":
        st = cache_blk["rwkv"]
        hn = apply_norm("layernorm", p["ln1"], h)
        y, st = rwkv_mod.rwkv_tmix_decode(p["tmix"], hn, st,
                                          num_heads=cfg.rwkv_heads)
        h = h + y
        hn = apply_norm("layernorm", p["ln2"], h)
        y, st = rwkv_mod.rwkv_cmix_decode(p["cmix"], hn, st)
        return h + y, {"rwkv": st}
    if cfg.family == "hybrid":
        e = cfg.attn_every
        attn_pos = e // 2
        mstates = cache_blk["mamba"]
        new_m = []
        entries = {}
        mi = di = oi = 0
        for i in range(e):
            hn = _rms(p["norm1"][i], h)
            if i == attn_pos:
                y, k_new, v_new = attn.gqa_decode_ro(
                    p["attn"], hn, cache_blk["k"], cache_blk["v"], pos,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
                entries["k"] = k_new
                entries["v"] = v_new
            else:
                mp = jax.tree.map(lambda x: x[mi], p["mamba"])
                ms = jax.tree.map(lambda x: x[mi], mstates)
                y, ms = mamba_mod.mamba_decode(mp, hn, ms,
                                               d_state=cfg.ssm.d_state)
                new_m.append(ms)
                mi += 1
            h = h + y
            hn = _rms(p["norm2"][i], h)
            if i % cfg.moe_every == cfg.moe_every - 1:
                fp = jax.tree.map(lambda x: x[oi], p["ffn_moe"])
                y, _ = _apply_ffn(cfg, fp, hn)
                oi += 1
            else:
                fp = jax.tree.map(lambda x: x[di], p["ffn_dense"])
                y = mlp(fp, hn, kind=cfg.mlp_kind)
                di += 1
            h = h + y
        entries["mamba"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
        return h, entries
    hn = apply_norm(cfg.norm_type, p["attn_norm"], h)
    if cfg.mla is not None:
        m = cfg.mla
        y, ckv_new, kr_new = attn.mla_decode_ro(
            p["attn"], hn, cache_blk["ckv"], cache_blk["kr"], pos,
            num_heads=cfg.num_heads, kv_lora_rank=m.kv_lora_rank,
            nope_dim=m.nope_dim, rope_dim=m.rope_dim, v_head_dim=m.v_head_dim,
            rope_theta=cfg.rope_theta)
        entries = {"ckv": ckv_new, "kr": kr_new}
    else:
        y, k_new, v_new = attn.gqa_decode_ro(
            p["attn"], hn, cache_blk["k"], cache_blk["v"], pos,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
        entries = {"k": k_new, "v": v_new}
    h = h + y
    hn = apply_norm(cfg.norm_type, p["ffn_norm"], h)
    y, _ = _apply_ffn(cfg, p["ffn"], hn)
    return h + y, entries


# cache fields that hold (L, B, S, ...) sequence buffers, committed with one
# dus at ``pos``; everything else (recurrent states) is replaced wholesale
_SEQ_CACHE_FIELDS = ("k", "v", "ckv", "kr")


def _commit_cache(cache: dict, entries: dict, pos: jax.Array) -> dict:
    new_cache = {}
    for field, val in entries.items():
        if field in _SEQ_CACHE_FIELDS:
            # scatter, NOT dynamic_update_slice: a traced-start DUS on the
            # sequence-sharded dim makes GSPMD reshard/gather the whole
            # cache (collectives >> the 16 KB payload); a scatter is masked
            # per-shard — only the owner of ``pos`` writes (§Perf cell 3)
            upd = val.astype(cache[field].dtype)               # (L, B, ...)
            new_cache[field] = cache[field].at[:, :, pos].set(upd)
        else:
            new_cache[field] = val
    return new_cache


def lm_decode_step(cfg: ModelConfig, params: Params, cache: dict,
                   tokens: jax.Array, pos: jax.Array):
    """tokens: (B, 1); pos: scalar. Returns (logits (B, 1, V) fp32, cache)."""
    h = shardutil.constrain_batch(embed(params["embed"], tokens))

    def body(h, xs):
        block_p, cache_blk = xs
        h, entries = _block_decode(cfg, block_p, h, cache_blk, pos)
        return h, entries

    h, entries = jax.lax.scan(body, h, (params["blocks"], cache))
    new_cache = _commit_cache(cache, entries, pos)
    h = apply_norm(cfg.norm_type, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = linear(params["lm_head"], h).astype(jnp.float32)
    return logits, new_cache
