"""Attention: GQA with RoPE + chunked (flash-style) computation, and MLA.

``chunked_causal_attention`` is a pure-JAX flash attention: queries and keys
are processed in blocks under lax.scan with a running (max, denom, acc)
triple, so the (S, S) score matrix is never materialized.  At the assigned
shapes (up to 32k prefill at batch 32) materialized scores would need TBs of
HBM — blockwise attention is a requirement, not an optimization.  XLA maps
each block product onto the MXU; block sizes are multiples of 128.

MLA (DeepSeek-V2) keeps a rank-512 compressed KV cache; decode uses the
*absorbed* form (q projected through W_uk so attention runs directly against
the compressed cache) — the trick that makes the 236B model's 32k decode
cache fit comfortably (see DESIGN.md §4).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, init_linear, linear

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": init_linear(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": init_linear(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": init_linear(ks[3], num_heads * head_dim, d_model, dtype),
    }


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             *, causal: bool = True, q_offset: int = 0,
                             q_chunk: int = 512, k_chunk: int = 1024,
                             kv_valid: int | None = None,
                             ) -> jax.Array:
    """Flash-style attention.

    q: (B, Sq, Hkv, rep, hd); k, v: (B, Sk, Hkv, hd).  Returns (B, Sq, Hkv,
    rep, hd).  ``q_offset`` is the absolute position of q[0] (cache decode /
    prefill continuation).  ``kv_valid`` masks out key positions >= it
    (padded cross-attention keys).
    """
    b, sq, hkv, rep, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # auto-pad ragged sequence lengths (e.g. whisper's 1500 frames) to chunk
    # multiples; padded keys are masked via kv_valid, padded queries sliced off
    q_pad = (-sq) % q_chunk
    k_pad = (-sk) % k_chunk
    orig_sq = sq
    if k_pad:
        kv_valid = min(kv_valid, sk) if kv_valid is not None else sk
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        sk += k_pad
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
        sq += q_pad
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(b, nq, q_chunk, hkv, rep, hd)
    kb = k.reshape(b, nk, k_chunk, hkv, hd)
    vb = v.reshape(b, nk, k_chunk, hkv, hd)

    q_pos = (q_offset + jnp.arange(sq)).reshape(nq, q_chunk)
    k_pos = jnp.arange(sk).reshape(nk, k_chunk)

    def per_q_block(iq, qblk):
        # qblk: (B, Tq, Hkv, rep, hd)
        qpos = q_pos[iq]                                     # (Tq,)

        @jax.checkpoint
        def per_k_block(carry, ik):
            # rematerialized: without this, the backward pass saves the
            # (Tq, Tk) f32 score/prob tiles of EVERY (q, k) chunk pair —
            # the full S^2 score matrix in disguise.  Recomputing tiles from
            # q/k/v is the flash-attention backward (§Perf cell 1, iter 2).
            m, l, acc = carry
            kblk = kb[:, ik]                                 # (B, Tk, Hkv, hd)
            vblk = vb[:, ik]
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            kpos = k_pos[ik]
            if causal:
                mask = qpos[:, None] >= kpos[None, :]        # (Tq, Tk)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            if kv_valid is not None:
                s = jnp.where((kpos < kv_valid)[None, None, None, None, :],
                              s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))      # (B,Hkv,rep,Tq)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhrqk,bkhd->bhrqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(per_k_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)                  # (B,Tq,Hkv,rep,hd)

    outs = jax.lax.map(lambda i: per_q_block(i, qb[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, rep, hd)
    if q_pad:
        out = out[:, :orig_sq]
    return out.astype(q.dtype)


def gqa_train(p: Params, x: jax.Array, *, num_heads: int, num_kv_heads: int,
              head_dim: int, rope_theta: float, causal: bool = True,
              use_rope: bool = True, q_chunk: int = 512,
              k_chunk: int = 1024, return_kv: bool = False,
              tp_pad_heads: int = 0):
    """Full-sequence attention (training / prefill). x: (B, S, D).

    ``tp_pad_heads`` (a TP width, e.g. 16): expand GQA K/V to full MHA and
    zero-pad the head dim to a multiple of the TP width, then pin the head
    dim to the 'model' axis.  Without this, head counts that don't divide
    the TP width leave the whole attention block REPLICATED across the
    model axis (GSPMD has nothing to shard) — a 16x compute+memory tax
    observed directly in the smollm dry-run (EXPERIMENTS.md §Perf).  The
    padded heads read zero K/V and their outputs are sliced off before wo.
    """
    from repro.models import shardutil
    b, s, _ = x.shape
    rep = num_heads // num_kv_heads
    q = linear(p["wq"], x).reshape(b, s, num_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, s, num_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, s, num_kv_heads, head_dim)
    if use_rope:
        pos = jnp.arange(s)[None, :]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if tp_pad_heads and num_heads % tp_pad_heads != 0:
        pad = (-num_heads) % tp_pad_heads
        hp = num_heads + pad
        k = jnp.repeat(k, rep, axis=2)                 # GQA -> MHA
        v = jnp.repeat(v, rep, axis=2)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dp = ("pod", "data")
        q = shardutil.constrain(q, dp, None, "model", None)
        k = shardutil.constrain(k, dp, None, "model", None)
        v = shardutil.constrain(v, dp, None, "model", None)
        o = chunked_causal_attention(q.reshape(b, s, hp, 1, head_dim), k, v,
                                     causal=causal, q_chunk=q_chunk,
                                     k_chunk=k_chunk)
        o = o.reshape(b, s, hp, head_dim)[:, :, :num_heads]
        o = o.reshape(b, s, num_heads * head_dim)
    else:
        qg = q.reshape(b, s, num_kv_heads, rep, head_dim)
        o = chunked_causal_attention(qg, k, v, causal=causal,
                                     q_chunk=q_chunk, k_chunk=k_chunk)
        o = o.reshape(b, s, num_heads * head_dim)
    y = linear(p["wo"], o)
    if return_kv:
        if tp_pad_heads and num_heads % tp_pad_heads != 0:
            # undo MHA expansion: kv head i lives at expanded index i*rep
            return y, (k[:, :, :num_kv_heads * rep:rep],
                       v[:, :, :num_kv_heads * rep:rep])
        return y, (k, v)
    return y


def gqa_decode_ro(p: Params, x: jax.Array, cache_k: jax.Array,
                  cache_v: jax.Array, pos: jax.Array, *, num_heads: int,
                  num_kv_heads: int, head_dim: int, rope_theta: float,
                  use_rope: bool = True):
    """Read-only-cache decode: attends over cache[<pos] + the current token,
    returning (y, k_new, v_new) WITHOUT writing the cache.

    Why: threading a mutated cache slice through the layer scan makes XLA
    rewrite the whole (L, B, S, H, hd) cache every token (67 MB/layer for a
    16 KB update — §Perf cell 3).  Callers stack the per-layer k_new/v_new
    and commit them with ONE dynamic_update_slice at ``pos`` after the scan.
    """
    b = x.shape[0]
    rep = num_heads // num_kv_heads
    smax = cache_k.shape[1]
    q = linear(p["wq"], x).reshape(b, 1, num_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, 1, num_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, 1, num_kv_heads, head_dim)
    if use_rope:
        posb = jnp.full((b, 1), pos)
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    qh = q.reshape(b, num_kv_heads, rep, head_dim)
    scale = 1.0 / math.sqrt(head_dim)
    s_cache = jnp.einsum("bhrd,bshd->bhrs", qh, cache_k,
                         preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(smax)[None, None, None, :] < pos
    s_cache = jnp.where(valid, s_cache, NEG_INF)
    s_new = jnp.einsum("bhrd,bhd->bhr", qh, k[:, 0],
                       preferred_element_type=jnp.float32) * scale
    # two-term flash combine — concatenating [S] and [1] scores would break
    # the sequence sharding of the cache scores (S+1 indivisible by the
    # mesh), forcing GSPMD to all-gather the f32-converted V cache
    # (observed: 70% of decode collective bytes, §Perf cell 3)
    m = jnp.maximum(jnp.max(s_cache, axis=-1), s_new)           # (B,h,r)
    p_cache = jnp.exp(s_cache - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = jnp.sum(p_cache, axis=-1) + p_new
    o = (jnp.einsum("bhrs,bshd->bhrd", p_cache.astype(cache_v.dtype),
                    cache_v, preferred_element_type=jnp.float32)
         + p_new[..., None] * v[:, 0, :, None, :].astype(jnp.float32))
    o = o / denom[..., None]
    o = o.astype(x.dtype).reshape(b, 1, num_heads * head_dim)
    return linear(p["wo"], o), k[:, 0], v[:, 0]


def gqa_decode(p: Params, x: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
               pos: jax.Array, *, num_heads: int, num_kv_heads: int,
               head_dim: int, rope_theta: float, use_rope: bool = True):
    """Single-token decode. x: (B, 1, D); cache_[kv]: (B, Smax, Hkv, hd);
    pos: scalar current position.  Returns (y, cache_k, cache_v)."""
    b = x.shape[0]
    rep = num_heads // num_kv_heads
    smax = cache_k.shape[1]
    q = linear(p["wq"], x).reshape(b, 1, num_heads, head_dim)
    k = linear(p["wk"], x).reshape(b, 1, num_kv_heads, head_dim)
    v = linear(p["wv"], x).reshape(b, 1, num_kv_heads, head_dim)
    if use_rope:
        posb = jnp.full((b, 1), pos)
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                  pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                  pos, axis=1)
    qh = q.reshape(b, num_kv_heads, rep, head_dim)
    s = jnp.einsum("bhrd,bshd->bhrs", qh, cache_k,
                   preferred_element_type=jnp.float32) / math.sqrt(head_dim)
    valid = jnp.arange(smax)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrs,bshd->bhrd", w.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o.astype(x.dtype).reshape(b, 1, num_heads * head_dim)
    return linear(p["wo"], o), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------

def init_mla(key, d_model: int, num_heads: int, *, kv_lora_rank: int,
             q_lora_rank: int, nope_dim: int, rope_dim: int, v_head_dim: int,
             dtype) -> Params:
    ks = jax.random.split(key, 7)
    qh = nope_dim + rope_dim
    return {
        "wdq": init_linear(ks[0], d_model, q_lora_rank, dtype),
        "wuq": init_linear(ks[1], q_lora_rank, num_heads * qh, dtype),
        "wdkv": init_linear(ks[2], d_model, kv_lora_rank, dtype),
        "wkr": init_linear(ks[3], d_model, rope_dim, dtype),
        "wuk": init_linear(ks[4], kv_lora_rank, num_heads * nope_dim, dtype),
        "wuv": init_linear(ks[5], kv_lora_rank, num_heads * v_head_dim, dtype),
        "wo": init_linear(ks[6], num_heads * v_head_dim, d_model, dtype),
    }


def mla_train(p: Params, x: jax.Array, *, num_heads: int, kv_lora_rank: int,
              nope_dim: int, rope_dim: int, v_head_dim: int, rope_theta: float,
              q_chunk: int = 512, k_chunk: int = 1024,
              return_kv: bool = False):
    """Training-time MLA: decompress K/V and run standard chunked attention.

    Sharding: the decompressed K/V/Q are pinned head-sharded over 'model'
    (128 heads / 16 = 8 per chip).  Left to propagation, GSPMD inherits the
    sequence sharding of the residual stream instead, which (a) replicates
    all 128 heads' score computation on every chip and (b) all-gathers
    f32 K chunks inside the flash loop — both observed on the deepseek
    train cell (§Perf cell 2).  Gathering the COMPRESSED c_kv (rank 512)
    once and expanding per head-shard is the cheap order of operations —
    MLA's compression works for training comms too, not just decode caches.
    """
    from repro.models import shardutil
    dp = ("pod", "data")
    b, s, _ = x.shape
    qh = nope_dim + rope_dim
    q = linear(p["wuq"], linear(p["wdq"], x)).reshape(b, s, num_heads, qh)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    c_kv = linear(p["wdkv"], x)                               # (B, S, rank)
    c_kv = shardutil.constrain(c_kv, dp, None, None)          # full-seq, tiny
    k_rope = linear(p["wkr"], x).reshape(b, s, 1, rope_dim)   # shared head
    pos = jnp.arange(s)[None, :]
    q_rope = apply_rope(q_rope, pos, rope_theta)
    k_rope = apply_rope(k_rope, pos, rope_theta)
    k_nope = linear(p["wuk"], c_kv).reshape(b, s, num_heads, nope_dim)
    v = linear(p["wuv"], c_kv).reshape(b, s, num_heads, v_head_dim)
    # pack rope part into the head dim so one chunked attention call suffices
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, num_heads, rope_dim))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = shardutil.constrain(k_full, dp, None, "model", None)
    vp = shardutil.constrain(v_pad(v, qh), dp, None, "model", None)
    # scale uses the true per-head dim (nope+rope)
    qf = q_full.reshape(b, s, num_heads, 1, qh)
    qf = shardutil.constrain(qf, dp, None, "model", None, None)
    o = chunked_causal_attention(qf, k_full, vp, causal=True,
                                 q_chunk=q_chunk, k_chunk=k_chunk)
    o = o.reshape(b, s, num_heads, qh)[..., :v_head_dim]
    y = linear(p["wo"], o.reshape(b, s, num_heads * v_head_dim))
    if return_kv:
        return y, (c_kv, k_rope[:, :, 0, :])   # compressed cache entries
    return y


def v_pad(v: jax.Array, to_dim: int) -> jax.Array:
    """Zero-pad value head dim so q/k/v share a head dim for the chunked core."""
    pad = to_dim - v.shape[-1]
    if pad == 0:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, pad),))


def mla_decode_ro(p: Params, x: jax.Array, cache_ckv: jax.Array,
                  cache_kr: jax.Array, pos: jax.Array, *, num_heads: int,
                  kv_lora_rank: int, nope_dim: int, rope_dim: int,
                  v_head_dim: int, rope_theta: float):
    """Read-only-cache absorbed MLA decode -> (y, ckv_new, kr_new)
    (see gqa_decode_ro for the cache-rewrite rationale)."""
    b = x.shape[0]
    smax = cache_ckv.shape[1]
    qh = nope_dim + rope_dim
    q = linear(p["wuq"], linear(p["wdq"], x)).reshape(b, num_heads, qh)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    posb = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope[:, None], posb, rope_theta)[:, 0]
    c_kv = linear(p["wdkv"], x)[:, 0]                          # (B, rank)
    k_rope = linear(p["wkr"], x).reshape(b, 1, 1, rope_dim)
    k_rope = apply_rope(k_rope, posb, rope_theta)[:, 0, 0]     # (B, rope)
    wuk = p["wuk"]["w"].reshape(kv_lora_rank, num_heads, nope_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, wuk)            # (B, H, rank)
    scale = 1.0 / math.sqrt(qh)
    s_cache = (jnp.einsum("bhr,bsr->bhs", q_abs, cache_ckv,
                          preferred_element_type=jnp.float32)
               + jnp.einsum("bhe,bse->bhs", q_rope, cache_kr,
                            preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(smax)[None, None, :] < pos
    s_cache = jnp.where(valid, s_cache, NEG_INF)
    s_new = (jnp.einsum("bhr,br->bh", q_abs, c_kv.astype(q_abs.dtype),
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhe,be->bh", q_rope, k_rope,
                          preferred_element_type=jnp.float32)) * scale
    # two-term flash combine (no concat — see gqa_decode_ro)
    m = jnp.maximum(jnp.max(s_cache, axis=-1), s_new)           # (B, H)
    p_cache = jnp.exp(s_cache - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = jnp.sum(p_cache, axis=-1) + p_new
    ctx = (jnp.einsum("bhs,bsr->bhr", p_cache.astype(cache_ckv.dtype),
                      cache_ckv, preferred_element_type=jnp.float32)
           + p_new[..., None] * c_kv[:, None, :].astype(jnp.float32))
    ctx = ctx / denom[..., None]
    wuv = p["wuv"]["w"].reshape(kv_lora_rank, num_heads, v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), wuv)
    y = linear(p["wo"], o.reshape(b, 1, num_heads * v_head_dim))
    return y, c_kv, k_rope


def mla_decode(p: Params, x: jax.Array, cache_ckv: jax.Array,
               cache_kr: jax.Array, pos: jax.Array, *, num_heads: int,
               kv_lora_rank: int, nope_dim: int, rope_dim: int,
               v_head_dim: int, rope_theta: float):
    """Absorbed-form MLA decode: attention runs against the compressed cache.

    cache_ckv: (B, Smax, rank); cache_kr: (B, Smax, rope_dim).
    score_h = (q_nope_h W_uk_h) · c_kv + q_rope_h · k_rope   — W_uk absorbed
    out_h   = (attn · c_kv) W_uv_h                           — W_uv absorbed
    """
    b = x.shape[0]
    smax = cache_ckv.shape[1]
    qh = nope_dim + rope_dim
    q = linear(p["wuq"], linear(p["wdq"], x)).reshape(b, num_heads, qh)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    posb = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope[:, None], posb, rope_theta)[:, 0]   # (B, H, rope)
    c_kv = linear(p["wdkv"], x)[:, 0]                          # (B, rank)
    k_rope = linear(p["wkr"], x).reshape(b, 1, 1, rope_dim)
    k_rope = apply_rope(k_rope, posb, rope_theta)[:, 0, 0]     # (B, rope)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv[:, None].astype(cache_ckv.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope[:, None].astype(cache_kr.dtype), pos, axis=1)
    wuk = p["wuk"]["w"].reshape(kv_lora_rank, num_heads, nope_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, wuk)            # (B, H, rank)
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, cache_ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhe,bse->bhs", q_rope, cache_kr,
                      preferred_element_type=jnp.float32)) / math.sqrt(qh)
    valid = jnp.arange(smax)[None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w.astype(cache_ckv.dtype), cache_ckv,
                     preferred_element_type=jnp.float32)       # (B, H, rank)
    wuv = p["wuv"]["w"].reshape(kv_lora_rank, num_heads, v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), wuv)
    return (linear(p["wo"], o.reshape(b, 1, num_heads * v_head_dim)),
            cache_ckv, cache_kr)
