"""Activation sharding constraints that degrade gracefully off-mesh.

GSPMD propagation alone picks pathological layouts for embedding gathers
(it follows the table's vocab/d sharding and *replicates the batch*, which
makes every downstream activation 16x too big — observed directly in the
smollm dry-run HLO).  One constraint on the residual stream at the block
boundary pins the data-parallel layout and lets everything else propagate.

The helpers are no-ops when no mesh context is active (unit tests, CPU
smoke runs) or when a dim is not divisible by its axes, so model code can
call them unconditionally.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.shape:
        return None
    return m


def constrain(x: jax.Array, *spec_axes) -> jax.Array:
    """with_sharding_constraint(x, P(*spec_axes)) with graceful fallback.

    Each entry is an axis name, tuple of names, or None/P.UNCONSTRAINED.
    Axes missing from the active mesh or not dividing the dim are dropped.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    out = []
    for dim, ax in zip(x.shape, spec_axes):
        if ax is None or ax is P.UNCONSTRAINED:
            out.append(ax)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        axs = tuple(a for a in axs if a in sizes)
        total = int(np.prod([sizes[a] for a in axs])) if axs else 1
        if axs and dim % total == 0:
            out.append(axs if len(axs) > 1 else axs[0])
        else:
            out.append(P.UNCONSTRAINED)
    out += [P.UNCONSTRAINED] * (x.ndim - len(out))
    return jax.lax.with_sharding_constraint(x, P(*out))


def constrain_batch(x: jax.Array, extra=None) -> jax.Array:
    """Pin dim0 to the data-parallel axes (pod+data), rest unconstrained
    except an optional dim1 axis (sequence parallelism)."""
    u = P.UNCONSTRAINED
    return constrain(x, ("pod", "data"), extra if extra else u, u)
