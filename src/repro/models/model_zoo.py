"""Uniform model facade: build(config) -> Model with init/loss/prefill/decode,
plus ``input_specs`` emitting ShapeDtypeStruct stand-ins for every input of
every (arch x shape) cell — the dry-run lowers against these (no allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.models import vlm as vlm_mod

_I = jnp.int32
_BF = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                 # (key) -> params
    loss: Callable                 # (params, batch) -> (scalar, metrics)
    prefill: Callable | None      # (params, batch, max_seq) -> (logits, cache[, aux])
    init_cache: Callable           # (batch_size, max_seq) -> cache
    decode_step: Callable          # (params, cache, tokens, pos) -> (logits, cache)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=functools.partial(ed.init_encdec, cfg),
            loss=functools.partial(ed.encdec_loss, cfg),
            prefill=lambda params, batch, max_seq: ed.encdec_prefill(
                cfg, params, batch["frames"],
                ed.init_encdec_cache(cfg, batch["frames"].shape[0], max_seq,
                                     cfg.frontend_len)),
            init_cache=lambda b, s: ed.init_encdec_cache(cfg, b, s,
                                                         cfg.frontend_len),
            decode_step=functools.partial(ed.encdec_decode_step, cfg),
        )
    if cfg.family == "vlm":
        return Model(
            cfg=cfg,
            init=functools.partial(tf.init_lm, cfg),
            loss=functools.partial(vlm_mod.vlm_loss, cfg),
            prefill=functools.partial(vlm_mod.vlm_prefill, cfg),
            init_cache=functools.partial(tf.init_lm_cache, cfg),
            decode_step=functools.partial(tf.lm_decode_step, cfg),
        )
    prefill = None
    if cfg.family in ("dense", "moe"):
        def prefill(params, batch, max_seq):
            return tf.lm_prefill(cfg, params, batch["tokens"], max_seq)
    return Model(
        cfg=cfg,
        init=functools.partial(tf.init_lm, cfg),
        loss=functools.partial(tf.lm_loss, cfg),
        prefill=prefill,
        init_cache=functools.partial(tf.init_lm_cache, cfg),
        decode_step=functools.partial(tf.lm_decode_step, cfg),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; weak-type-correct, shardable, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Model inputs for one shape cell.

    train  : token/label batch (+ stub frontend embeddings where relevant)
    prefill: tokens only
    decode : one new token + position; the KV cache spec comes separately
             from ``cache_specs`` (it is a donated carry, not a data input).
    """
    b, s = cell.global_batch, cell.seq_len
    if cfg.family == "audio":
        frames = _sds((b, cfg.frontend_len, cfg.d_model), _BF)
        if cell.kind == "train":
            return {"frames": frames, "tokens": _sds((b, s), _I),
                    "labels": _sds((b, s), _I)}
        if cell.kind == "prefill":
            return {"frames": frames}
        return {"tokens": _sds((b, 1), _I)}
    if cfg.family == "vlm":
        p = cfg.frontend_len
        patches = _sds((b, p, cfg.d_model), _BF)
        s_text = s - p                       # total sequence = patches + text
        if cell.kind == "train":
            return {"patches": patches, "tokens": _sds((b, s_text), _I),
                    "labels": _sds((b, s_text), _I)}
        if cell.kind == "prefill":
            return {"patches": patches, "tokens": _sds((b, s_text), _I)}
        return {"tokens": _sds((b, 1), _I)}
    if cell.kind == "train":
        return {"tokens": _sds((b, s), _I), "labels": _sds((b, s), _I)}
    if cell.kind == "prefill":
        return {"tokens": _sds((b, s), _I)}
    return {"tokens": _sds((b, 1), _I)}


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> Any:
    """ShapeDtypeStruct pytree for the decode cache of one cell."""
    model = build(cfg)
    return jax.eval_shape(lambda: model.init_cache(cell.global_batch,
                                                   cell.seq_len))


def param_specs(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree for params (AOT lowering, no allocation)."""
    model = build(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
