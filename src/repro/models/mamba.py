"""Mamba (selective SSM) block — the sub-quadratic half of Jamba.

Mamba-1 as used by Jamba (arXiv:2403.19887): in_proj -> causal depthwise
conv -> selective scan (input-dependent dt, B, C over a diagonal state) ->
gate -> out_proj.  The sequence scan is a lax.scan carrying the (B, d_inner,
d_state) state: O(1) memory in sequence length, which is what makes the
long_500k cell runnable for the hybrid/ssm families (DESIGN.md §4).

Decode keeps (conv_state, ssm_state) per layer and advances one token in
O(d_inner * d_state) — no KV cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, init_linear, linear, truncated_normal


def init_mamba(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dtype=jnp.bfloat16) -> Params:
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": truncated_normal(ks[1], (d_conv, d_inner), 0.1, dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": init_linear(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": init_linear(ks[3], dt_rank, d_inner, dtype, bias=True),
        "a_log": jnp.log(a),                                  # fp32
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_linear(ks[4], d_inner, d_model, dtype),
    }


def _conv_causal(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv; x: (B, S, d_inner), w: (K, d_inner)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return y + b[None, None, :]


def _ssm_params(p: Params, x: jax.Array, d_state: int):
    """Input-dependent (dt, B, C); x: (..., d_inner)."""
    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = linear(p["x_proj"], x)
    dt = jax.nn.softplus(linear(p["dt_proj"], proj[..., :dt_rank])
                         .astype(jnp.float32))                # (..., d_inner)
    bmat = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    cmat = proj[..., dt_rank + d_state:].astype(jnp.float32)
    return dt, bmat, cmat


def mamba_train(p: Params, x: jax.Array, *, d_state: int = 16) -> jax.Array:
    """x: (B, S, D) -> (B, S, D); scan over time."""
    b, s, d = x.shape
    xz = linear(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # (B, S, d_inner)
    xi = jax.nn.silu(_conv_causal(p["conv_w"], p["conv_b"], xi))
    dt, bmat, cmat = _ssm_params(p, xi, d_state)
    a = -jnp.exp(p["a_log"])                                  # (d_inner, N)

    def step(h, inp):
        xt, dtt, bt, ct = inp                                 # (B,di) (B,di) (B,N) (B,N)
        da = jnp.exp(dtt[..., None] * a[None])                # (B, di, N)
        db = dtt[..., None] * bt[:, None, :]                  # (B, di, N)
        h = da * h + db * xt.astype(jnp.float32)[..., None]
        y = jnp.einsum("bdn,bn->bd", h, ct)                   # (B, di)
        return h, y

    h0 = jnp.zeros((b, xi.shape[-1], d_state), jnp.float32)
    xs = (jnp.moveaxis(xi, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                # (B, S, d_inner)
    y = y + xi.astype(jnp.float32) * p["d_skip"][None, None, :]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return linear(p["out_proj"], y)


def init_mamba_state(batch: int, d_model: int, *, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2):
    d_inner = expand * d_model
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), jnp.bfloat16),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode(p: Params, x: jax.Array, state: dict, *, d_state: int = 16):
    """One-token step. x: (B, 1, D). Returns (y, state)."""
    xz = linear(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # (B, 1, di)
    window = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
    k = p["conv_w"].shape[0]
    y = sum(window[:, i, :] * p["conv_w"][i][None, :] for i in range(k))
    xi1 = jax.nn.silu(y + p["conv_b"][None, :])               # (B, di)
    new_conv = window[:, 1:, :].astype(state["conv"].dtype)
    dt, bmat, cmat = _ssm_params(p, xi1, d_state)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a[None])
    db = dt[..., None] * bmat[:, None, :]
    h = da * state["ssm"] + db * xi1.astype(jnp.float32)[..., None]
    yo = jnp.einsum("bdn,bn->bd", h, cmat)
    yo = yo + xi1.astype(jnp.float32) * p["d_skip"][None, :]
    yo = yo.astype(x.dtype) * jax.nn.silu(z[:, 0])
    out = linear(p["out_proj"], yo)[:, None, :]
    return out, {"conv": new_conv, "ssm": h}
