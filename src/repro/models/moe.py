"""Mixture-of-Experts FFN with grouped, sort-free (cumsum-ranked) dispatch.

Token -> expert routing is the paper's *multisplit* primitive
(repro.core.distributed.multisplit) specialized for SPMD execution.  Two
structural choices matter at 256-way scale (both found via the §Perf
hillclimb on deepseek-v2; see EXPERIMENTS.md):

1. **Grouped dispatch**: tokens are reshaped to (G, T/G, D) with the group
   dim pinned to the data axes.  Every routing op (top-k, rank, scatter to
   the expert buffer, gather back) is vmapped over G, so XLA sees *batched*
   scatters/gathers it can partition along G.  Without the group dim, the
   dp-sharded-tokens -> expert-sharded-buffer scatter has no common axis and
   GSPMD falls back to "involuntary full rematerialization" (replicating
   the token tensor on every chip).

2. **Rank-by-cumsum** (GShard): position-in-expert from an exclusive cumsum
   over (T, E) one-hots, one pass per top-k choice, k-priority drop order.
   The argsort-based variant is semantically equivalent but lowers to a
   cross-shard sort network — measured 30% MORE collective traffic.

Capacity semantics are per-group (standard practice — each data shard
dispatches its own tokens); dropped assignments contribute zero, exactly
the padded-exchange semantics of the distributed hash table (DESIGN §3.3).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, init_linear, init_mlp, linear, mlp
from repro.models import shardutil

DEFAULT_GROUPS = 32      # = pod * data on the production meshes


def init_moe(key, d_model: int, d_ff_expert: int, num_experts: int,
             num_shared: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    ek = jax.random.split(ks[0], num_experts)
    experts = jax.vmap(
        lambda k: init_mlp(k, d_model, d_ff_expert, dtype, kind="swiglu"))(ek)
    p = {
        "router": init_linear(ks[1], d_model, num_experts, jnp.float32),
        "experts": experts,                          # stacked (E, ...) pytree
    }
    if num_shared > 0:
        p["shared"] = init_mlp(ks[2], d_model, num_shared * d_ff_expert, dtype,
                               kind="swiglu")
    return p


def _expert_mlp(experts: Params, xe: jax.Array) -> jax.Array:
    """xe: (G, E, C, D) -> (G, E, C, D); batched swiglu over experts."""
    gate = jnp.einsum("gecd,edf->gecf", xe, experts["gate"]["w"])
    up = jnp.einsum("gecd,edf->gecf", xe, experts["up"]["w"])
    h = jax.nn.silu(gate) * up
    return jnp.einsum("gecf,efd->gecd", h, experts["down"]["w"])


def _largest_divisor(n: int, upto: int) -> int:
    for g in range(min(upto, n), 0, -1):
        if n % g == 0:
            return g
    return 1


def moe_ffn(p: Params, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, num_groups: int = DEFAULT_GROUPS,
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    g = _largest_divisor(t, num_groups)
    tg = t // g
    capacity = max(1, int(math.ceil(tg * top_k * capacity_factor
                                    / num_experts)))
    xg = x.reshape(g, tg, d)
    xg = shardutil.constrain(xg, ("pod", "data"), None, None)
    eids = jnp.arange(num_experts)

    def route_group(xf):
        """(Tg, D) -> (slot (k*Tg,), weight, src, probs)."""
        logits = linear(p["router"], xf.astype(jnp.float32))   # (Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, top_k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
        base = jnp.zeros((num_experts,), jnp.int32)
        ranks = []
        for kk in range(top_k):                               # k-priority
            onehot = (top_i[:, kk][:, None] == eids[None, :]).astype(jnp.int32)
            within = jnp.cumsum(onehot, axis=0) - 1
            r = jnp.take_along_axis(within, top_i[:, kk][:, None],
                                    axis=1)[:, 0] + base[top_i[:, kk]]
            ranks.append(r)
            base = base + jnp.sum(onehot, axis=0)
        rank = jnp.concatenate(ranks)                         # (k*Tg,)
        e_flat = top_i.T.reshape(-1)
        w_flat = top_w.T.reshape(-1)
        src = jnp.tile(jnp.arange(tg), top_k)
        keep = rank < capacity
        slot = jnp.where(keep, e_flat * capacity + rank,
                         num_experts * capacity)
        # load-balance stats (Switch): fraction routed + mean router prob
        frac = base.astype(jnp.float32) / (tg * top_k)
        return slot, w_flat, src, keep, frac, jnp.mean(probs, axis=0)

    slot, w_flat, src, keep, frac, meanp = jax.vmap(route_group)(xg)

    def dispatch_group(xf, slot, src):
        buf = jnp.zeros((num_experts * capacity, d), x.dtype)
        return buf.at[slot].set(xf[src], mode="drop")

    xbuf = jax.vmap(dispatch_group)(xg, slot, src)            # (G, E*C, D)
    ybuf = _expert_mlp(p["experts"], xbuf.reshape(g, num_experts, capacity, d))
    ybuf = ybuf.reshape(g, num_experts * capacity, d)

    def combine_group(ybuf, slot, keep, w, src):
        ya = jnp.take(ybuf, jnp.minimum(slot, num_experts * capacity - 1),
                      axis=0)
        # weight in bf16 BEFORE any cast: the expert->token combine crosses
        # the model axis, and an f32 intermediate here doubles that
        # collective's wire bytes (§Perf cell 2, iter 4)
        ya = jnp.where(keep[:, None], ya, 0) * w[:, None].astype(ya.dtype)
        return jnp.zeros((tg, d), x.dtype).at[src].add(ya.astype(x.dtype))

    y = jax.vmap(combine_group)(ybuf, slot, keep, w_flat, src)
    y = y.reshape(b, s, d)

    if "shared" in p:
        y = y + mlp(p["shared"], x.reshape(b, s, d), kind="swiglu")

    aux = num_experts * jnp.mean(jnp.sum(frac * meanp, axis=-1))
    return y, aux
