"""Fault-tolerant sharded checkpointing — save/restore/reshard, no orbax.

Design (DESIGN.md §5):
- one ``.npy`` blob per pytree leaf, named by its flattened key path, plus a
  ``manifest.json`` recording tree structure, logical dtypes and the step;
- **atomic**: everything is written into ``<dir>/tmp.<step>`` then
  os.rename'd to ``<dir>/step_<n>`` — a crash mid-save never corrupts the
  latest checkpoint;
- **async**: ``save_async`` snapshots to host (device_get) synchronously —
  cheap — and does file I/O on a daemon thread; the next save joins it
  (bounded staleness of one);
- **elastic restore**: ``restore`` takes target shardings; leaves are
  device_put with the *new* mesh's NamedSharding, so restoring a checkpoint
  onto a different mesh shape (scale up/down) is the same code path;
- bf16 leaves are stored as uint16 bit patterns (npy has no bfloat16),
  with the logical dtype recorded in the manifest;
- ``keep`` bounds retained checkpoints (oldest pruned after a successful
  save).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, dtype: str):
    if dtype == _BF16:
        return arr.view(jnp.bfloat16)
    return arr


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        self.wait()
        host = self._snapshot(tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Snapshot synchronously (device -> host), write on a thread."""
        self.wait()
        host = self._snapshot(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree: Any):
        leaves_kp = jax.tree_util.tree_flatten_with_path(tree)[0]
        return [(_path_str(kp), _to_numpy(x)) for kp, x in leaves_kp]

    def _write(self, step: int, host_leaves, extra: dict) -> str:
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for name, (arr, dtype) in host_leaves:
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "dtype": dtype, "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into ``template``'s structure.  ``shardings`` (same-struct
        pytree of jax.sharding.Sharding, or None) places each leaf — pass the
        NEW mesh's shardings to reshard elastically."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}

        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves_kp))
        out = []
        for (kp, tmpl), shard in zip(leaves_kp, shard_leaves):
            name = _path_str(kp)
            arr = np.load(os.path.join(path, name + ".npy"))
            arr = _from_numpy(arr, dtypes[name])
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out), manifest["extra"]
