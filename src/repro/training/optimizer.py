"""Optimizers — AdamW and Adafactor, built here (no optax dependency).

Functional API: ``init(cfg, params) -> state``; ``update(cfg, grads, state,
params) -> (new_params, new_state, stats)``.  Grads arrive in fp32 (the
train loop accumulates in fp32); params stay in their storage dtype.

Adafactor exists for the memory-critical archs (jamba-398B, deepseek-236B,
mistral-123B): factored second moments cost ~4 bytes/param versus AdamW's 8,
which is the difference between fitting and not fitting a 16 GB HBM chip at
256-way sharding (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"               # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # adafactor
    factored_min_dim: int = 128


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments
# ---------------------------------------------------------------------------

def _factored(p, min_dim: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def _adafactor_init(params, min_dim: int):
    def per_leaf(p):
        if _factored(p, min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(per_leaf, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def _adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    beta2 = 1.0 - step.astype(jnp.float32) ** -0.8      # t^-0.8 decay schedule
    eps = 1e-30

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p, cfg.factored_min_dim):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            pre = (vr / denom)[..., None] * vc[..., None, :]
            delta = g * jax.lax.rsqrt(jnp.maximum(pre, eps))
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            delta = g * jax.lax.rsqrt(jnp.maximum(vv, eps))
            new_v = {"v": vv}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + eps)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, new_v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v, "step": step}, {"lr": lr}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def init(cfg: OptConfig, params):
    if cfg.name == "adamw":
        return _adamw_init(params)
    if cfg.name == "adafactor":
        return _adafactor_init(params, cfg.factored_min_dim)
    raise ValueError(cfg.name)


def update(cfg: OptConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.name == "adamw":
        p, s, stats = _adamw_update(cfg, grads, state, params)
    elif cfg.name == "adafactor":
        p, s, stats = _adafactor_update(cfg, grads, state, params)
    else:
        raise ValueError(cfg.name)
    stats["grad_norm"] = gnorm
    return p, s, stats


def state_bytes(state) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(state)
               if hasattr(x, "dtype"))
