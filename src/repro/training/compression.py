"""Gradient compression with error feedback — cross-pod bandwidth savers.

Two compressors, both with error-feedback state so compression error is
carried to the next step instead of lost (Karimireddy et al., 2019):

- ``int8``  — per-tensor symmetric quantization to int8 (4x fewer wire
  bytes for fp32 grads).  ``compressed_psum`` performs the cross-shard sum
  on the int8 payload (accumulated in int32) inside shard_map, so the wire
  format really is 1 byte/element on the slow (cross-pod) axis.
- ``topk``  — magnitude top-k sparsification (values + indices), for the
  very-low-bandwidth regime.

The train loop applies compression ONLY to the designated axis (cross-pod
DP sync), never to intra-pod TP collectives — ICI is fast, DCI is not.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size_compat


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | int8 | topk
    topk_frac: float = 0.01


def init_state(cfg: CompressionConfig, grads_shape):
    """Error-feedback residual, one fp32 leaf per grad leaf."""
    if cfg.kind == "none":
        return {}
    return {"residual": jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)}


# ---------------------------------------------------------------------------
# int8
# ---------------------------------------------------------------------------

def quantize_int8(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(g: jax.Array):
    q, s = quantize_int8(g)
    return dequantize_int8(q, s)


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------

def topk_roundtrip(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    keep = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return keep.reshape(g.shape)


# ---------------------------------------------------------------------------
# error-feedback wrapper
# ---------------------------------------------------------------------------

def compress_decompress(cfg: CompressionConfig, grads, state):
    """Apply lossy round-trip with error feedback.  Returns (grads, state).

    The round-tripped values are exactly what the other shards would decode,
    so applying them locally keeps all replicas bit-identical.
    """
    if cfg.kind == "none":
        return grads, state

    def per_leaf(g, r):
        g = g.astype(jnp.float32) + r
        if cfg.kind == "int8":
            out = int8_roundtrip(g)
        else:
            out = topk_roundtrip(g, cfg.topk_frac)
        return out, g - out

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(state["residual"])
    outs = [per_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, {"residual": new_r}


def compressed_psum(cfg: CompressionConfig, grads, axis: str, state):
    """Cross-shard gradient sum with int8 wire format (call inside shard_map).

    Quantizes, psums the int8 payload in int32 (no overflow up to 2^23
    shards), and dequantizes with the max scale — then mean-normalizes.
    """
    n = axis_size_compat(axis)
    if cfg.kind == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, grads), state

    def per_leaf(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g)
        gmax = jax.lax.pmax(scale, axis)           # shared scale across shards
        q = jnp.clip(jnp.round(g / gmax), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        out = summed.astype(jnp.float32) * gmax / n
        return out, g - dequantize_int8(q, gmax)   # residual vs what was sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(state["residual"])
    outs = [per_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            {"residual": treedef.unflatten([o[1] for o in outs])})
