"""Train-step builder: grad accumulation, fp32 grad accumulate, optimizer
update, optional gradient compression hook.

``make_train_step(model, opt_cfg, accum_steps)`` returns a pure
``train_step(state, batch) -> (state, metrics)`` suitable for jax.jit with
donated state.  Microbatch accumulation is a lax.scan over ``accum_steps``
slices of the batch — the standard trick for fitting large global batches,
and it gives XLA's latency-hiding scheduler independent per-microbatch
reduce-scatters to overlap with the next microbatch's compute (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt_mod
from repro.training.optimizer import OptConfig


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    def tree_flatten(self):  # pragma: no cover - registered below
        raise NotImplementedError


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "step"], meta_fields=[])


def init_state(model, opt_cfg: OptConfig, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=opt_mod.init(opt_cfg, params),
                      step=jnp.zeros((), jnp.int32))


def _split_microbatch(batch: dict, accum_steps: int, i: jax.Array) -> dict:
    def slice_leaf(x):
        mb = x.shape[0] // accum_steps
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(slice_leaf, batch)


def make_train_step(model, opt_cfg: OptConfig, *, accum_steps: int = 1,
                    grad_transform: Callable | None = None):
    """Build train_step. ``grad_transform(grads) -> grads`` hooks compression
    or custom cross-axis reductions between accumulation and the update."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def micro(carry, i):
                acc = carry
                mb = _split_microbatch(batch, accum_steps, i)
                (l, m), g = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / accum_steps,
                    acc, g)
                return acc, (l, m)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, ms) = jax.lax.scan(micro, zero,
                                               jnp.arange(accum_steps))
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)

        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, stats = opt_mod.update(opt_cfg, grads,
                                                  state.opt_state, state.params)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        out = {"loss": loss, **metrics, **stats}
        return new_state, out

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}
    return eval_step
