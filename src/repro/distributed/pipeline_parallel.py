"""Pipeline parallelism over the ``pod`` axis — ppermute-based GPipe.

The block stack's leading dim is split across pipeline stages (the ``pod``
mesh axis); microbatches stream through stages with
``jax.lax.ppermute`` moving activations to the next stage.  The schedule is
the scan-based rotating-buffer pipeline used by praxis/MaxText: at step t,
stage s processes microbatch (t - s); jax.grad differentiates straight
through (ppermute's transpose is the reverse ppermute), giving GPipe-style
training without a hand-written 1F1B.

Bubble fraction is (S-1)/(M+S-1) for S stages and M microbatches — choose
M >= 4*S.  This is an opt-in alternative to the default pod=DP mapping
(see DESIGN.md §5); ``tests/test_pipeline_parallel.py`` validates gradient
equivalence against the unpipelined model on a host mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import axis_size_compat, shard_map_compat


def stage_params(params_blocks, num_stages: int):
    """Split stacked (L, ...) block params into (S, L/S, ...) stage stacks."""
    def split(x):
        l = x.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return x.reshape(num_stages, l // num_stages, *x.shape[1:])
    return jax.tree.map(split, params_blocks)


def pipelined_apply(block_fn: Callable, staged_params, x_microbatches,
                    axis: str):
    """Run microbatches through pipeline stages connected by ppermute.

    Call INSIDE shard_map where ``staged_params`` has its stage dim mapped
    over ``axis`` (each device holds (1, L/S, ...)) and ``x_microbatches``
    is (M, mb, S, D) — every stage holds all microbatches (simplest
    rotating-buffer variant).

    Returns (M, mb, S, D) outputs valid on the LAST stage.
    """
    num_stages = axis_size_compat(axis)
    stage = jax.lax.axis_index(axis)
    local_params = jax.tree.map(lambda p: p[0], staged_params)   # (L/S, ...)
    m = x_microbatches.shape[0]
    total_ticks = m + num_stages - 1
    perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]

    def run_stage(h):
        def body(carry, blk):
            return block_fn(blk, carry), None
        out, _ = jax.lax.scan(body, h, local_params)
        return out

    def tick(carry, t):
        buf, outs = carry                          # buf: (mb, S, D) in flight
        # which microbatch enters stage 0 at tick t
        mb_idx = jnp.clip(t, 0, m - 1)
        incoming = x_microbatches[mb_idx]
        h_in = jnp.where(stage == 0, incoming, buf)
        h_out = run_stage(h_in)
        # last stage writes its completed microbatch (t - S + 1)
        done_idx = t - (num_stages - 1)
        write = jnp.logical_and(stage == num_stages - 1, done_idx >= 0)
        outs = jax.lax.cond(
            write,
            lambda o: jax.lax.dynamic_update_slice_in_dim(
                o, h_out[None], jnp.clip(done_idx, 0, m - 1), axis=0),
            lambda o: o, outs)
        buf = jax.lax.ppermute(h_out, axis, perm)
        return (buf, outs), None

    buf0 = jnp.zeros_like(x_microbatches[0])
    outs0 = jnp.zeros_like(x_microbatches)
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(total_ticks))
    # broadcast final outputs from the last stage to everyone
    outs = jax.lax.ppermute(
        outs, axis, [( (num_stages - 1 + i) % num_stages, i)
                     for i in range(num_stages)]) if num_stages > 1 else outs
    return outs


def make_pipelined_loss(block_fn: Callable, loss_head: Callable,
                        embed_fn: Callable, mesh: Mesh, axis: str = "pod",
                        num_microbatches: int = 8):
    """Wrap a block-structured LM into a pipeline-parallel loss over ``axis``.

    embed_fn(params, batch) -> (h, extras); loss_head(params, h, batch) ->
    scalar.  Embedding and head run replicated over the pipeline axis (they
    are cheap relative to blocks at scale; vocab stays sharded over model).
    """
    def loss(params, batch):
        def inner(staged_blocks, h_mb, batch_local):
            outs = pipelined_apply(block_fn, staged_blocks, h_mb, axis)
            return outs

        def full(params, batch):
            h, extras = embed_fn(params, batch)
            mbs = h.reshape(num_microbatches,
                            h.shape[0] // num_microbatches, *h.shape[1:])
            staged = stage_params(params["blocks"],
                                  int(mesh.shape[axis]))
            spec_blocks = jax.tree.map(lambda _: P(axis), staged)
            outs = shard_map_compat(
                functools.partial(inner), mesh,
                in_specs=(spec_blocks, P(), P()),
                out_specs=P(),
            )(staged, mbs, 0)
            h_out = outs.reshape(h.shape)
            return loss_head(params, h_out, batch)

        return full(params, batch)

    return loss
