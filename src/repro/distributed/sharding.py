"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (DESIGN.md §5): FSDP over the ``data`` axis + TP over ``model`` +
EP (experts over ``model``) + sequence sharding for long-context caches.
The ``pod`` axis, when present, extends data parallelism (batch and FSDP
both widen over pod x data).

Rules are path-keyword driven with a final divisibility guard: any dim not
divisible by its assigned axis size falls back to replication for that dim
— so one rule set serves all ten architectures (uneven head counts, odd
vocab sizes, 1500-frame cross caches, ...).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fit(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Clip a logical spec to a concrete shape with divisibility fallback."""
    if len(spec) < len(shape):                       # leading stack dims
        spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    spec = tuple(spec[-len(shape):]) if shape else ()
    out = []
    for dim, axis in zip(shape, spec):
        out.append(axis if axis and dim % _axis_size(mesh, axis) == 0 else None)
    return P(*out)


# logical 2-D cores: (row_axis, col_axis).  DATA/MODEL are placeholders
# resolved against the mesh (DATA widens to ('pod','data') on multi-pod).
_PARAM_RULES: list[tuple[str, tuple]] = [
    # MoE experts: (E, D, F) / (E, F, D) with E on the model axis (EP)
    (r"experts.*(gate|up)", ("MODEL", "DATA", None)),
    (r"experts.*down", ("MODEL", None, "DATA")),
    # embeddings / heads
    (r"embed.*table", ("MODEL", "DATA")),
    (r"lm_head", ("DATA", "MODEL")),
    (r"frontend_proj", ("DATA", "MODEL")),
    # attention projections
    (r"(wq|wk|wv|wuq|wdq|wdkv|wkr|wuk|wuv)\b.*w$", ("DATA", "MODEL")),
    (r"wo\b.*w$", ("MODEL", "DATA")),
    # dense mlp
    (r"(gate|up|wk)\b.*w$", ("DATA", "MODEL")),
    (r"(down|wv)\b.*w$", ("MODEL", "DATA")),
    # mamba
    (r"in_proj", ("DATA", "MODEL")),
    (r"out_proj", ("MODEL", "DATA")),
    (r"x_proj", ("MODEL", None)),
    (r"dt_proj", (None, "MODEL")),
    (r"(conv_w|conv_b|a_log|d_skip)", ("MODEL",)),
    # rwkv
    (r"(wr|wg)\b.*w$", ("DATA", "MODEL")),
    (r"(mix_a|decay_a)", ("DATA", None)),
    (r"(mix_b|decay_b)", (None, None, "MODEL")),
    # router fp32, norms, biases: replicate
    (r"router", (None, None)),
]


def _resolve(axis, mesh: Mesh, mode: str = "train"):
    if axis == "DATA":
        if mode == "serve":
            return None          # no FSDP: weights must not gather per token
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return dp if len(dp) > 1 else (dp[0] if dp else None)
    if axis == "MODEL":
        if mode == "serve":
            # serving: the whole mesh is tensor-parallel for weights — a
            # decode step touches every weight once, so FSDP-style gathers
            # would move the full model over ICI per token (§Perf cell 3)
            axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)
            return axes if len(axes) > 1 else (axes[0] if axes else None)
        return "model" if "model" in mesh.axis_names else None
    return axis


def param_spec(path: str, shape: tuple, mesh: Mesh, mode: str = "train") -> P:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            resolved = tuple(_resolve(a, mesh, mode) for a in spec)
            return _fit(resolved, shape, mesh)
    if len(shape) >= 2:
        resolved = (_resolve("DATA", mesh, mode), _resolve("MODEL", mesh, mode))
        return _fit(resolved, shape, mesh)
    return _fit((None,) * len(shape), shape, mesh)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def tree_shardings(tree: Any, mesh: Mesh, mode: str = "train") -> Any:
    """NamedSharding pytree for params or optimizer state (same rules —
    opt-state leaves inherit the rule matched by their param path prefix,
    clipped to their own rank, so Adafactor's vr/vc factor shardings follow
    the param automatically).  mode="serve" turns FSDP off (see _resolve)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in leaves:
        spec = param_spec(_path_str(kp), tuple(leaf.shape), mesh, mode)
        out.append(NamedSharding(mesh, spec))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_spec(shape: tuple, mesh: Mesh) -> P:
    """Shard dim0 (batch) over pod+data when divisible."""
    dp = _resolve("DATA", mesh)
    return _fit((dp,) + (None,) * (len(shape) - 1), shape, mesh)


def batch_shardings(batch_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(tuple(leaf.shape), mesh)),
        batch_tree)


def cache_shardings(cache_tree: Any, mesh: Mesh, cfg: ModelConfig,
                    cell: ShapeCell) -> Any:
    """Decode-cache placement.

    Priority per leaf (shape (L, B, S, [H, hd]) or state tensors):
      1. batch over pod+data when divisible,
      2. kv-heads over model when divisible, else sequence over model,
      3. batch==1 long-context: sequence over every available axis.
    """
    dp = _resolve("DATA", mesh)
    model = _resolve("MODEL", mesh)
    dp_size = _axis_size(mesh, dp)

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if len(shape) < 3:
            return P(*([None] * len(shape)))
        b, rest = shape[1], shape[2:]
        spec = [None] * len(shape)
        if b % dp_size == 0:
            spec[1] = dp
            seq_axes = model
        else:
            seq_axes = (tuple(a for a in ((dp,) if isinstance(dp, str) else dp)
                              ) + (model,)) if model else dp
            if isinstance(seq_axes, tuple) and len(seq_axes) == 1:
                seq_axes = seq_axes[0]
        # heads over model (dim -2 for (L,B,S,H,hd))
        if (len(shape) == 5 and model
                and shape[3] % _axis_size(mesh, model) == 0):
            spec[3] = model
        elif len(shape) >= 4 and shape[2] % _axis_size(mesh, seq_axes or ()) == 0 \
                and seq_axes:
            spec[2] = seq_axes
        elif len(shape) == 4 and model and shape[3] % _axis_size(mesh, model) == 0:
            spec[3] = model                    # e.g. MLA (L,B,S,rank): rank/model
        return _fit(tuple(spec), shape, mesh)

    return jax.tree.map(lambda l: NamedSharding(mesh, leaf_spec(l)), cache_tree)


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# jax-version compat + ownership partitioning (re-exported from repro.core)
# ---------------------------------------------------------------------------
#
# The hash-table side of the system (repro.core.distributed) assigns every
# key exactly one owner shard via ``hash_owner``.  The relational operators
# (repro.relational.join) reuse that rule to co-partition *both* sides of a
# join: route build and probe batches to the key's owner, and each shard
# joins only the keys it owns — one writer per shard, no CAS, no result
# merging.  Composite multi-word keys ride the same exchange: ``owner_of``
# folds every key plane before ``hash_owner``, so (n, key_words) batches
# co-partition uniformly and the sharded join accepts tuple-of-column keys
# end-to-end.  The routing block itself (owner_of -> make_plan -> scatter ->
# all_to_all) lives in ``repro.core.exchange`` — one implementation for the
# distributed tables AND the relational shuffle — and the version shims in
# ``repro.core.compat``; both are re-exported here for existing callers
# (distributed code may import core, never the reverse).

from repro.core.compat import (  # noqa: E402,F401  (re-exports)
    axis_size_compat,
    make_mesh_compat,
    set_mesh_compat,
    shard_map_compat,
)
from repro.core.exchange import (  # noqa: E402,F401  (re-exports)
    ExchangePlan,
    ownership_exchange,
    ownership_return,
)
