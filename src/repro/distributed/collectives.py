"""Collective helpers: cross-pod gradient sync with optional compression,
and overlap-friendly reduction scheduling.

``make_grad_sync`` builds the grad_transform hook for the train loop: a
nested shard_map over ONLY the ``pod`` axis (data/model stay GSPMD-auto)
that all-reduces gradients across pods — in int8 wire format when
compression is enabled (repro.training.compression.compressed_psum).  This
is the mechanism that turns the slow cross-pod DCI hop into 1 byte/element
traffic while ICI-local collectives stay in bf16/f32 (DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import axis_size_compat, shard_map_compat
from repro.training import compression as comp


def make_grad_sync(mesh: Mesh, cfg: comp.CompressionConfig,
                   state_holder: dict | None = None) -> Callable:
    """grad_transform(grads) -> grads, averaging over the pod axis.

    With ``cfg.kind == 'none'`` this is a plain psum-mean over pods (what
    GSPMD would insert anyway — made explicit so it can be scheduled and
    measured).  With int8/topk, the wire payload is compressed with error
    feedback kept in ``state_holder`` (a mutable dict captured across steps
    via donated carry in launch.train)."""
    if "pod" not in mesh.axis_names:
        return lambda g: g

    def sync(grads):
        def body(g):
            if cfg.kind == "none":
                n = axis_size_compat("pod")
                return jax.tree.map(lambda x: jax.lax.psum(x, "pod") / n, g)
            st = {"residual": jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), g)}
            out, _ = comp.compressed_psum(cfg, g, "pod", st)
            return out

        spec = jax.tree.map(lambda _: P(), grads)
        # manual over 'pod' only; data/model stay GSPMD-automatic
        return shard_map_compat(body, mesh, in_specs=(spec,),
                                out_specs=spec,
                                axis_names=frozenset({"pod"}))(grads)

    return sync


def reduce_scatter_grads(grads, axis: str):
    """Per-parameter reduce-scatter along dim0 (ZeRO-style sharded grads) —
    callable inside shard_map when manual gradient placement is wanted."""
    def rs(g):
        if g.ndim >= 1 and g.shape[0] % axis_size_compat(axis) == 0:
            return jax.lax.psum_scatter(g, axis, scatter_dimension=0,
                                        tiled=True)
        return jax.lax.psum(g, axis)
    return jax.tree.map(rs, grads)
