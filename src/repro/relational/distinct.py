"""DISTINCT / dedup on a WarpCore HashSet.

``add`` on the set reports per element whether its key claimed a fresh
slot — the insert-status trick the data pipeline's ``dedup_filter``
already uses (STATUS_INSERTED <=> first occurrence).  On top of that this
module offers:

- ``first_occurrence`` — streaming dedup mask against a running set (feed
  batch after batch; duplicates across batches are caught);
- ``distinct`` — one-shot compaction of the unique keys into a static
  ``out_capacity`` output (counting-pass style: the mask's cumulative sum
  is the output layout).

Pure, jittable, pytree-functional, like the rest of repro.relational.
Membership re-checks (``hs.contains`` on the running set) ride the fused
bulk-retrieval engine's dedup walk on the default backend, like every
other retrieval consumer.

Composite multi-column keys: ``distinct`` accepts a tuple of u32 columns
(``key_words`` inferred) and then returns the unique keys as a matching
tuple of columns; DISTINCT over (a, b) pairs is one call, no manual
packing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core import hashset as hs
from repro.core import single_value as sv
from repro.core.common import DEFAULT_SEED, DEFAULT_WINDOW
from repro.relational.util import capacity_for, compact  # compact re-exported

_U = jnp.uint32
_I = jnp.int32

DistinctSet = hs.HashSet


def create(min_capacity: int, *, key_words: int = 1,
           window: int = DEFAULT_WINDOW, scheme: str = "cops",
           layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax") -> DistinctSet:
    return hs.create(min_capacity, key_words=key_words, window=window,
                     scheme=scheme, layout=layout, seed=seed,
                     max_probes=max_probes, backend=backend)


def first_occurrence(dset: DistinctSet, keys, mask=None,
                     ) -> tuple[DistinctSet, jax.Array]:
    """Streaming dedup: True where the key was never seen before.

    Duplicates within the batch and against every earlier batch fed into
    ``dset`` are both marked False (the set is the cross-batch memory).
    """
    return hs.add(dset, keys, mask=mask)


def distinct(keys, out_capacity: int, *, key_words: int | None = None,
             window: int = DEFAULT_WINDOW, backend: str = "jax",
             load: float = 0.5, capacity: int | None = None, mask=None,
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-shot DISTINCT: (unique_keys, n_unique, first_occurrence_mask).

    ``unique_keys`` comes back in first-occurrence order, shaped like the
    input: a tuple of columns for tuple input, (out_capacity,) for flat
    1-word input, else (out_capacity, key_words) planes; entries past
    ``n_unique`` are zero.  ``key_words`` is inferred when omitted.
    """
    as_columns = isinstance(keys, tuple)
    keys_n, key_words = sv.normalize_keys(keys, key_words, "keys")
    n = keys_n.shape[0]
    if capacity is None:
        capacity = capacity_for(n, load, window)
    dset = create(capacity, key_words=key_words, window=window,
                  backend=backend)
    _, fresh = first_occurrence(dset, keys_n, mask=mask)
    packed, n_unique = compact(keys_n, fresh, out_capacity)
    if as_columns:
        return hashing.unpack_columns(packed), n_unique, fresh
    if key_words == 1:
        packed = packed[:, 0]
    return packed, n_unique, fresh
