"""repro.relational — relational operators on WarpCore hash tables.

The paper's headline comparison (§V, Fig. 5-7) benchmarks WarpCore
against NVIDIA RAPIDS **cuDF** — a GPU *relational* engine whose join,
group-by, and drop-duplicates operators are hash tables under the hood.
This subsystem closes the loop: the same operators, built from the
repo's table primitives, so the reproduction covers not just the
microbenchmark but the workload class cuDF represents ("data processing
pipelines entirely on the GPU", §I):

====================  ==============================  =====================
operator              cuDF analogue                   substrate
====================  ==============================  =====================
``join.hash_join``    ``cudf.merge`` (inner/left/     MultiValueHashTable +
                      semi/anti hash join)            counting-pass sizing
``groupby.aggregate`` ``cudf.groupby().agg`` (sum /   SingleValueHashTable
                      min / max / count / mean)       RMW upsert
``distinct.distinct`` ``cudf.drop_duplicates``        HashSet insert status
``join.shard_join``   dask-cudf shuffle join          ownership exchange
====================  ==============================  =====================

Every operator is a pure, jittable pytree function and runs on both the
``"jax"`` and ``"pallas"`` table backends (the build side of a join goes
through the COPS Pallas kernel when the table says so).  Keys may be
composite: pass a tuple of u32 columns (``hash_join((a, b), (c, d),
...)``) and ``key_words`` is inferred — outputs are bit-exact against
the equivalently-packed single-word run (fig9's in-run parity gate).
The sharded join co-partitions both inputs by the ``hash_owner`` rule
via ``repro.distributed.sharding.ownership_exchange`` (hashing every
key plane) — one writer per shard, the paper's multi-GPU ownership
partitioning (§IV-E) reused as a shuffle.
"""

from repro.relational import distinct, groupby, join
from repro.relational.groupby import AGGS, aggregate
from repro.relational.join import HOW, NO_MATCH, JoinResult, hash_join, shard_join

__all__ = [
    "AGGS", "HOW", "NO_MATCH", "JoinResult",
    "aggregate", "distinct", "groupby", "hash_join", "join", "shard_join",
]
