"""Hash joins on WarpCore tables (inner / left-outer / semi / anti).

Classic two-phase GPU hash join, rendered on the repo's table primitives:

- **build** — insert every build-side row as a ``(key, row_index)`` pair
  into a ``MultiValueHashTable`` (duplicate build keys occupy distinct
  slots, so N:M joins fall out of the multi-value semantics for free).
  The default ``backend="jax"`` build runs the vectorized bulk engine
  (``repro.core.bulk``: one placement fixpoint instead of a per-row scan);
  ``backend="scan"`` selects the sequential reference and
  ``backend="pallas"`` the COPS kernel — all bit-identical, so join
  results never depend on the build backend;
- **probe** — the probe side keeps the paper's prefix-sum output layout
  (§IV-B.4) but, on the default backend, produces it with the fused
  bulk-retrieval engine (``repro.core.bulk_retrieve``): ONE probe walk
  emits the per-row match counts *and* the gathered build row indices
  (inner/left go through ``retrieve_all``, semi/anti through
  ``count_values`` — all four flavors ride the same engine; duplicate
  probe keys walk the table once).  ``backend="scan"`` keeps the
  two-walk count-then-gather reference.  ``out_capacity`` is static
  (jit shape) exactly like the paper's pre-sized output arrays.

All operators are pure pytree functions: jit them, vmap them, or fuse
them into larger computations.  Tombstoned (erased) build rows drop out
of every flavor automatically — erased keys never match and never stop
the probe walk.

The sharded variant (``shard_join`` / ``join_partitioned``) co-partitions
both sides by key ownership (``repro.distributed.sharding.
ownership_exchange`` — the same ``hash_owner`` rule the distributed
tables use), so every shard builds and probes only the keys it owns: one
writer per shard, no CAS, no cross-shard result merge.

**Composite multi-column keys.**  Real relational pipelines join on
tuples of columns; every operator here accepts its key batches as a
tuple of (n,) u32 columns (``hash_join((order_cust, order_day),
...)``) or an explicit (n, key_words) plane array, with ``key_words``
inferred from the input when not given (``core.hashing.pack_columns``
defines the packing: column 0 most significant, two columns == the
table-native u64 hi/lo planes).  Join OUTPUT is representation-
independent: within each probe row's segment, matches are emitted in
build-batch order regardless of the key packing or hash placement, so a
composite join is bit-exact against the same join run over
equivalently-packed single-word keys (the fig9 parity gate).  The
sharded variant hashes ALL key planes for ownership (``exchange.
owner_of`` folds the planes before ``hash_owner``), so co-partitioning
stays uniform for composite keys too.

Two-column (kw=2) joins dedup through the general lane's multi-plane
sort; on x64-enabled configs that sort runs the **packed-u64 lane**
(``bulk._sort_batch`` + ``compat.supports_u64_sort``): both key planes
fuse into one uint64 sort word, one comparator key fewer per element on
the build-dedup and probe-group sorts, bit-identical output either way
(``tests/test_packed_sort.py``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    register_struct,
)
from repro.relational.util import capacity_for, compact

_U = jnp.uint32
_I = jnp.int32

HOW = ("inner", "left", "semi", "anti")

#: build_idx sentinel for rows with no build-side match (left/semi/anti).
#: A plain int, NOT a jnp scalar: modules may be first imported inside a
#: jit trace (lazy imports in jitted pipeline code), where a module-level
#: jnp constant would be created as a tracer and leak across traces.
NO_MATCH = -1


@register_struct
@dataclasses.dataclass
class JoinResult:
    """Materialized join output (static ``out_capacity`` rows).

    - ``build_idx`` (out_capacity,) i32 — build-side row index per output
      row; ``NO_MATCH`` for unmatched left-outer rows and for semi/anti
      (which emit probe rows only).
    - ``probe_idx`` (out_capacity,) i32 — probe-side row index per output
      row.
    - ``valid`` (out_capacity,) bool — which output slots are live; rows
      past ``total`` are padding.
    - ``matched`` (n_probe,) bool — per *probe row*: had >= 1 build match.
    - ``total`` () i32 — number of live output rows (may exceed
      ``out_capacity``, in which case the overflowed tail was dropped —
      size via ``count_matches`` exactly like the paper's counting pass).
    """
    build_idx: jax.Array
    probe_idx: jax.Array
    valid: jax.Array
    matched: jax.Array
    total: jax.Array


def build(build_keys, *, capacity: int | None = None,
          key_words: int | None = None, window: int = DEFAULT_WINDOW,
          scheme: str = "cops", layout: str = "soa", seed: int = DEFAULT_SEED,
          max_probes: int | None = None, backend: str = "jax",
          load: float = 0.5, mask=None, row_ids=None,
          ) -> tuple[mv.MultiValueHashTable, jax.Array]:
    """Build phase: key -> build row index in a MultiValueHashTable.

    ``build_keys`` may be a tuple of u32 columns (composite key), a
    (n, key_words) plane array, or a flat (n,) batch; ``key_words`` is
    inferred when omitted.  ``row_ids`` overrides the stored row indices
    (the sharded join stores *global* row ids).  Returns
    (table, insert_status).
    """
    keys, key_words = sv.normalize_keys(build_keys, key_words, "build_keys")
    n = keys.shape[0]
    if capacity is None:
        capacity = capacity_for(n, load, window)
    table = mv.create(capacity, key_words=key_words, value_words=1,
                      window=window, scheme=scheme, layout=layout, seed=seed,
                      max_probes=max_probes, backend=backend)
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=_U)
    return mv.insert(table, keys, row_ids.astype(_U), mask=mask)


def count_matches(table: mv.MultiValueHashTable, probe_keys, how: str = "inner",
                  mask=None) -> jax.Array:
    """Output rows the probe side will emit — the paper's counting pass.

    Sum this (host-side or via a first jitted call) to size
    ``out_capacity`` for ``probe``.
    """
    keys = sv.normalize_key_batch(probe_keys, table.key_words, "probe_keys")
    counts = mv.count_values(table, keys, mask=mask)
    live = jnp.ones(counts.shape, bool) if mask is None else mask
    if how == "inner":
        return counts
    if how == "left":
        return jnp.where(live, jnp.maximum(counts, 1), 0)
    if how == "semi":
        return ((counts > 0) & live).astype(_I)
    if how == "anti":
        return ((counts == 0) & live).astype(_I)
    raise ValueError(f"how={how!r} not in {HOW}")


def _segment_of(offsets: jax.Array, out_capacity: int) -> jax.Array:
    """Probe row owning each output slot: row i owns [offsets[i], offsets[i+1])."""
    return jnp.searchsorted(offsets[1:], jnp.arange(out_capacity, dtype=_I),
                            side="right").astype(_I)


def probe(table: mv.MultiValueHashTable, probe_keys, out_capacity: int,
          how: str = "inner", mask=None) -> JoinResult:
    """Probe phase: emit (build_idx, probe_idx) pairs per ``how`` flavor.

    ``out_capacity`` is static; size it with ``count_matches`` (or an upper
    bound such as n_probe * max_multiplicity).  ``mask`` drops probe rows
    entirely (they match nothing and emit nothing, in every flavor).
    """
    if how not in HOW:
        raise ValueError(f"how={how!r} not in {HOW}")
    keys = sv.normalize_key_batch(probe_keys, table.key_words, "probe_keys")
    n = keys.shape[0]
    live = jnp.ones((n,), bool) if mask is None else mask

    if how in ("semi", "anti"):
        counts = mv.count_values(table, keys, mask=mask)
        matched = (counts > 0) & live
        sel = matched if how == "semi" else ((counts == 0) & live)
        probe_idx, total = compact(jnp.arange(n, dtype=_I), sel,
                                   out_capacity, fill=NO_MATCH)
        valid = jnp.arange(out_capacity, dtype=_I) < jnp.minimum(
            total, out_capacity)
        build_idx = jnp.full((out_capacity,), NO_MATCH, _I)
        return JoinResult(build_idx=build_idx, probe_idx=probe_idx,
                          valid=valid, matched=matched, total=total)

    # inner / left: gather matching build row ids in counting-pass layout
    vals, offsets, counts = mv.retrieve_all(table, keys, out_capacity,
                                            mask=mask)
    matched = (counts > 0) & live
    if how == "inner":
        total = offsets[n]
        seg = _segment_of(offsets, out_capacity)
        valid = jnp.arange(out_capacity, dtype=_I) < jnp.minimum(
            total, out_capacity)
        build_idx = jnp.where(valid, vals.astype(_I), NO_MATCH)
        probe_idx = jnp.where(valid, seg, NO_MATCH)
        return JoinResult(build_idx=build_idx, probe_idx=probe_idx,
                          valid=valid, matched=matched, total=total)

    # left outer: unmatched live probe rows emit one NO_MATCH row
    counts_lo = jnp.where(live, jnp.maximum(counts, 1), 0)
    offs_lo = jnp.concatenate([jnp.zeros((1,), _I), jnp.cumsum(counts_lo)])
    total = offs_lo[n]
    seg = _segment_of(offs_lo, out_capacity)
    rank = jnp.arange(out_capacity, dtype=_I) - offs_lo[seg]
    has_match = matched[seg] if n else jnp.zeros((out_capacity,), bool)
    inner_pos = (offsets[seg] if n else jnp.zeros((out_capacity,), _I)) + rank
    gathered = vals[jnp.clip(inner_pos, 0, max(out_capacity - 1, 0))].astype(_I)
    valid = jnp.arange(out_capacity, dtype=_I) < jnp.minimum(total,
                                                             out_capacity)
    build_idx = jnp.where(valid & has_match, gathered, NO_MATCH)
    probe_idx = jnp.where(valid, seg, NO_MATCH)
    return JoinResult(build_idx=build_idx, probe_idx=probe_idx, valid=valid,
                      matched=matched, total=total)


def hash_join(build_keys, probe_keys, out_capacity: int, how: str = "inner",
              *, key_words: int | None = None, window: int = DEFAULT_WINDOW,
              scheme: str = "cops", backend: str = "jax", load: float = 0.5,
              capacity: int | None = None, build_mask=None, probe_mask=None,
              ) -> JoinResult:
    """One-shot build + probe.  Pure and jittable (out_capacity/how static).

    Composite keys: pass tuples of u32 columns for both sides
    (``key_words`` inferred), e.g. ``hash_join((b_hi, b_lo),
    (p_hi, p_lo), cap, "inner")`` for a two-column equi-join.
    """
    table, _ = build(build_keys, capacity=capacity, key_words=key_words,
                     window=window, scheme=scheme, backend=backend, load=load,
                     mask=build_mask)
    return probe(table, probe_keys, out_capacity, how=how, mask=probe_mask)


def gather_payload(result: JoinResult, build_values=None, probe_values=None,
                   fill=0):
    """Materialize joined payload columns from a JoinResult.

    Returns (build_cols, probe_cols) — each ``None`` if the corresponding
    values were not given; NO_MATCH / padding rows get ``fill``.
    """
    def take(values, idx):
        values = jnp.asarray(values)
        ok = (idx >= 0) & result.valid
        got = values[jnp.clip(idx, 0, values.shape[0] - 1)]
        return jnp.where(ok.reshape((-1,) + (1,) * (got.ndim - 1)), got, fill)

    bcols = None if build_values is None else take(build_values,
                                                   result.build_idx)
    pcols = None if probe_values is None else take(probe_values,
                                                   result.probe_idx)
    return bcols, pcols


# ---------------------------------------------------------------------------
# sharded join: ownership co-partitioning, one writer per shard
# ---------------------------------------------------------------------------

def join_partitioned(build_keys, probe_keys, axis: str, out_capacity: int,
                     how: str = "inner", *, key_words: int | None = None,
                     window: int = DEFAULT_WINDOW, backend: str = "jax",
                     load: float = 0.5, slack: float = 2.0):
    """Per-shard body of the sharded hash join (call inside shard_map).

    Both sides are routed to key owners via
    ``repro.distributed.sharding.ownership_exchange``; each shard builds a
    local table over the build keys it owns and probes it with the probe
    keys it owns.  Emitted indices are *global* row ids.  Returns
    ``(result, overflow)`` where ``result.matched`` is realigned with this
    shard's original probe slice and ``overflow`` counts exchange drops
    (size ``slack`` so it is zero, as with the distributed tables).
    """
    from repro.distributed import sharding as shd
    idx = jax.lax.axis_index(axis)
    bk, key_words = sv.normalize_keys(build_keys, key_words, "build_keys")
    pk = sv.normalize_key_batch(probe_keys, key_words, "probe_keys")
    n_b, n_p = bk.shape[0], pk.shape[0]
    bgid = (idx * n_b + jnp.arange(n_b)).astype(_U)
    pgid = (idx * n_p + jnp.arange(n_p)).astype(_I)

    rbk, rbid, rbm, bplan = shd.ownership_exchange(
        bk, bgid, axis, key_words=key_words, slack=slack)
    capacity = capacity_for(rbk.shape[0], load, window)
    table, _ = build(rbk, capacity=capacity, key_words=key_words,
                     window=window, backend=backend, mask=rbm, row_ids=rbid)

    rpk, rpid, rpm, pplan = shd.ownership_exchange(
        pk, pgid, axis, key_words=key_words, slack=slack)
    res = probe(table, rpk, out_capacity, how=how, mask=rpm)
    # local recv-slot probe indices -> global probe row ids
    ok = res.probe_idx >= 0
    pglob = rpid[jnp.clip(res.probe_idx, 0, rpid.shape[0] - 1)]
    probe_idx = jnp.where(ok, pglob, NO_MATCH)
    # matched travels the reverse exchange back to the sending shard
    matched = shd.ownership_return(pplan, res.matched, axis, fill=False)
    res = dataclasses.replace(res, probe_idx=probe_idx, matched=matched)
    return res, bplan.overflow + pplan.overflow


def shard_join(mesh: Mesh, axis: str, build_keys, probe_keys,
               out_capacity_per_shard: int, how: str = "inner", *,
               key_words: int | None = None, window: int = DEFAULT_WINDOW,
               backend: str = "jax", load: float = 0.5, slack: float = 2.0):
    """Host-level sharded hash join over mesh ``axis``.

    ``build_keys`` / ``probe_keys`` are sharded over ``axis`` (leading dim
    divisible by the axis size); composite tuples-of-columns and
    (n, key_words) plane arrays are accepted like ``hash_join`` (ownership
    hashing folds every key plane).  Returns a dict with the concatenated
    per-shard outputs:

    - ``build_idx`` / ``probe_idx`` / ``valid``: (P * out_capacity_per_shard,)
      global-row-id join pairs (order is per-owner-shard, not input order);
    - ``matched``: (n_probe,) aligned with the input probe batch;
    - ``total``: (P,) live rows per shard;
    - ``overflow``: (P,) exchange drops (zero when slack suffices).
    """
    from repro.distributed.sharding import shard_map_compat

    # normalize composite spellings host-side: shard_map sees plain
    # (n, key_words) plane arrays, sharded over dim 0
    bk_n, key_words = sv.normalize_keys(build_keys, key_words, "build_keys")
    pk_n = sv.normalize_key_batch(probe_keys, key_words, "probe_keys")

    def body(bk, pk):
        res, ov = join_partitioned(
            bk, pk, axis, out_capacity_per_shard, how, key_words=key_words,
            window=window, backend=backend, load=load, slack=slack)
        return (res.build_idx, res.probe_idx, res.valid, res.matched,
                res.total[None], ov[None])

    f = shard_map_compat(body, mesh, in_specs=(P(axis), P(axis)),
                         out_specs=(P(axis),) * 6)
    build_idx, probe_idx, valid, matched, total, overflow = f(bk_n, pk_n)
    return {"build_idx": build_idx, "probe_idx": probe_idx, "valid": valid,
            "matched": matched, "total": total, "overflow": overflow}
