"""Shared sizing/packing helpers for the relational operators.

``pack_columns`` / ``unpack_columns`` (re-exported from
``repro.core.hashing``) define the composite multi-column key encoding
every relational operator accepts: N u32 columns -> (n, N) key planes,
column 0 most significant, two columns == the table-native u64 hi/lo
planes.  ``compact`` works unchanged on (n, key_words) plane arrays —
a composite key row is selected or dropped as one unit.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.common import DEFAULT_WINDOW
from repro.core.hashing import (  # noqa: F401  (re-exports — public API)
    pack_columns,
    unpack_columns,
)

_I = jnp.int32


def capacity_for(num_keys: int, load: float = 0.5,
                 window: int = DEFAULT_WINDOW) -> int:
    """min_capacity sizing: ``num_keys`` distinct entries at target load."""
    return max(int(math.ceil(max(num_keys, 1) / load)), window)


def compact(values, sel, out_capacity: int, fill=0,
            ) -> tuple[jax.Array, jax.Array]:
    """Pack ``values[sel]`` into a static-size output (prefix-sum layout).

    Returns (packed, n_selected); slots past ``n_selected`` hold ``fill``,
    selections past ``out_capacity`` are dropped.
    """
    values = jnp.asarray(values)
    pos = jnp.cumsum(sel.astype(_I)) - 1
    slot = jnp.where(sel & (pos < out_capacity), pos, out_capacity)
    out_shape = (out_capacity,) + values.shape[1:]
    out = jnp.full(out_shape, fill, values.dtype).at[slot].set(values,
                                                               mode="drop")
    return out, jnp.sum(sel, dtype=_I)
