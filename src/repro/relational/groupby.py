"""Group-by aggregation on WarpCore tables (sum / min / max / count / mean).

A group-by is a CountingHashTable generalized to carry an aggregation
operand: every group key owns one slot of a ``SingleValueHashTable`` with
two value words — plane 0 the aggregate accumulator, plane 1 the group
cardinality — and each input batch performs a read-modify-write upsert
via ``single_value.update_values`` (absent key -> seed the accumulator,
present key -> fold the new operand in).  Every fold ships an associative
``combine`` so the vectorized bulk engine (repro.core.bulk) pre-merges
duplicate keys and applies one RMW per distinct group — batch-level
conflict resolution instead of the CUDA atomics a GPU group-by would use
(DESIGN.md §2).  On the ``"pallas"`` backend the fused COPS RMW tile
(repro.kernels.cops) folds in-VMEM instead of falling back to the scan.

All operators are pure pytree functions; ``aggregate`` is the one-shot
jittable entry point.  ``mean`` finalizes as float32 accumulator/count;
``sum`` wraps mod 2^32 like the u32 arithmetic it is built on.

Group keys may be composite: pass a tuple of u32 columns (``aggregate(
(region, year), amounts, ...)``) or an (n, key_words) plane array —
``key_words`` is inferred by ``aggregate`` and ``single_value.
normalize_key_batch`` accepts the same spellings on ``update``/``lookup``.
``finalize`` returns multi-word group keys as (capacity, key_words)
planes; ``core.hashing.unpack_columns`` turns them back into columns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import single_value as sv
from repro.relational.util import capacity_for  # re-export (public API)
from repro.core.common import (
    DEFAULT_SEED,
    DEFAULT_WINDOW,
    EMPTY_KEY,
    TOMBSTONE_KEY,
)

_U = jnp.uint32
_I = jnp.int32

AGGS = ("sum", "min", "max", "count", "mean")

GroupByTable = sv.SingleValueHashTable


def create(min_capacity: int, *, key_words: int = 1,
           window: int = DEFAULT_WINDOW, scheme: str = "cops",
           layout: str = "soa", seed: int = DEFAULT_SEED,
           max_probes: int | None = None, backend: str = "jax",
           ) -> GroupByTable:
    """An empty group-by table: value plane 0 = accumulator, plane 1 = count."""
    return sv.create(min_capacity, key_words=key_words, value_words=2,
                     window=window, scheme=scheme, layout=layout, seed=seed,
                     max_probes=max_probes, backend=backend)


def _fold_fn(agg: str):
    """(old, key, new) -> new slot value; new = (operand, weight) planes."""
    if agg in ("sum", "mean", "count"):
        return lambda old, key, new: old + new
    if agg == "min":
        return lambda old, key, new: jnp.stack([jnp.minimum(old[0], new[0]),
                                                old[1] + new[1]])
    if agg == "max":
        return lambda old, key, new: jnp.stack([jnp.maximum(old[0], new[0]),
                                                old[1] + new[1]])
    raise ValueError(f"agg={agg!r} not in {AGGS}")


def _combine_fn(agg: str):
    """Associative pre-merge of operand pairs — the bulk engine's segment
    combiner, as a per-value-word spec (plane 0 = aggregate, plane 1 =
    weight), so duplicate group keys fold via scatter-reduce before any
    table RMW."""
    if agg in ("sum", "mean", "count"):
        return ("add", "add")
    if agg == "min":
        return ("min", "add")
    if agg == "max":
        return ("max", "add")
    raise ValueError(f"agg={agg!r} not in {AGGS}")


def update(table: GroupByTable, agg: str, keys, values=None, mask=None,
           ) -> tuple[GroupByTable, jax.Array]:
    """Fold a batch of (key, value) elements into the running aggregate.

    ``values`` may be omitted for ``count``.  Returns (table, status) with
    the usual STATUS_* codes per element.  Backend routing: ``"pallas"``
    runs the fused COPS RMW tile when the table qualifies; otherwise the
    associative combiner sends the fold down the vectorized bulk path
    (``backend="scan"`` keeps the sequential reference).
    """
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if values is None:
        if agg != "count":
            raise ValueError(f"agg={agg!r} needs a values operand")
        values = jnp.zeros((n,), _U)
    v = sv.normalize_words(values, 1, "values")[:, 0]
    ones = jnp.ones((n,), _U)
    payload = jnp.stack([ones if agg == "count" else v, ones], axis=1)
    if table.backend == "pallas":
        from repro.kernels.cops import ops as cops_ops
        return cops_ops.update_groupby(table, agg, keys, payload, mask)
    return sv.update_values(table, keys, _fold_fn(agg), payload, mask=mask,
                            combine=_combine_fn(agg))


def lookup(table: GroupByTable, agg: str, keys) -> tuple[jax.Array, jax.Array]:
    """Per-key aggregate -> (values, found).  ``mean`` returns float32.

    Rides ``single_value.retrieve``'s backend dispatch: the default path
    is the fused bulk-retrieval engine (duplicate lookup keys probe the
    table once), ``backend="scan"`` the direct reference walk.
    """
    vals, found = sv.retrieve(table, keys)
    return _finalize_planes(agg, vals[:, 0], vals[:, 1], found), found


def _finalize_planes(agg: str, acc, cnt, live):
    if agg == "mean":
        out = acc.astype(jnp.float32) / jnp.maximum(cnt, 1).astype(jnp.float32)
        return jnp.where(live, out, 0.0)
    out = cnt if agg == "count" else acc
    return jnp.where(live, out, _U(0))


def finalize(table: GroupByTable, agg: str,
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dump every live group -> (keys, aggregates, live_mask).

    Arrays span the table's full capacity; ``live_mask`` marks real groups
    (``int(table.count)`` of them).  Keys come back as (capacity,) for
    1-word keys, else (capacity, key_words) planes — use
    ``core.hashing.unpack_columns`` to recover composite key columns.
    """
    kp = table.key_planes().reshape(table.key_words, -1)        # (kw, c)
    vp = table.value_planes().reshape(2, -1)                    # (2, c)
    live = (kp[0] != EMPTY_KEY) & (kp[0] != TOMBSTONE_KEY)
    out = _finalize_planes(agg, vp[0], vp[1], live)
    keys = kp[0] if table.key_words == 1 else kp.T
    keys = jnp.where(live if table.key_words == 1 else live[:, None],
                     keys, _U(0))
    return keys, out, live


def aggregate(keys, values, min_capacity: int, agg: str, *,
              key_words: int | None = None, window: int = DEFAULT_WINDOW,
              backend: str = "jax", mask=None,
              ) -> tuple[jax.Array, jax.Array, jax.Array, GroupByTable]:
    """One-shot group-by: returns (group_keys, aggregates, live, table).

    ``keys`` may be a tuple of u32 columns (composite group key), an
    (n, key_words) plane array, or a flat (n,) batch; ``key_words`` is
    inferred when omitted.
    """
    keys, key_words = sv.normalize_keys(keys, key_words, "keys")
    table = create(min_capacity, key_words=key_words, window=window,
                   backend=backend)
    table, _ = update(table, agg, keys, values, mask=mask)
    gk, out, live = finalize(table, agg)
    return gk, out, live, table
