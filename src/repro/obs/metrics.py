"""In-graph table telemetry: the ``TableStats`` pytree.

Every engine entry point (``single_value``/``multi_value``/``counting``/
``bucket_list`` insert and retrieval, and the bulk engines underneath)
accepts ``stats: bool = False``.  The flag is **static**: when False the
traced graph is exactly the pre-telemetry graph (byte-identical HLO,
census-asserted by ``tests/test_obs.py``); when True the walk loops carry
a few extra i32 vectors and the op returns a ``TableStats`` alongside its
usual results — all accumulated inside the compiled graph, no host
round-trips.

Conventions
-----------

- **probe length** = probe *windows examined* by an element's walk: a key
  found in its first window has probe length 1; a claimer placed on its
  k-th row has probe length k; FULL elements report ``max_probes``.  Only
  elements that actually walk (representatives after dedup, live claimers)
  contribute — masked and duplicate elements count 0 and are excluded.
- **probe histogram** bins are fixed powers of two: bin i counts lengths
  in ``(2^(i-1), 2^i]`` (bin 0 = length 1), the last bin is open-ended.
  ``probe_sum``/``probe_n`` carry the exact first moment so the roofline
  bytes model can use the true mean rather than a bin midpoint.
- **status histogram** is indexed by the STATUS_* codes (INSERTED=0,
  UPDATED=1, FULL=2, MASKED=3, POOL_FULL=4).  Pure retrieval ops have no
  statuses and leave it zero.
- **fixpoint_iters** counts virtual-fill arbitration sweeps
  (``bulk.place_claims``) — 0 for ops that never place.
- **live/tombstone slots + load factor** are a census of key plane 0 of
  the post-op store: exactly the signals a growth/compaction policy
  triggers on (ROADMAP).

Backends: ``backend="jax"`` threads the counters through the engine loops
themselves.  The scan/pallas backends run their op *unchanged* (outputs
stay bit-exact with ``stats=False`` — the parity suite asserts it) and
derive probe lengths from a measurement walk against the post-op table,
traced into the same graph (``measure_probe_lengths``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.common import (
    EMPTY_KEY,
    TOMBSTONE_KEY,
    register_struct,
)

_U = jnp.uint32
_I = jnp.int32
_F = jnp.float32

NUM_STATUS = 5                       # INSERTED/UPDATED/FULL/MASKED/POOL_FULL
NUM_PROBE_BINS = 16                  # bin i <=> probe length in (2^(i-1), 2^i]
_EDGES = (2 ** np.arange(NUM_PROBE_BINS)).astype(np.int32)   # 1,2,4,...,2^15


@register_struct
@dataclasses.dataclass
class TableStats:
    """Per-op telemetry accumulated inside the compiled graph."""
    status_hist: jax.Array           # (NUM_STATUS,) i32
    probe_hist: jax.Array            # (NUM_PROBE_BINS,) i32
    probe_sum: jax.Array             # i32 — sum of probe lengths
    probe_n: jax.Array               # i32 — number of walking elements
    fixpoint_iters: jax.Array        # i32 — arbitration sweeps
    live_slots: jax.Array            # i32
    tombstone_slots: jax.Array       # i32
    load_factor: jax.Array           # f32 — live / capacity

    # -- host-side readers ---------------------------------------------------
    def mean_probe_len(self) -> float:
        n = int(self.probe_n)
        return float(self.probe_sum) / n if n else 0.0

    def probe_quantile(self, q: float) -> float:
        """Approximate quantile from the histogram (upper bin edge)."""
        hist = np.asarray(self.probe_hist)
        total = int(hist.sum())
        if total == 0:
            return 0.0
        cum = np.cumsum(hist)
        i = int(np.searchsorted(cum, q * total, side="left"))
        return float(_EDGES[min(i, NUM_PROBE_BINS - 1)])

    def as_dict(self) -> dict:
        """Plain-python rendering (for JSON rows / report tables)."""
        return {
            "status_hist": [int(x) for x in np.asarray(self.status_hist)],
            "probe_len_mean": self.mean_probe_len(),
            "probe_len_p50": self.probe_quantile(0.50),
            "probe_len_p99": self.probe_quantile(0.99),
            "fixpoint_iters": int(self.fixpoint_iters),
            "live_slots": int(self.live_slots),
            "tombstone_slots": int(self.tombstone_slots),
            "load_factor": float(self.load_factor),
        }


def empty() -> TableStats:
    z = jnp.zeros((), _I)
    return TableStats(
        status_hist=jnp.zeros((NUM_STATUS,), _I),
        probe_hist=jnp.zeros((NUM_PROBE_BINS,), _I),
        probe_sum=z, probe_n=z, fixpoint_iters=z,
        live_slots=z, tombstone_slots=z, load_factor=jnp.zeros((), _F))


@register_struct
@dataclasses.dataclass
class StreamCounters:
    """Streaming-ingestion telemetry carried *inside* a ``lax.scan``.

    The streaming engine (``repro.data.stream``) threads one of these
    through its scan carry, so a whole stream's counters accumulate in
    the compiled graph — zero host round-trips mid-stream, read once at
    the end.  All fields are scalar i32 (same dtype under x64, so the
    carry is stable across the packed-sort lane toggle).
    """
    chunks: jax.Array            # chunks processed
    kept: jax.Array              # sequences surviving dedup
    hits: jax.Array              # watchlist join hits (aggregated)
    erased: jax.Array            # fingerprints forgotten (ring expiry)
    compactions: jax.Array       # in-graph compactions fired
    live_slots: jax.Array        # dedup-table census after last chunk
    tombstone_slots: jax.Array   # ditto

    def as_dict(self) -> dict:
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}


def stream_counters_empty() -> StreamCounters:
    # one zeros() call PER field: the stream carry is donated, and two
    # pytree leaves sharing one buffer make donation reject the call
    # ("attempt to donate the same buffer twice")
    z = lambda: jnp.zeros((), _I)
    return StreamCounters(chunks=z(), kept=z(), hits=z(), erased=z(),
                          compactions=z(), live_slots=z(),
                          tombstone_slots=z())


def status_hist(status: jax.Array) -> jax.Array:
    """(n,) STATUS_* codes -> (NUM_STATUS,) counts."""
    idx = jnp.clip(status.astype(_I), 0, NUM_STATUS - 1)
    return jnp.zeros((NUM_STATUS,), _I).at[idx].add(1)


def probe_hist(plen: jax.Array, active: jax.Array):
    """Bin probe lengths of ``active`` elements into the power-of-two
    histogram.  Returns (hist, probe_sum, probe_n)."""
    plen = plen.astype(_I)
    counted = active & (plen > 0)
    edges = jnp.asarray(_EDGES, _I)
    # bin = first i with plen <= 2^i  (length 1 -> bin 0)
    b = jnp.searchsorted(edges, plen, side="left").astype(_I)
    b = jnp.where(counted, jnp.clip(b, 0, NUM_PROBE_BINS - 1), NUM_PROBE_BINS)
    hist = jnp.zeros((NUM_PROBE_BINS,), _I).at[b].add(1, mode="drop")
    return (hist, jnp.sum(jnp.where(counted, plen, 0), dtype=_I),
            jnp.sum(counted, dtype=_I))


def slot_stats(ops, store):
    """Census of key plane 0: (live, tombstones, load_factor)."""
    kp0 = ops.key_planes(store)[0]
    live = jnp.sum((kp0 != EMPTY_KEY) & (kp0 != TOMBSTONE_KEY), dtype=_I)
    tomb = jnp.sum(kp0 == TOMBSTONE_KEY, dtype=_I)
    lf = live.astype(_F) / _F(max(ops.num_rows * ops.window, 1))
    return live, tomb, lf


def table_stats(ops, store, *, status=None, plen=None, active=None,
                fixpoint_iters=None) -> TableStats:
    """Assemble a ``TableStats`` from whatever an op measured.

    ``store`` is the *post-op* store (slot census); any of the walk-level
    inputs may be omitted (pure retrieval has no statuses, scan backends
    have no fixpoint)."""
    st = empty()
    live, tomb, lf = slot_stats(ops, store)
    sh = st.status_hist if status is None else status_hist(status)
    if plen is not None:
        act = jnp.ones(plen.shape, bool) if active is None else active
        ph, ps, pn = probe_hist(plen, act)
    else:
        ph, ps, pn = st.probe_hist, st.probe_sum, st.probe_n
    fx = st.fixpoint_iters if fixpoint_iters is None else \
        jnp.asarray(fixpoint_iters, _I)
    return TableStats(status_hist=sh, probe_hist=ph, probe_sum=ps,
                      probe_n=pn, fixpoint_iters=fx, live_slots=live,
                      tombstone_slots=tomb, load_factor=lf)


def merge(a: TableStats, b: TableStats) -> TableStats:
    """Accumulate two ops' stats (slot census / load factor taken from b,
    the later op)."""
    return TableStats(
        status_hist=a.status_hist + b.status_hist,
        probe_hist=a.probe_hist + b.probe_hist,
        probe_sum=a.probe_sum + b.probe_sum,
        probe_n=a.probe_n + b.probe_n,
        fixpoint_iters=a.fixpoint_iters + b.fixpoint_iters,
        live_slots=b.live_slots, tombstone_slots=b.tombstone_slots,
        load_factor=b.load_factor)


def measure_probe_lengths(tstatic, store, keys, active,
                          words=None) -> jax.Array:
    """Bolt-on probe-length measurement: one stats-enabled match walk
    against ``store`` (windows examined to hit the key or its EMPTY
    frontier).  Used by the scan/pallas backends, whose op itself is kept
    untouched — the measurement is an extra read-only walk traced into
    the same graph.  ``words`` overrides the probe words (quotient tables
    probe by the full hash, not the raw key word)."""
    from repro.core import bulk
    from repro.core import single_value as sv
    if words is None:
        words = sv.key_hash_word(keys)
    _, _, _, plen = bulk.probe_matches(tstatic, store, keys, words, active,
                                       stats=True)
    return plen


def bolt_on_stats(table, keys, status=None, mask=None) -> TableStats:
    """TableStats for an op that ran *unchanged* (scan/pallas backends).

    Dedups the batch like the bulk engines (one walking representative
    per distinct live key) and measures probe lengths with a read-only
    walk against the post-op store; status histogram and slot census come
    from the op's own outputs/state.  Traced into the caller's graph."""
    from repro.core import bulk_retrieve
    from repro.core import single_value as sv
    keys = sv.normalize_key_batch(keys, table.key_words, "keys")
    n = keys.shape[0]
    if n == 0:
        return table_stats(table.ops, table.store, status=status)
    live = jnp.ones((n,), bool) if mask is None else mask
    is_rep, _ = bulk_retrieve.group_queries(keys, live)
    from repro.core import probing
    tstatic = (table.ops, table.scheme, table.seed,
               probing.effective_probes(table.scheme, table.max_probes,
                                        table.num_rows))
    plen = measure_probe_lengths(tstatic, table.store, keys, is_rep,
                                 words=sv.probe_words(table, keys))
    return table_stats(table.ops, table.store, status=status, plen=plen,
                       active=is_rep)
