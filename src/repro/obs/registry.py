"""Host-side metrics registry: named counters, gauges, histograms.

The process-wide ``REGISTRY`` is the rendezvous between instrumented
library code (``serving.kv_cache`` FULL-status / eviction counts, the
serve-loop and pipeline latency spans) and whoever reads the signals (the
examples' metrics printout today; the ROADMAP auto-growth policy hook
tomorrow).

Everything here is **tracer-safe**: recording a value that is still a jax
tracer (the instrumented call ran under ``jit``) is a silent no-op rather
than an error, so instrumentation never constrains how callers compile.
Callers that want exact counts under jit return them from the graph and
record the concrete values afterwards.
"""

from __future__ import annotations

import dataclasses
import math
import threading

import numpy as np


def _concrete(value):
    """float(value) if it is a host-side number, else None (jax tracer)."""
    try:
        import jax
        if isinstance(value, jax.core.Tracer):
            return None
        if isinstance(value, jax.Array) and not value.is_fully_replicated:
            return None
    except Exception:
        pass
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


@dataclasses.dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, amount=1) -> None:
        v = _concrete(amount)
        if v is not None:
            self.value += v


@dataclasses.dataclass
class Gauge:
    name: str
    value: float = float("nan")

    def set(self, value) -> None:
        v = _concrete(value)
        if v is not None:
            self.value = v


class Histogram:
    """Reservoir-free latency histogram: keeps every sample (these are
    per-span wall times, thousands at most) and answers percentiles
    exactly."""

    def __init__(self, name: str):
        self.name = name
        self.samples: list[float] = []

    def record(self, value) -> None:
        v = _concrete(value)
        if v is not None and math.isfinite(v):
            self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    def percentile(self, q: float) -> float:
        """q in [0, 100]; nan when empty."""
        if not self.samples:
            return float("nan")
        return float(np.percentile(np.asarray(self.samples), q))

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0}
        return {"count": self.count, "sum_s": self.sum,
                "p50_s": self.percentile(50), "p95_s": self.percentile(95),
                "p99_s": self.percentile(99)}


class Registry:
    """Named metric store.  ``counter``/``gauge``/``histogram`` create on
    first use and return the same object afterwards (a name is bound to
    one kind; rebinding raises)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, "
                    f"not a {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """{name: value | histogram summary} for every registered metric."""
        out = {}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if isinstance(m, (Counter, Gauge)):
                out[name] = m.value
            else:
                out[name] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def render(self) -> str:
        """Human-readable one-metric-per-line dump (examples' printout)."""
        lines = []
        for name in sorted(self.snapshot()):
            v = self.snapshot()[name]
            if isinstance(v, dict):
                if not v.get("count"):
                    lines.append(f"{name}: (no samples)")
                else:
                    lines.append(
                        f"{name}: n={v['count']} p50={v['p50_s'] * 1e3:.3f}ms"
                        f" p95={v['p95_s'] * 1e3:.3f}ms"
                        f" p99={v['p99_s'] * 1e3:.3f}ms")
            else:
                lines.append(f"{name}: {v:g}")
        return "\n".join(lines)


#: process-wide default registry (library instrumentation records here)
REGISTRY = Registry()
