"""Observability: in-graph table telemetry, host-side metrics, tracing.

Three layers (ISSUE 6 / ROADMAP "sensor layer"):

- ``obs.metrics`` — the jit-compatible ``TableStats`` pytree accumulated
  *inside* the compiled graph by the bulk engines when an entry point is
  called with ``stats=True`` (status histogram, power-of-two probe-length
  histogram, fixpoint iteration count, live/tombstone slot census, load
  factor).  ``stats=False`` (the default) is a static python flag: the
  traced graph is unchanged and compiles to byte-identical HLO — the
  invariant ``tests/test_obs.py`` census-asserts.
- ``obs.registry`` — named host-side counters/gauges/histograms (the
  process-wide ``REGISTRY``), tracer-safe: recording a jax tracer is a
  silent no-op, so instrumented library code stays jittable.
- ``obs.trace`` — a span tracer (``perf_counter`` wall times, p50/p95/p99
  latency histograms per span name) with optional JSONL event emission in
  the schema ``launch.report`` renders.
"""

from repro.obs import metrics, registry, trace
from repro.obs.metrics import TableStats
from repro.obs.registry import REGISTRY
from repro.obs.trace import Tracer

__all__ = ["metrics", "registry", "trace", "TableStats", "REGISTRY",
           "Tracer"]
