"""Span tracer: wall-time spans, latency histograms, JSONL events.

A ``Tracer`` times named spans with ``time.perf_counter`` and feeds each
duration into a per-span-name latency ``Histogram`` in a ``Registry``
(p50/p95/p99 readable at any time), optionally appending one JSONL event
per span/event to a file.

The JSONL **event schema** is shared with ``launch.report`` (which renders
trace files next to the dry-run tables)::

    {"event": str,          # span or event name
     "t_s": float,          # start time, perf_counter seconds
     "dur_s": float,        # span duration (0.0 for point events)
     ...fields}             # caller-supplied scalar fields

``EVENT_FIELDS`` lists the required keys; ``is_event``/``validate_event``
are the shared predicates report-side code uses to recognize them.
"""

from __future__ import annotations

import contextlib
import json
import time

from repro.obs import registry as _registry

#: required keys of one trace JSONL record (shared with launch.report)
EVENT_FIELDS = ("event", "t_s", "dur_s")


def is_event(record: dict) -> bool:
    return all(k in record for k in EVENT_FIELDS)


def validate_event(record: dict) -> None:
    for k in EVENT_FIELDS:
        if k not in record:
            raise ValueError(f"trace event missing {k!r}: {record}")
    if not isinstance(record["event"], str):
        raise ValueError(f"trace event name must be a string: {record}")
    for k in ("t_s", "dur_s"):
        float(record[k])


class Tracer:
    """Times spans; ``span(name)`` is a context manager.

    Durations land in ``registry.histogram(f"{name}.latency_s")`` (the
    process ``REGISTRY`` by default) so p50/p95/p99 are free; with
    ``jsonl_path`` every span/event also appends one schema-conforming
    JSONL line.  A disabled tracer (``enabled=False``) is free: span() is
    a no-op context."""

    def __init__(self, registry: _registry.Registry | None = None,
                 jsonl_path: str | None = None, enabled: bool = True):
        self.registry = registry if registry is not None \
            else _registry.REGISTRY
        self.jsonl_path = jsonl_path
        self.enabled = enabled
        self._sink = None

    def _emit(self, record: dict) -> None:
        if not self.jsonl_path:
            return
        if self._sink is None:
            self._sink = open(self.jsonl_path, "a")
        self._sink.write(json.dumps(record) + "\n")
        self._sink.flush()

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        """Time a block; record latency + optional JSONL event."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dur = time.perf_counter() - t0
            self.registry.histogram(f"{name}.latency_s").record(dur)
            self._emit({"event": name, "t_s": t0, "dur_s": dur, **fields})

    def event(self, name: str, **fields) -> None:
        """Point event (no duration)."""
        if not self.enabled:
            return
        self._emit({"event": name, "t_s": time.perf_counter(),
                    "dur_s": 0.0, **fields})

    def percentiles(self, name: str) -> dict:
        """{p50_s, p95_s, p99_s, count} for one span name."""
        return self.registry.histogram(f"{name}.latency_s").summary()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None


def load_events(path: str) -> list[dict]:
    """Read a trace JSONL file, validating each record against the schema."""
    out = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            validate_event(rec)
            out.append(rec)
    return out
