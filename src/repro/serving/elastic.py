"""Elastic sharded serving: the table as a service.

The paper's weak-scaling story (fig6) puts the interesting regime for a
heavily-trafficked hash table in the distributed layout: every key owned
by exactly one shard, batches routed by owner, one writer per key.  This
module is that layout as a *long-lived service*: a :class:`ShardedTable`
of P same-geometry single-value shards that

- **routes** inserts/lookups/erases through the same multisplit ->
  padded-buffer plan the mesh path uses (``core.exchange``), here over
  *simulated* shards in one process — the data movement and ownership
  math are identical to the shard_map path in ``core.distributed``, so
  properties proven here transfer;
- **filters** cross-shard lookups through per-shard blocked bloom
  filters (``core.bloom``): each query is admission-tested against its
  owner's filter *before* routing, so absent-key probes die locally and
  never consume exchange slots (the NUMA-scaling layout from PAPERS.md).
  Filters are maintained incrementally on insert and rebuilt from the
  live set on compaction (erase leaves them permissive — see the bloom
  module docstring for the staleness contract);
- **checkpoints** via ``core.snapshot``: ``save``/``load`` write one
  versioned, checksummed snapshot per shard plus a manifest, and
  ``load`` onto a *different* shard count reshards — every live entry
  re-routed by ``owner_of`` over the resized mesh, each shard ending
  with exactly its owned keys (``check_ownership`` asserts this).

The serve step (insert batch + filtered lookup batch + erase batch) is
one jitted, donated graph — the shard stores alias input->output, so
steady-state serving never copies an arena — with the same
zero-retrace contract as ``serving.serve_loop``.

Registry counters: ``elastic.bloom_probes`` / ``elastic.bloom_skips`` /
``elastic.bloom_false_positives`` / ``elastic.hits`` /
``elastic.reshards``.  See docs/ELASTIC.md.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom, exchange, hashing, migrate, snapshot
from repro.core import single_value as sv
from repro.core.common import (
    EMPTY_KEY,
    STATUS_MASKED,
    register_struct,
    static_field,
)
from repro.obs.registry import REGISTRY

_U = jnp.uint32
_I = jnp.int32

#: manifest version for ``save``/``load`` directories
ELASTIC_VERSION = 1
_MANIFEST = "manifest.json"


@register_struct
@dataclasses.dataclass
class ShardedTable:
    """P same-geometry single-value shards + their bloom filters.

    ``shards``/``filters`` are pytree children (tuples), so the whole
    service state jits, donates and snapshots as one value.  ``slack``
    is the exchange capacity factor (static: it fixes buffer shapes).
    """
    shards: tuple          # P x sv.SingleValueHashTable
    filters: tuple         # P x bloom.BloomFilter
    num_shards: int = static_field()
    slack: float = static_field()

    @property
    def key_words(self) -> int:
        return self.shards[0].key_words

    @property
    def value_words(self) -> int:
        return self.shards[0].value_words


def create(num_shards: int, capacity_per_shard: int, *,
           bloom_bits_per_key: int = 16, slack: float = 2.0,
           **table_kwargs) -> ShardedTable:
    """A fresh sharded service; ``table_kwargs`` pass to ``sv.create``."""
    shards = tuple(sv.create(capacity_per_shard, **table_kwargs)
                   for _ in range(num_shards))
    filters = tuple(
        bloom.create(bloom_bits_per_key * shards[0].capacity)
        for _ in range(num_shards))
    return ShardedTable(shards=shards, filters=filters,
                        num_shards=num_shards, slack=slack)


def count(st: ShardedTable) -> jax.Array:
    """Total live entries across shards."""
    return sum(t.count for t in st.shards)


# ---------------------------------------------------------------------------
# owner routing over simulated shards
# ---------------------------------------------------------------------------

def _route(st: ShardedTable, keys, mask=None):
    """keys -> (plan, (P, cap, kw) key buffer, (P, cap) valid, owners, words).

    The exact ``owner_of -> make_plan -> scatter`` block the mesh path
    runs inside shard_map; with simulated shards the (P*cap) buffer *is*
    the post-all_to_all layout, reshaped so axis 0 is the shard.
    """
    keys = sv.normalize_key_batch(keys, st.key_words, "keys")
    words = sv.key_hash_word(keys)
    owners = hashing.hash_owner(words, st.num_shards)
    n = keys.shape[0]
    p = st.num_shards
    cap = int(math.ceil(n / p * st.slack))
    plan = exchange.make_plan(owners, p, cap, mask=mask)
    kbuf = exchange.scatter_to_buffer(plan, keys, p, fill=EMPTY_KEY)
    return (plan, kbuf.reshape(p, cap, st.key_words),
            plan.valid_send.reshape(p, cap), owners, words)


def insert(st: ShardedTable, keys, values, mask=None):
    """Route (key, value) pairs to their owners, insert, update filters.

    Returns ``(st, status)`` with ``status`` aligned to the input batch
    (``STATUS_MASKED`` for masked-out or overflowed elements).  Each
    owner's bloom filter learns the folded key word incrementally — the
    same word ``rebuild_from_table`` re-inserts, so incremental and
    rebuilt filters agree on every live key.
    """
    values = sv.normalize_words(values, st.value_words, "values")
    plan, kbuf, mbuf, owners, words = _route(st, keys, mask=mask)
    p, cap = st.num_shards, plan.cap
    vbuf = exchange.scatter_to_buffer(plan, values, p) \
        .reshape(p, cap, st.value_words)
    new_shards, statuses = [], []
    for i, t in enumerate(st.shards):
        t, s = sv.insert(t, kbuf[i], vbuf[i], mask=mbuf[i])
        new_shards.append(t)
        statuses.append(s)
    base = jnp.ones(words.shape, bool) if mask is None else mask
    new_filters = tuple(
        bloom.insert(f, words, mask=base & (owners == _U(i)))
        for i, f in enumerate(st.filters))
    status = exchange.gather_from_buffer(
        plan, jnp.concatenate(statuses), fill=STATUS_MASKED)
    return dataclasses.replace(st, shards=tuple(new_shards),
                               filters=new_filters), status


def lookup(st: ShardedTable, keys):
    """Bloom-filtered sharded lookup.

    Each query is admission-tested against its owner shard's filter
    BEFORE routing; a filter miss is proof of absence, so the query is
    answered ``found=False`` locally and consumes no exchange slot.
    Returns ``(values, found, stats)`` where ``stats`` carries in-graph
    counters: ``probes`` (batch size), ``skips`` (queries killed by the
    filter), ``hits`` (found), ``false_positives`` (admitted but not
    found — filter FP plus erase-staleness), ``overflow``.
    """
    keys_n = sv.normalize_key_batch(keys, st.key_words, "keys")
    words = sv.key_hash_word(keys_n)
    owners = hashing.hash_owner(words, st.num_shards)
    bits_stack = jnp.stack([f.bits for f in st.filters])
    admit = bloom.contains_stack(st.filters[0], bits_stack, owners, words)
    plan = exchange.make_plan(owners, st.num_shards, _lookup_cap(st, keys_n),
                              mask=admit)
    p, cap = st.num_shards, plan.cap
    kbuf = exchange.scatter_to_buffer(plan, keys_n, p, fill=EMPTY_KEY) \
        .reshape(p, cap, st.key_words)
    vals, founds = [], []
    for i, t in enumerate(st.shards):
        v, fnd = sv.retrieve(t, kbuf[i])
        vals.append(sv.normalize_words(v, st.value_words, "values"))
        founds.append(fnd)
    # skipped/unmapped queries take the gather fill: found=False, value 0
    out_vals = exchange.gather_from_buffer(plan, jnp.concatenate(vals))
    out_found = exchange.gather_from_buffer(
        plan, jnp.concatenate(founds), fill=False)
    if st.value_words == 1:
        out_vals = out_vals[:, 0]
    stats = {"probes": jnp.asarray(keys_n.shape[0], _I),
             "skips": jnp.sum(~admit, dtype=_I),
             "hits": jnp.sum(out_found, dtype=_I),
             "false_positives": jnp.sum(admit & ~out_found, dtype=_I),
             "overflow": plan.overflow}
    return out_vals, out_found, stats


def _lookup_cap(st: ShardedTable, keys_n) -> int:
    return int(math.ceil(keys_n.shape[0] / st.num_shards * st.slack))


def erase(st: ShardedTable, keys):
    """Route erases to owners.  Filters are deliberately NOT touched —
    a bloom filter cannot delete (shared bits); the dead key keeps
    advertising until ``compact_all`` rebuilds from the live set.
    Returns ``(st, erased)`` aligned with the input batch.
    """
    plan, kbuf, mbuf, _, _ = _route(st, keys)
    new_shards, eras = [], []
    for i, t in enumerate(st.shards):
        t, e = sv.erase(t, kbuf[i], mask=mbuf[i])
        new_shards.append(t)
        eras.append(e)
    erased = exchange.gather_from_buffer(
        plan, jnp.concatenate(eras), fill=False)
    return dataclasses.replace(st, shards=tuple(new_shards)), erased


# ---------------------------------------------------------------------------
# maintenance: compaction (+ filter rebuild), resharding
# ---------------------------------------------------------------------------

def compact_all(st: ShardedTable) -> ShardedTable:
    """Compact every shard and rebuild its filter from the live set.

    This is the hook that closes the bloom staleness loop: after the
    rebuild a shard's filter stops advertising erased keys, so the
    false-positive rate recovers to the live-set baseline.
    """
    shards = tuple(migrate.compact(t) for t in st.shards)
    filters = tuple(bloom.rebuild_from_table(f, t)
                    for f, t in zip(st.filters, shards))
    return dataclasses.replace(st, shards=shards, filters=filters)


def check_ownership(st: ShardedTable) -> None:
    """Assert every shard holds exactly the keys it owns (host-side)."""
    for i, t in enumerate(st.shards):
        keys, _, live = migrate.live_entries(t)
        owners = hashing.hash_owner(sv.key_hash_word(keys), st.num_shards)
        stray = int(jnp.sum(live & (owners != _U(i)), dtype=_I))
        if stray:
            raise AssertionError(
                f"shard {i} holds {stray} keys owned elsewhere — "
                "ownership partition violated")


def reshard(st: ShardedTable, new_num_shards: int, *,
            capacity_per_shard: int | None = None,
            bloom_bits_per_key: int = 16) -> ShardedTable:
    """Re-partition every live entry onto ``new_num_shards`` shards.

    The elastic move: sweep each shard's live set, concatenate, and
    replay the ownership exchange over the resized mesh — ``owner_of``
    is a pure function of (key, P), so the new partition is exactly the
    one a fresh cluster of P' shards would build.  Filters are derived
    state and are rebuilt tight.  Raises if any live entry fails to
    land (capacity too small for the skew).
    """
    sweeps = [migrate.live_entries(t) for t in st.shards]
    keys = jnp.concatenate([s[0] for s in sweeps])
    vals = jnp.concatenate([s[1] for s in sweeps])
    live = jnp.concatenate([s[2] for s in sweeps])
    total = int(jnp.sum(live, dtype=_I))
    cap = capacity_per_shard or st.shards[0].capacity
    kw = {f: getattr(st.shards[0], f)
          for f in ("key_words", "value_words", "window", "scheme",
                    "layout", "seed", "backend")}
    # a whole-table sweep routed at once needs slack >= the skew ratio;
    # exact per-segment sizing keeps the reshard overflow-free
    n = keys.shape[0]
    owners = hashing.hash_owner(sv.key_hash_word(keys), new_num_shards)
    seg = int(jnp.max(jnp.bincount(
        jnp.where(live, owners, _U(new_num_shards)).astype(_I),
        length=new_num_shards + 1)[:new_num_shards]))
    reslack = max(st.slack, new_num_shards * max(seg, 1) / max(n, 1) * 1.01)
    fresh = create(new_num_shards, cap, bloom_bits_per_key=bloom_bits_per_key,
                   slack=reslack, **kw)
    fresh, _ = insert(fresh, keys, vals, mask=live)
    fresh = dataclasses.replace(fresh, slack=st.slack)
    landed = int(count(fresh))
    if landed != total:
        raise ValueError(
            f"reshard({st.num_shards}->{new_num_shards}) landed {landed} of "
            f"{total} live entries — raise capacity_per_shard")
    REGISTRY.counter("elastic.reshards").inc(1)
    return fresh


# ---------------------------------------------------------------------------
# checkpoint/restore (one snapshot per shard + manifest)
# ---------------------------------------------------------------------------

def save(st: ShardedTable, path: str, *,
         writer: snapshot.SnapshotWriter | None = None) -> None:
    """Checkpoint the service to directory ``path``.

    One ``core.snapshot`` file per shard (versioned, checksummed,
    bit-exact) plus ``manifest.json`` recording the mesh and filter
    geometry.  With ``writer`` the per-shard writes go through the async
    double-buffered path (call ``writer.flush()`` for durability);
    filters are derived state and are rebuilt on load, not serialized.
    """
    os.makedirs(path, exist_ok=True)
    f0 = st.filters[0]
    manifest = {"version": ELASTIC_VERSION, "num_shards": st.num_shards,
                "slack": st.slack,
                "bloom": {"num_blocks": f0.num_blocks,
                          "block_bits": f0.block_bits,
                          "k": f0.k, "seed": f0.seed}}
    tmp = os.path.join(path, f"{_MANIFEST}.tmp")
    with open(tmp, "w") as fh:
        json.dump(manifest, fh)
    os.replace(tmp, os.path.join(path, _MANIFEST))
    for i, t in enumerate(st.shards):
        dst = os.path.join(path, f"shard_{i}.snap")
        if writer is None:
            snapshot.save(t, dst)
        else:
            writer.save(t, dst)


def load(path: str, *, num_shards: int | None = None,
         capacity_per_shard: int | None = None) -> ShardedTable:
    """Restore a service from ``save`` output.

    With ``num_shards=None`` (or equal to the saved count) every shard
    restores bit-exactly (``core.snapshot`` guarantees) and filters are
    rebuilt from the live sets.  A *different* ``num_shards`` restores
    the saved shards and then :func:`reshard`\\ s onto the new mesh.
    Raises :class:`~repro.core.snapshot.SnapshotError` on torn or
    corrupted state — never a silently wrong service.
    """
    mf = os.path.join(path, _MANIFEST)
    if not os.path.exists(mf):
        raise snapshot.SnapshotError(f"no {_MANIFEST} in {path!r}")
    with open(mf) as fh:
        try:
            manifest = json.load(fh)
        except json.JSONDecodeError as e:
            raise snapshot.SnapshotError(
                f"corrupted {_MANIFEST} in {path!r}: {e}") from e
    if manifest.get("version") != ELASTIC_VERSION:
        raise snapshot.SnapshotError(
            f"unsupported elastic manifest version "
            f"{manifest.get('version')!r}")
    saved_p = manifest["num_shards"]
    shards = tuple(snapshot.load(os.path.join(path, f"shard_{i}.snap"))
                   for i in range(saved_p))
    b = manifest["bloom"]
    filters = tuple(
        bloom.rebuild_from_table(
            bloom.BloomFilter(
                bits=jnp.zeros((b["num_blocks"], b["block_bits"]), jnp.uint8),
                num_blocks=b["num_blocks"], block_bits=b["block_bits"],
                k=b["k"], seed=b["seed"]),
            t)
        for t in shards)
    st = ShardedTable(shards=shards, filters=filters, num_shards=saved_p,
                      slack=manifest["slack"])
    if num_shards is not None and num_shards != saved_p:
        bits_per_key = (b["num_blocks"] * b["block_bits"]
                        // max(shards[0].capacity, 1))
        st = reshard(st, num_shards,
                     capacity_per_shard=capacity_per_shard,
                     bloom_bits_per_key=max(bits_per_key, 1))
    return st


# ---------------------------------------------------------------------------
# the serve step: one donated graph, zero retraces after warmup
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_serve_step():
    """Jitted mixed-traffic step over a donated :class:`ShardedTable`.

    Upsert a batch, answer a bloom-filtered lookup batch, erase a batch.
    Donation aliases every shard store input->output; fixed batch shapes
    mean one executable per service geometry.  Memoized so all callers
    share one jitted wrapper (the warmup compile pays for every run).
    """
    @functools.partial(jax.jit, donate_argnums=(0,))
    def serve_step(st, ins_keys, ins_vals, get_keys, del_keys):
        st, status = insert(st, ins_keys, ins_vals)
        vals, found, stats = lookup(st, get_keys)
        st, erased = erase(st, del_keys)
        return st, (status, vals, found, erased, stats)

    return serve_step


def serve_traffic(st: ShardedTable, traffic, *, rate_hz: float | None = None,
                  tracer=None):
    """Drive the donated serve step over a traffic iterable.

    ``traffic`` yields ``(ins_keys, ins_vals, get_keys, del_keys)``
    fixed-shape batches.  ``rate_hz`` paces step *starts* open-loop (a
    slow step eats into the next slot — honest serving latency);
    ``None`` runs closed-loop.  Every step is spanned
    (``elastic.serve_step``) and blocked, so ``tracer.percentiles``
    gives true p50/p95/p99.  Bloom counters accumulate into the global
    REGISTRY (``elastic.bloom_probes/skips/false_positives``,
    ``elastic.hits``).  Returns ``(st, tracer, steps, totals)`` where
    ``totals`` is the summed stats dict; raises on retrace after warmup
    or on exchange overflow (undersized ``slack``).
    """
    import time

    from repro.obs.trace import Tracer

    if tracer is None:
        tracer = Tracer()
    step = make_serve_step()
    period = 1.0 / rate_hz if rate_hz else 0.0
    next_t = time.perf_counter()
    steps = 0
    totals = {k: 0 for k in ("probes", "skips", "hits", "false_positives",
                             "overflow")}
    for batch in traffic:
        if period:
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            next_t += period
        with tracer.span("elastic.serve_step", step=steps):
            st, outs = step(st, *batch)
            jax.block_until_ready(outs)
        stats = outs[-1]
        for k in totals:
            totals[k] += int(stats[k])
        if totals["overflow"]:
            raise AssertionError(
                "elastic exchange overflowed — raise ShardedTable.slack")
        steps += 1
        if steps == 1:
            compilations = step._cache_size()
        elif step._cache_size() != compilations:
            raise AssertionError(
                f"elastic serve step retraced mid-stream: cache "
                f"{compilations} -> {step._cache_size()}")
    REGISTRY.counter("elastic.bloom_probes").inc(totals["probes"])
    REGISTRY.counter("elastic.bloom_skips").inc(totals["skips"])
    REGISTRY.counter("elastic.bloom_false_positives").inc(
        totals["false_positives"])
    REGISTRY.counter("elastic.hits").inc(totals["hits"])
    return st, tracer, steps, totals
