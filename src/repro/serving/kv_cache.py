"""Paged KV cache whose page table is a WarpCore SingleValueHashTable.

vLLM-style paging adapted to TPU + the paper's data structure (DESIGN.md
§3.3): the logical->physical page mapping for every (sequence, page_index)
lives in a repro.core SingleValueHashTable with packed keys

    key   = seq_id * MAX_PAGES_PER_SEQ + page_idx     (u32)
    value = physical page id                          (u32)

Allocation inserts into the table (O(1) amortized, COPS-probed); the decode
gather retrieves a batch of page translations in one vectorized lookup —
the hash table's bulk-retrieve is exactly the address-translation traffic
pattern.  Freeing a sequence erases its keys (tombstones), returning pages
to a free list.

The dense per-layer cache in ``transformer.py`` remains the dry-run path
(GSPMD shards it); this paged cache is the serving-memory-manager feature
exercised by ``examples/paged_serving.py`` and the serving tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import single_value as sv
from repro.core.common import (
    STATUS_FULL,
    STATUS_INSERTED,
    register_struct,
    static_field,
)
from repro.obs.registry import REGISTRY

_I = jnp.int32
_U = jnp.uint32

MAX_PAGES_PER_SEQ = 1 << 12           # 4096 pages/seq (128 tokens/page -> 512k)


@register_struct
@dataclasses.dataclass
class PagedKVCache:
    pages_k: jax.Array                # (L, num_pages, page, Hkv, hd) bf16
    pages_v: jax.Array
    page_table: sv.SingleValueHashTable
    free_top: jax.Array               # bump allocator over the free list
    free_list: jax.Array              # (num_pages,) physical ids
    page_size: int = static_field()
    num_pages: int = static_field()

    @property
    def num_layers(self) -> int:
        return self.pages_k.shape[0]


def create(num_layers: int, num_pages: int, page_size: int, num_kv_heads: int,
           head_dim: int, *, table_slack: float = 1.5) -> PagedKVCache:
    table = sv.create(int(num_pages * table_slack) + 64, window=32)
    shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
    return PagedKVCache(
        pages_k=jnp.zeros(shape, jnp.bfloat16),
        pages_v=jnp.zeros(shape, jnp.bfloat16),
        page_table=table,
        free_top=jnp.zeros((), _I),
        free_list=jnp.arange(num_pages, dtype=_U),
        page_size=page_size, num_pages=num_pages)


def _pt_key(seq_ids: jax.Array, page_idx: jax.Array) -> jax.Array:
    return (seq_ids.astype(_U) * _U(MAX_PAGES_PER_SEQ)
            + page_idx.astype(_U) + _U(1))      # +1 keeps 0 < key < sentinel


def allocate_pages(cache: PagedKVCache, seq_ids: jax.Array,
                   page_idx: jax.Array, mask=None):
    """Map (seq, page_idx) -> fresh physical pages.  Returns (cache, phys).

    Already-mapped pairs return their existing page (idempotent; the insert
    status distinguishes INSERTED from UPDATED)."""
    n = seq_ids.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    keys = _pt_key(seq_ids, page_idx)
    # tentatively hand out the next free pages to genuinely-new keys
    present = sv.contains(cache.page_table, keys)
    fresh = mask & ~present
    order = jnp.cumsum(fresh.astype(_I)) - 1
    phys_new = cache.free_list[
        jnp.clip(cache.free_top + order, 0, cache.num_pages - 1)]
    table, status = sv.insert(cache.page_table, keys,
                              jnp.where(fresh, phys_new, 0), mask=fresh)
    got_new = status == STATUS_INSERTED
    n_new = jnp.sum(got_new, dtype=_I)
    # registry counters: concrete in eager serving loops, silent no-op
    # under jit (values are tracers there — see obs.registry._concrete)
    REGISTRY.counter("kv_cache.pages_allocated").inc(n_new)
    REGISTRY.counter("kv_cache.alloc_full").inc(
        jnp.sum(status == STATUS_FULL, dtype=_I))
    vals, found = sv.retrieve(table, keys)
    cache = dataclasses.replace(cache, page_table=table,
                                free_top=cache.free_top + n_new)
    return cache, jnp.where(found, vals, 0)


def lookup_pages(cache: PagedKVCache, seq_ids: jax.Array,
                 page_idx: jax.Array):
    """Translate a batch of (seq, page_idx) -> (physical page, found)."""
    vals, found = sv.retrieve(cache.page_table, _pt_key(seq_ids, page_idx))
    return vals, found


def append_token(cache: PagedKVCache, seq_ids: jax.Array, pos: jax.Array,
                 k: jax.Array, v: jax.Array):
    """Write one token's K/V for a batch of sequences.

    k, v: (L, B, Hkv, hd); pos: (B,) absolute positions.  Allocates the page
    on first touch."""
    page_idx = pos // cache.page_size
    offset = pos % cache.page_size
    cache, phys = allocate_pages(cache, seq_ids, page_idx)
    pk = cache.pages_k.at[:, phys, offset].set(k.astype(jnp.bfloat16))
    pv = cache.pages_v.at[:, phys, offset].set(v.astype(jnp.bfloat16))
    return dataclasses.replace(cache, pages_k=pk, pages_v=pv)


def gather_kv(cache: PagedKVCache, seq_ids: jax.Array, max_len: int):
    """Materialize (L, B, max_len, Hkv, hd) K/V for attention.

    One bulk hash-table retrieve translates every (seq, page) in the window;
    a vectorized gather pulls the pages."""
    b = seq_ids.shape[0]
    n_pages = -(-max_len // cache.page_size)
    pi = jnp.arange(n_pages, dtype=_I)
    sq = jnp.repeat(seq_ids, n_pages)
    pg = jnp.tile(pi, b)
    phys, found = lookup_pages(cache, sq, pg)           # (B*n_pages,)
    phys = jnp.where(found, phys, 0).reshape(b, n_pages)
    k = cache.pages_k[:, phys]                          # (L, B, n_pages, page, H, hd)
    v = cache.pages_v[:, phys]
    l = cache.pages_k.shape[0]
    k = k.reshape(l, b, n_pages * cache.page_size, *k.shape[4:])[:, :, :max_len]
    v = v.reshape(l, b, n_pages * cache.page_size, *v.shape[4:])[:, :, :max_len]
    return k, v


def free_sequences(cache: PagedKVCache, seq_ids: jax.Array, max_pages: int):
    """Erase a sequence's page-table entries (tombstones; paper §IV-B.5)."""
    pi = jnp.arange(max_pages, dtype=_I)
    sq = jnp.repeat(seq_ids, max_pages)
    pg = jnp.tile(pi, seq_ids.shape[0])
    keys = _pt_key(sq, pg)
    table, erased = sv.erase(cache.page_table, keys)
    n_erased = jnp.sum(erased)
    REGISTRY.counter("kv_cache.pages_evicted").inc(n_erased)
    return dataclasses.replace(cache, page_table=table), n_erased
