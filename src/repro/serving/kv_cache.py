"""Paged KV cache whose page table is a WarpCore SingleValueHashTable.

vLLM-style paging adapted to TPU + the paper's data structure (DESIGN.md
§3.3): the logical->physical page mapping for every (sequence, page_index)
lives in a repro.core SingleValueHashTable with packed keys

    key   = seq_id * MAX_PAGES_PER_SEQ + page_idx     (u32)
    value = physical page id                          (u32)

Allocation inserts into the table (O(1) amortized, COPS-probed); the decode
gather retrieves a batch of page translations in one vectorized lookup —
the hash table's bulk-retrieve is exactly the address-translation traffic
pattern.  Freeing a sequence erases its keys (tombstones), returning pages
to a free list.

The dense per-layer cache in ``transformer.py`` remains the dry-run path
(GSPMD shards it); this paged cache is the serving-memory-manager feature
exercised by ``examples/paged_serving.py`` and the serving tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import single_value as sv
from repro.core.common import (
    STATUS_FULL,
    STATUS_INSERTED,
    register_struct,
    static_field,
)
from repro.obs.registry import REGISTRY

_I = jnp.int32
_U = jnp.uint32

MAX_PAGES_PER_SEQ = 1 << 12           # 4096 pages/seq (128 tokens/page -> 512k)


@register_struct
@dataclasses.dataclass
class PagedKVCache:
    pages_k: jax.Array                # (L, num_pages, page, Hkv, hd) bf16
    pages_v: jax.Array
    page_table: sv.SingleValueHashTable
    free_top: jax.Array               # bump allocator over the free list
    free_list: jax.Array              # (num_pages,) physical ids
    page_size: int = static_field()
    num_pages: int = static_field()
    # auto-growth policy for the page table (repro.core.migrate.GrowthPolicy,
    # frozen/hashable -> static).  None keeps the fixed-capacity behavior:
    # a sequence flood eventually reports per-key allocation failures.
    policy: object = static_field(default=None)

    @property
    def num_layers(self) -> int:
        return self.pages_k.shape[0]


def create(num_layers: int, num_pages: int, page_size: int, num_kv_heads: int,
           head_dim: int, *, table_slack: float = 1.5,
           policy=None) -> PagedKVCache:
    table = sv.create(int(num_pages * table_slack) + 64, window=32)
    shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
    return PagedKVCache(
        pages_k=jnp.zeros(shape, jnp.bfloat16),
        pages_v=jnp.zeros(shape, jnp.bfloat16),
        page_table=table,
        free_top=jnp.zeros((), _I),
        free_list=jnp.arange(num_pages, dtype=_U),
        page_size=page_size, num_pages=num_pages, policy=policy)


def _pt_key(seq_ids: jax.Array, page_idx: jax.Array) -> jax.Array:
    return (seq_ids.astype(_U) * _U(MAX_PAGES_PER_SEQ)
            + page_idx.astype(_U) + _U(1))      # +1 keeps 0 < key < sentinel


def allocate_pages(cache: PagedKVCache, seq_ids: jax.Array,
                   page_idx: jax.Array, mask=None):
    """Map (seq, page_idx) -> fresh physical pages.  Returns
    ``(cache, phys, ok)`` — ``ok[i]`` False means key i got NO page
    (free list exhausted, or the page table was full with no growth
    policy); ``phys`` is 0 there and must not be written to.

    Already-mapped pairs return their existing page (idempotent).
    Duplicate (seq, page) keys inside one batch resolve to the SAME
    physical page: only the first occurrence of each fresh key draws
    from the free list.  When the free list runs out, the trailing fresh
    keys are reported failed (``kv_cache.alloc_full``) instead of being
    silently aliased onto the last physical page.  With
    ``cache.policy`` set, the page *table* auto-grows through
    ``migrate.insert_or_grow`` so table occupancy never causes a
    failure — only genuine physical-page exhaustion can.
    """
    from repro.core import bulk_retrieve
    n = seq_ids.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    keys = _pt_key(seq_ids, page_idx)
    present = sv.contains(cache.page_table, keys)
    fresh = mask & ~present
    # one free-list draw per DISTINCT fresh key (first occurrence is the
    # representative; duplicates map to its page via the final retrieve)
    is_rep, _ = bulk_retrieve.group_queries(keys[:, None], fresh)
    rep = fresh & is_rep
    order = jnp.cumsum(rep.astype(_I)) - 1          # free-list rank per rep
    avail = _I(cache.num_pages) - cache.free_top
    has_page = rep & (order < avail)                # free list can cover it
    phys_new = cache.free_list[
        jnp.clip(cache.free_top + order, 0, cache.num_pages - 1)]
    table = cache.page_table
    new_vals = jnp.where(has_page, phys_new, 0)
    if cache.policy is not None:
        from repro.core import migrate
        table, status = migrate.insert_or_grow(table, keys, new_vals,
                                               mask=has_page,
                                               policy=cache.policy)
    else:
        table, status = sv.insert(table, keys, new_vals, mask=has_page)
    got_new = status == STATUS_INSERTED
    n_new = jnp.sum(got_new, dtype=_I)
    # advance past the highest rank actually inserted (== n_new unless a
    # FULL without policy skipped a mid-batch rank; those pages leak and
    # are accounted by alloc_full rather than handed out twice)
    top_adv = jnp.max(jnp.where(got_new, order + 1, 0), initial=0)
    vals, found = sv.retrieve(table, keys)
    ok = mask & found
    # registry counters: concrete in eager serving loops, silent no-op
    # under jit (values are tracers there — see obs.registry._concrete)
    REGISTRY.counter("kv_cache.pages_allocated").inc(n_new)
    REGISTRY.counter("kv_cache.alloc_full").inc(
        jnp.sum(mask & ~found, dtype=_I))
    cache = dataclasses.replace(cache, page_table=table,
                                free_top=cache.free_top + top_adv)
    return cache, jnp.where(ok, vals, 0), ok


def lookup_pages(cache: PagedKVCache, seq_ids: jax.Array,
                 page_idx: jax.Array):
    """Translate a batch of (seq, page_idx) -> (physical page, found)."""
    vals, found = sv.retrieve(cache.page_table, _pt_key(seq_ids, page_idx))
    return vals, found


def append_token(cache: PagedKVCache, seq_ids: jax.Array, pos: jax.Array,
                 k: jax.Array, v: jax.Array):
    """Write one token's K/V for a batch of sequences.

    k, v: (L, B, Hkv, hd); pos: (B,) absolute positions.  Allocates the page
    on first touch."""
    page_idx = pos // cache.page_size
    offset = pos % cache.page_size
    cache, phys, ok = allocate_pages(cache, seq_ids, page_idx)
    # failed allocations must not corrupt page 0: OOR drop their writes
    wphys = jnp.where(ok, phys.astype(_I), _I(cache.num_pages))
    pk = cache.pages_k.at[:, wphys, offset].set(k.astype(jnp.bfloat16),
                                                mode="drop")
    pv = cache.pages_v.at[:, wphys, offset].set(v.astype(jnp.bfloat16),
                                                mode="drop")
    return dataclasses.replace(cache, pages_k=pk, pages_v=pv)


def gather_kv(cache: PagedKVCache, seq_ids: jax.Array, max_len: int):
    """Materialize (L, B, max_len, Hkv, hd) K/V for attention.

    One bulk hash-table retrieve translates every (seq, page) in the window;
    a vectorized gather pulls the pages."""
    b = seq_ids.shape[0]
    n_pages = -(-max_len // cache.page_size)
    pi = jnp.arange(n_pages, dtype=_I)
    sq = jnp.repeat(seq_ids, n_pages)
    pg = jnp.tile(pi, b)
    phys, found = lookup_pages(cache, sq, pg)           # (B*n_pages,)
    phys = jnp.where(found, phys, 0).reshape(b, n_pages)
    k = cache.pages_k[:, phys]                          # (L, B, n_pages, page, H, hd)
    v = cache.pages_v[:, phys]
    l = cache.pages_k.shape[0]
    k = k.reshape(l, b, n_pages * cache.page_size, *k.shape[4:])[:, :, :max_len]
    v = v.reshape(l, b, n_pages * cache.page_size, *v.shape[4:])[:, :, :max_len]
    return k, v


def free_sequences(cache: PagedKVCache, seq_ids: jax.Array, max_pages: int):
    """Erase a sequence's page-table entries (tombstones; paper §IV-B.5)."""
    pi = jnp.arange(max_pages, dtype=_I)
    sq = jnp.repeat(seq_ids, max_pages)
    pg = jnp.tile(pi, seq_ids.shape[0])
    keys = _pt_key(sq, pg)
    table, erased = sv.erase(cache.page_table, keys)
    n_erased = jnp.sum(erased)
    REGISTRY.counter("kv_cache.pages_evicted").inc(n_erased)
    return dataclasses.replace(cache, page_table=table), n_erased
