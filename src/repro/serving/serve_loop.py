"""Serving loop: batched prefill + greedy decode over the model facade.

``generate`` drives the dense-cache path (the dry-run serve_step); the
paged-cache path (hash-table page table) is exercised by
``examples/paged_serving.py``.  Sampling is greedy or temperature-based on a
counter-mode PRNG keyed by (seed, step) so generation is reproducible across
restarts mid-stream.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

_I = jnp.int32


def _sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(_I)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(_I)


def generate(model, params, prompts: jax.Array, max_new: int, *,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S_prompt) int32. Returns (B, max_new) generated tokens.

    Uses real prefill where the family supports it, otherwise a decode-scan
    warmup (state-recurrent families).
    """
    b, s_prompt = prompts.shape
    max_seq = s_prompt + max_new

    if model.prefill is not None and model.cfg.family in ("dense", "moe"):
        logits, cache, *_ = model.prefill(params, {"tokens": prompts}, max_seq)
        last_logits = logits[:, -1]
        start_pos = s_prompt
    else:
        cache = model.init_cache(b, max_seq)

        def warm(carry, i):
            cache, _ = carry
            tok = jax.lax.dynamic_slice_in_dim(prompts, i, 1, axis=1)
            lg, cache = model.decode_step(params, cache, tok, i)
            return (cache, lg[:, 0]), None

        (cache, last_logits), _ = jax.lax.scan(
            warm, (cache, jnp.zeros((b, model.cfg.vocab_size), jnp.float32)),
            jnp.arange(s_prompt))
        start_pos = s_prompt

    def step(carry, i):
        cache, logits = carry
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        tok = _sample(logits, key, temperature)[:, None]
        lg, cache = model.decode_step(params, cache, tok, start_pos + i)
        return (cache, lg[:, 0]), tok[:, 0]

    (_, _), toks = jax.lax.scan(step, (cache, last_logits),
                                jnp.arange(max_new))
    return jnp.moveaxis(toks, 0, 1)                      # (B, max_new)


def make_serve_step(model):
    """The unit the dry-run lowers for decode cells: one token for a batch
    against a fully-sized cache.  Donated cache; jit-ready."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def generate_traced(model, params, prompts: jax.Array, max_new: int, *,
                    temperature: float = 0.0, seed: int = 0, tracer=None):
    """``generate`` with per-step wall-time spans (p50/p95/p99 latencies).

    The decode loop runs at the python level — one jitted ``serve_step``
    call per token, each wrapped in ``tracer.span("serve.decode_step")``
    and blocked to completion so the span measures real device time.
    ``generate``'s fused ``lax.scan`` graph is untouched; this variant
    exists for serving-latency observability (docs/OBSERVABILITY.md), not
    peak throughput.  Returns ``(tokens, tracer)``.
    """
    from repro.obs.trace import Tracer

    if tracer is None:
        tracer = Tracer()
    b, s_prompt = prompts.shape
    max_seq = s_prompt + max_new
    step_fn = jax.jit(make_serve_step(model))

    with tracer.span("serve.prefill", batch=b, prompt_len=s_prompt):
        # decode-scan warmup works for every family (incl. state-recurrent)
        cache = model.init_cache(b, max_seq)
        last_logits = jnp.zeros((b, model.cfg.vocab_size), jnp.float32)
        for i in range(s_prompt):
            lg, cache = step_fn(params, cache, prompts[:, i:i + 1], _I(i))
            last_logits = lg[:, 0]
        jax.block_until_ready(last_logits)

    toks = []
    for i in range(max_new):
        with tracer.span("serve.decode_step", step=i):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
            tok = _sample(last_logits, key, temperature)[:, None]
            lg, cache = step_fn(params, cache, tok, _I(s_prompt + i))
            jax.block_until_ready(lg)
        last_logits = lg[:, 0]
        toks.append(tok[:, 0])
    return jnp.stack(toks, axis=1), tracer
