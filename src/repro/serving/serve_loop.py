"""Serving loop: batched prefill + greedy decode over the model facade.

``generate`` drives the dense-cache path (the dry-run serve_step); the
paged-cache path (hash-table page table) is exercised by
``examples/paged_serving.py``.  Sampling is greedy or temperature-based on a
counter-mode PRNG keyed by (seed, step) so generation is reproducible across
restarts mid-stream.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

_I = jnp.int32


def _sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(_I)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(_I)


def generate(model, params, prompts: jax.Array, max_new: int, *,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, S_prompt) int32. Returns (B, max_new) generated tokens.

    Uses real prefill where the family supports it, otherwise a decode-scan
    warmup (state-recurrent families).
    """
    b, s_prompt = prompts.shape
    max_seq = s_prompt + max_new

    if model.prefill is not None and model.cfg.family in ("dense", "moe"):
        logits, cache, *_ = model.prefill(params, {"tokens": prompts}, max_seq)
        last_logits = logits[:, -1]
        start_pos = s_prompt
    else:
        cache = model.init_cache(b, max_seq)

        def warm(carry, i):
            cache, _ = carry
            tok = jax.lax.dynamic_slice_in_dim(prompts, i, 1, axis=1)
            lg, cache = model.decode_step(params, cache, tok, i)
            return (cache, lg[:, 0]), None

        (cache, last_logits), _ = jax.lax.scan(
            warm, (cache, jnp.zeros((b, model.cfg.vocab_size), jnp.float32)),
            jnp.arange(s_prompt))
        start_pos = s_prompt

    def step(carry, i):
        cache, logits = carry
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        tok = _sample(logits, key, temperature)[:, None]
        lg, cache = model.decode_step(params, cache, tok, start_pos + i)
        return (cache, lg[:, 0]), tok[:, 0]

    (_, _), toks = jax.lax.scan(step, (cache, last_logits),
                                jnp.arange(max_new))
    return jnp.moveaxis(toks, 0, 1)                      # (B, max_new)


def make_serve_step(model):
    """The unit the dry-run lowers for decode cells: one token for a batch
    against a fully-sized cache.  Donated cache; jit-ready."""

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def generate_traced(model, params, prompts: jax.Array, max_new: int, *,
                    temperature: float = 0.0, seed: int = 0, tracer=None):
    """``generate`` with per-step wall-time spans (p50/p95/p99 latencies).

    The decode loop runs at the python level — one jitted ``serve_step``
    call per token, each wrapped in ``tracer.span("serve.decode_step")``
    and blocked to completion so the span measures real device time.
    ``generate``'s fused ``lax.scan`` graph is untouched; this variant
    exists for serving-latency observability (docs/OBSERVABILITY.md), not
    peak throughput.  Returns ``(tokens, tracer)``.
    """
    from repro.obs.trace import Tracer

    if tracer is None:
        tracer = Tracer()
    b, s_prompt = prompts.shape
    max_seq = s_prompt + max_new
    step_fn = jax.jit(make_serve_step(model))

    with tracer.span("serve.prefill", batch=b, prompt_len=s_prompt):
        # decode-scan warmup works for every family (incl. state-recurrent)
        cache = model.init_cache(b, max_seq)
        last_logits = jnp.zeros((b, model.cfg.vocab_size), jnp.float32)
        for i in range(s_prompt):
            lg, cache = step_fn(params, cache, prompts[:, i:i + 1], _I(i))
            last_logits = lg[:, 0]
        jax.block_until_ready(last_logits)

    toks = []
    for i in range(max_new):
        with tracer.span("serve.decode_step", step=i):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
            tok = _sample(last_logits, key, temperature)[:, None]
            lg, cache = step_fn(params, cache, tok, _I(s_prompt + i))
            jax.block_until_ready(lg)
        last_logits = lg[:, 0]
        toks.append(tok[:, 0])
    return jnp.stack(toks, axis=1), tracer


# ---------------------------------------------------------------------------
# sustained table traffic: mixed insert/lookup/erase under rate pacing
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_table_serve_step():
    """One serve step of hash-table traffic, single compilation, donated.

    The step upserts a batch, answers a lookup batch and erases a batch
    against ONE donated table — the store buffers alias input->output
    (``donate_argnums``), so a steady-state serve loop never copies the
    table arena.  Fixed batch shapes => the jit caches exactly one
    executable per table geometry; ``serve_table_traffic`` asserts this
    (zero retraces after warmup) in-run.  Returns the jitted
    ``step(table, ins_keys, ins_vals, get_keys, del_keys) ->
    (table, (status, values, found, erased))``.  Memoized: every caller
    shares ONE jitted wrapper, so a warmup pass really does pay the
    compile for all later traffic runs.
    """
    from repro.core import single_value as sv

    @functools.partial(jax.jit, donate_argnums=(0,))
    def table_serve_step(table, ins_keys, ins_vals, get_keys, del_keys):
        table, status = sv.insert(table, ins_keys, ins_vals)
        values, found = sv.retrieve(table, get_keys)
        table, erased = sv.erase(table, del_keys)
        return table, (status, values, found, erased)

    return table_serve_step


def serve_table_traffic(table, traffic, *, rate_hz: float | None = None,
                        tracer=None):
    """Drive ``make_table_serve_step`` over a traffic iterable.

    ``traffic`` yields ``(ins_keys, ins_vals, get_keys, del_keys)``
    batches of fixed shapes.  ``rate_hz`` paces step *starts* to the
    target rate (open-loop arrivals, the honest way to measure serving
    latency: a slow step eats into the next slot instead of silently
    stretching the clock); ``None`` runs closed-loop/back-to-back.  Each
    step is wrapped in a ``serve.table_step`` span and blocked to
    completion so p50/p95/p99 (``tracer.percentiles``) are true per-step
    latencies.  Returns ``(table, tracer, steps)``; raises if the step
    retraced after the first chunk (the single-compilation contract).
    """
    import time

    from repro.obs.trace import Tracer

    if tracer is None:
        tracer = Tracer()
    step = make_table_serve_step()
    period = 1.0 / rate_hz if rate_hz else 0.0
    next_t = time.perf_counter()
    steps = 0
    for batch in traffic:
        if period:
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            next_t += period
        with tracer.span("serve.table_step", step=steps):
            table, outs = step(table, *batch)
            jax.block_until_ready(outs)
        steps += 1
        if steps == 1:
            compilations = step._cache_size()
        elif step._cache_size() != compilations:
            raise AssertionError(
                f"table serve step retraced mid-stream: cache "
                f"{compilations} -> {step._cache_size()}")
    return table, tracer, steps
