"""Single-compilation streaming ingestion: donated chunked scan pipeline.

The per-batch pipeline (``pipeline.relational_stage``) re-enters Python
between batches: every chunk pays dispatch for each stage (dedup insert,
watchlist join, group-by aggregate), and sliding-window forget plus
tombstone compaction are extra host round-trips.  This module restructures
that hot path as ONE compiled program:

- **The carry is the table.**  ``StreamState`` is a pytree carrying the
  dedup table, the sliding-window fingerprint ring, the chunk cursor and
  in-graph ``obs.metrics.StreamCounters``; ``stream_scan`` threads it
  through ``jax.lax.scan`` over a fixed-shape ``(n_chunks, chunk_batch,
  seq_len)`` token block.  One trace, one compilation, zero per-chunk
  re-entry.
- **Donation.**  Both entry points (``stream_scan`` and the single-step
  ``stream_step``) donate the state argument, so XLA aliases the table
  buffers input->output instead of copying a table-sized arena per call —
  ``launch.hlo_census.input_output_aliases`` reads the aliasing back out
  of the compiled HLO and the stream tests assert it.
- **In-graph compaction.**  Forget-churn tombstones the dedup table;
  rather than breaking the stream to call host-side ``migrate.compact``,
  every ``compact_every``-th chunk evaluates a tombstone-density
  predicate from ``obs.metrics.slot_stats`` and fires
  ``migrate.compact_in_graph`` under ``lax.cond`` — a same-shape
  sweep+rebuild, so the scan carry structure is untouched.

Chunk semantics per step, in order (mirrored 1:1 — same primitive ops,
same order — by the eager ``reference_run``, so streaming output is
bit-exact against the per-batch pipeline, including across compaction
boundaries; compaction only relocates live slots, never changes the live
set):

1. forget the fingerprints ingested ``forget_after`` chunks ago
   (``sv.erase`` on the ring slot about to be overwritten);
2. dedup: fingerprint each sequence, count-insert, keep first
   occurrences (``STATUS_INSERTED``) — identical to
   ``pipeline.dedup_filter``;
3. join the kept token stream against the prebuilt watchlist
   (``join.probe``, inner) and group-by count hits per sequence —
   identical to ``pipeline.relational_stage`` stages 2-3;
4. record the chunk's fingerprints in the ring;
5. maybe compact (``lax.cond`` on the density predicate);
6. accumulate counters.

The host driver ``stream`` runs the jitted step with one-chunk
``device_put`` lookahead (double buffering): while the device executes
chunk i, chunk i+1's tokens are already being staged, so host transfer
hides under compute.  See docs/STREAMING.md.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp

from repro.core import counting
from repro.core import migrate
from repro.core import single_value as sv
from repro.core.common import STATUS_INSERTED, register_struct, static_field
from repro.data import pipeline
from repro.obs import metrics

_U = jnp.uint32
_I = jnp.int32


# ---------------------------------------------------------------------------
# config + carry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static shape/policy knobs of a stream (hashable: rides as the
    carry's aux data, so two configs compile separately and equal configs
    share one cache entry).

    - ``chunk_batch`` x ``seq_len``: the fixed chunk shape.  Every chunk
      must match; the driver pads or rejects ragged tails.
    - ``dedup_capacity``: slots in the counting dedup table.
    - ``pair_capacity``: join output bound per chunk (default
      ``chunk_batch * seq_len`` — safe: the build side is deduplicated,
      so each stream position matches at most once).
    - ``forget_after``: sliding dedup window in chunks (0 = never forget;
      the ring then holds one unused row so carry shapes stay static).
    - ``compact_every``: evaluate the compaction predicate every K chunks
      (0 = never).  The predicate itself is in-graph: tombstones >
      ``max_tombstone_density`` * capacity.
    """
    seq_len: int
    chunk_batch: int
    dedup_capacity: int
    pair_capacity: int | None = None
    forget_after: int = 0
    compact_every: int = 0
    max_tombstone_density: float = 0.25

    @property
    def pairs(self) -> int:
        return (self.pair_capacity if self.pair_capacity is not None
                else self.chunk_batch * self.seq_len)

    @property
    def ring_len(self) -> int:
        return max(self.forget_after, 1)


@register_struct
@dataclasses.dataclass
class StreamState:
    """The scan carry: table + ring + cursor + counters, cfg static."""
    table: counting.CountingHashTable
    history: jax.Array               # (ring_len, chunk_batch) u32 fps
    chunk_idx: jax.Array             # i32 — chunks ingested so far
    counters: metrics.StreamCounters
    cfg: StreamConfig = static_field()


def create_state(cfg: StreamConfig, *, seed: int | None = None) -> StreamState:
    """Fresh stream: empty dedup table, zeroed ring and counters."""
    kw = {} if seed is None else {"seed": seed}
    return StreamState(
        table=counting.create(cfg.dedup_capacity, **kw),
        history=jnp.zeros((cfg.ring_len, cfg.chunk_batch), _U),
        chunk_idx=jnp.zeros((), _I),
        counters=metrics.stream_counters_empty(),
        cfg=cfg)


# ---------------------------------------------------------------------------
# one chunk, fully traceable
# ---------------------------------------------------------------------------

def _tombstone_limit(cfg: StreamConfig, table) -> int:
    return int(cfg.max_tombstone_density * table.capacity)


def pipeline_step(state: StreamState, watchlist, chunk: jax.Array):
    """Ingest one ``(chunk_batch, seq_len)`` token chunk.

    Returns ``(state, (keep, hits))`` — ``keep`` (chunk_batch,) bool,
    ``hits`` (chunk_batch,) i32 — exactly ``relational_stage``'s per-batch
    outputs.  Pure jnp/lax end-to-end: scan body and jitted step share
    this one definition.  ``watchlist`` is a prebuilt
    ``pipeline.build_watchlist`` table (probe-only on the hot path).
    """
    from repro.relational import groupby, join

    cfg = state.cfg
    table = state.table
    if cfg.forget_after > 0:
        cursor = state.chunk_idx % _I(cfg.ring_len)
        wrapped = state.chunk_idx >= _I(cfg.forget_after)
    else:
        cursor, wrapped = _I(0), jnp.zeros((), bool)

    # 1. forget: erase the expired ring row (a no-op mask until the ring
    # wraps — the zeros it holds before then are never erased)
    expired = state.history[cursor]
    forget_mask = jnp.broadcast_to(wrapped, (cfg.chunk_batch,))
    table, forgotten = sv.erase(table, expired, mask=forget_mask)

    # 2. dedup (== pipeline.dedup_filter: count-insert, keep fresh)
    fps = pipeline.sequence_fingerprints(chunk)
    table, status = counting.insert(table, fps)
    keep = status == STATUS_INSERTED

    # 3. join + aggregate (== relational_stage stages 2-3)
    flat = chunk.reshape(-1).astype(_U)
    stream_mask = jnp.broadcast_to(keep[:, None], chunk.shape).reshape(-1)
    res = join.probe(watchlist, flat, cfg.pairs, "inner", mask=stream_mask)
    seq_of_pair = jnp.where(res.valid, res.probe_idx // cfg.seq_len, 0)
    gt = groupby.create(groupby.capacity_for(cfg.chunk_batch))
    gt, _ = groupby.update(gt, "count", seq_of_pair.astype(_U),
                           mask=res.valid)
    hits, _ = groupby.lookup(gt, "count",
                             jnp.arange(cfg.chunk_batch, dtype=_U))
    hits = hits.astype(_I)

    # 4. ring update
    history = state.history.at[cursor].set(fps)

    # 5. in-graph compaction: every compact_every-th chunk, fire iff
    # tombstone density crossed the threshold — same-shape sweep+rebuild,
    # so both cond branches carry the identical pytree structure
    live, tomb, _ = metrics.slot_stats(table.ops, table.store)
    if cfg.compact_every > 0:
        due = (state.chunk_idx % _I(cfg.compact_every)
               == _I(cfg.compact_every - 1))
        fire = due & (tomb > _I(_tombstone_limit(cfg, table)))
        table = jax.lax.cond(fire, migrate.compact_in_graph,
                             lambda t: t, table)
        live, tomb, _ = metrics.slot_stats(table.ops, table.store)
    else:
        fire = jnp.zeros((), bool)

    # 6. counters
    c = state.counters
    counters = metrics.StreamCounters(
        chunks=c.chunks + 1,
        kept=c.kept + jnp.sum(keep, dtype=_I),
        hits=c.hits + jnp.sum(hits, dtype=_I),
        erased=c.erased + jnp.sum(forgotten, dtype=_I),
        compactions=c.compactions + fire.astype(_I),
        live_slots=live, tombstone_slots=tomb)

    state = StreamState(table=table, history=history,
                        chunk_idx=state.chunk_idx + 1,
                        counters=counters, cfg=cfg)
    return state, (keep, hits)


# ---------------------------------------------------------------------------
# compiled entry points — ONE compilation each, donated carry
# ---------------------------------------------------------------------------

def _scan_fun(state, watchlist, chunks):
    def body(st, chunk):
        return pipeline_step(st, watchlist, chunk)
    return jax.lax.scan(body, state, chunks)


#: whole-stream entry point: ``stream_scan(state, watchlist, chunks)``
#: with chunks (n_chunks, chunk_batch, seq_len) — one lax.scan, one
#: compilation per (cfg, shapes), state donated.  Returns
#: (final_state, (keep (n, cb) bool, hits (n, cb) i32)).
stream_scan = jax.jit(_scan_fun, donate_argnums=(0,))

#: single-chunk entry point, same body, same donation — for drivers that
#: interleave ingestion with other host work (the serve loop) and for
#: per-step latency measurement.  Compiles once per (cfg, shapes).
stream_step = jax.jit(pipeline_step, donate_argnums=(0,))


def compiled_stream_hlo(state: StreamState, watchlist,
                        chunks: jax.Array) -> str:
    """Optimized HLO text of the scan program (for
    ``launch.hlo_census``: aliasing audit, loop census)."""
    return stream_scan.lower(state, watchlist, chunks) \
        .compile().as_text()


# ---------------------------------------------------------------------------
# host driver: double-buffered step loop
# ---------------------------------------------------------------------------

def _staged(chunks: Iterable, expect_shape) -> Iterator[jax.Array]:
    for c in chunks:
        c = jnp.asarray(c)
        if tuple(c.shape) != tuple(expect_shape):
            raise ValueError(f"chunk shape {tuple(c.shape)} != "
                             f"{tuple(expect_shape)} (fixed-shape stream)")
        yield jax.device_put(c)


def stream(state: StreamState, watchlist, chunks: Iterable,
           *, tracer=None):
    """Drive ``stream_step`` over an iterable of token chunks.

    Double buffering: chunk i+1 is ``device_put`` before chunk i's step
    is awaited, so host staging overlaps device execution (async
    dispatch).  ``tracer`` (an ``obs.trace.Tracer``) wraps each step in a
    ``stream.step`` span — spans block on the step's outputs, so they
    measure true per-chunk latency.  Returns
    ``(final_state, keep (n, cb), hits (n, cb))``.
    """
    cfg = state.cfg
    it = _staged(chunks, (cfg.chunk_batch, cfg.seq_len))
    keeps, hitss = [], []
    pending = next(it, None)
    while pending is not None:
        chunk, pending = pending, next(it, None)   # lookahead staged now
        if tracer is not None:
            with tracer.span("stream.step"):
                state, (keep, hits) = stream_step(state, watchlist, chunk)
                jax.block_until_ready(hits)
        else:
            state, (keep, hits) = stream_step(state, watchlist, chunk)
        keeps.append(keep)
        hitss.append(hits)
    if not keeps:
        z = jnp.zeros((0, cfg.chunk_batch))
        return state, z.astype(bool), z.astype(_I)
    return state, jnp.stack(keeps), jnp.stack(hitss)


# ---------------------------------------------------------------------------
# eager per-batch reference (the parity oracle + re-entry baseline)
# ---------------------------------------------------------------------------

def reference_run(state: StreamState, watchlist, chunks):
    """Per-batch eager reference: the SAME chunk semantics, driven through
    the pre-existing per-batch entry points with host re-entry between
    every stage — ``sv.erase`` forget, ``pipeline.relational_stage``
    (dedup -> join -> aggregate), host-side compaction predicate +
    ``migrate.compact``.  Bit-exact against ``stream_scan``/``stream``
    on every output and every carry leaf (compaction included: both paths
    run the identical same-shape sweep at the identical chunk
    boundaries), and the honest "what the code did before" baseline for
    the fig11 speedup rows.
    """
    cfg = state.cfg
    table = state.table
    history = jax.device_get(state.history).copy()
    chunk_idx = int(state.chunk_idx)
    counters = state.counters
    keeps, hitss = [], []
    for chunk in chunks:
        chunk = jnp.asarray(chunk)
        cursor = chunk_idx % cfg.ring_len if cfg.forget_after > 0 else 0
        forget = cfg.forget_after > 0 and chunk_idx >= cfg.forget_after
        mask = jnp.broadcast_to(jnp.asarray(forget), (cfg.chunk_batch,))
        table, forgotten = sv.erase(table, jnp.asarray(history[cursor]),
                                    mask=mask)
        fps = pipeline.sequence_fingerprints(chunk)
        table, keep, hits = pipeline.relational_stage(
            table, chunk, watchlist, pair_capacity=cfg.pairs)
        history[cursor] = jax.device_get(fps)
        live, tomb, _ = metrics.slot_stats(table.ops, table.store)
        fire = False
        if cfg.compact_every > 0:
            due = chunk_idx % cfg.compact_every == cfg.compact_every - 1
            fire = due and int(tomb) > _tombstone_limit(cfg, table)
            if fire:
                table = migrate.compact_in_graph(table)
                live, tomb, _ = metrics.slot_stats(table.ops, table.store)
        counters = metrics.StreamCounters(
            chunks=counters.chunks + 1,
            kept=counters.kept + jnp.sum(keep, dtype=_I),
            hits=counters.hits + jnp.sum(hits, dtype=_I),
            erased=counters.erased + jnp.sum(forgotten, dtype=_I),
            compactions=counters.compactions + _I(int(fire)),
            live_slots=live, tombstone_slots=tomb)
        chunk_idx += 1
        keeps.append(keep)
        hitss.append(hits)
    final = StreamState(table=table, history=jnp.asarray(history),
                        chunk_idx=_I(chunk_idx), counters=counters,
                        cfg=cfg)
    if not keeps:
        z = jnp.zeros((0, cfg.chunk_batch))
        return final, z.astype(bool), z.astype(_I)
    return final, jnp.stack(keeps), jnp.stack(hitss)
