"""Data pipeline: deterministic, resumable, straggler-proof token streams.

Key property (DESIGN.md §5 straggler mitigation / elasticity): a batch is a
pure function of ``(seed, step, shard, num_shards)`` — no iterator state, no
host-local queues.  Any replacement host can recompute exactly the shard a
failed host would have produced, and restart-from-checkpoint only needs the
step counter.  Two sources:

- ``synthetic`` — PRNG tokens (threefry counter mode, zero I/O), used by the
  examples, smoke tests, and the end-to-end driver;
- ``memmap``    — a flat binary token file read by stride, the standard
  production format (tokens packed uint16/uint32); same determinism contract.

``dedup_filter`` plugs the paper's CountingHashTable into the pipeline: the
insert *status* of an n-gram fingerprint says whether a sequence was seen
before (STATUS_INSERTED = fresh) — hash-table-as-a-feature, not a demo.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"         # synthetic | memmap
    path: str = ""                    # for memmap
    token_dtype: str = "uint16"


def _fold(seed: int, *xs: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    for x in xs:
        key = jax.random.fold_in(key, x)
    return key


def synthetic_batch(cfg: DataConfig, step: int, shard: int = 0,
                    num_shards: int = 1) -> dict:
    """Deterministic batch for (step, shard): tokens + next-token labels."""
    per_shard = cfg.global_batch // num_shards
    key = _fold(cfg.seed, step, shard)
    toks = jax.random.randint(key, (per_shard, cfg.seq_len + 1), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def memmap_batch(cfg: DataConfig, step: int, shard: int = 0,
                 num_shards: int = 1) -> dict:
    """Strided reads from a flat token file; deterministic per (step, shard)."""
    per_shard = cfg.global_batch // num_shards
    data = np.memmap(cfg.path, dtype=np.dtype(cfg.token_dtype), mode="r")
    n_windows = (len(data) - 1) // cfg.seq_len
    # window indices for this (step, shard): counter-mode PRNG, no state
    rng = np.random.Generator(np.random.Philox(key=cfg.seed,
                                               counter=[0, 0, step, shard]))
    idx = rng.integers(0, n_windows, size=per_shard)
    starts = idx * cfg.seq_len
    toks = np.stack([data[s:s + cfg.seq_len + 1] for s in starts]).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def get_batch(cfg: DataConfig, step: int, shard: int = 0,
              num_shards: int = 1) -> dict:
    if cfg.source == "synthetic":
        return synthetic_batch(cfg, step, shard, num_shards)
    if cfg.source == "memmap":
        return memmap_batch(cfg, step, shard, num_shards)
    raise ValueError(cfg.source)


def batch_iterator(cfg: DataConfig, start_step: int = 0, shard: int = 0,
                   num_shards: int = 1) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, get_batch(cfg, step, shard, num_shards)
        step += 1


# ---------------------------------------------------------------------------
# hash-table-backed dedup (paper integration)
# ---------------------------------------------------------------------------

def sequence_fingerprints(tokens: jax.Array, seed: int = 0x1234) -> jax.Array:
    """Order-sensitive u32 fingerprint per sequence (polynomial rolling hash)."""
    from repro.core import hashing
    t = tokens.astype(jnp.uint32)

    def step(acc, col):
        return acc * jnp.uint32(0x01000193) ^ hashing.mix_murmur3(col), None

    acc0 = jnp.full((tokens.shape[0],), np.uint32(seed), jnp.uint32)
    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(t, 1, 0))
    # avoid the table sentinels
    return jnp.minimum(acc, jnp.uint32(0xFFFFFFFD))


def dedup_filter(table, tokens: jax.Array, *, policy=None):
    """Drop sequences whose fingerprint was already seen.

    Returns (table, keep_mask).  Uses the CountingHashTable insert status:
    STATUS_INSERTED <=> first occurrence (paper C2 as a pipeline feature).

    ``policy`` (a ``repro.core.migrate.GrowthPolicy``) puts the filter's
    table under the auto-growth layer: a stream that outgrows the initial
    sizing grows the table instead of reporting FULL (dropped sequences),
    and ``dedup_forget`` churn compacts away tombstone buildup once the
    density threshold trips.  Host-side only (see ``repro.core.migrate``);
    the default ``policy=None`` keeps the fixed-capacity jittable path.
    """
    from repro.core import counting
    from repro.core.common import STATUS_INSERTED
    fps = sequence_fingerprints(tokens)
    if policy is not None:
        table, status = counting.insert_or_grow(table, fps, policy=policy)
    else:
        table, status = counting.insert(table, fps)
    return table, status == STATUS_INSERTED


def dedup_forget(table, tokens: jax.Array):
    """Forget sequences: erase their fingerprints from the dedup table.

    Sliding-window dedup — a retention pass drops expired batches so
    their sequences may appear again.  Erasure tombstones the slots
    (paper §IV-B.5); under sustained churn tombstones accumulate and tax
    every probe walk, which is exactly the trigger
    ``dedup_filter(policy=...)`` compacts on.  Returns
    (table, forgotten_mask).
    """
    from repro.core import single_value as sv
    fps = sequence_fingerprints(tokens)
    return sv.erase(table, fps)


# ---------------------------------------------------------------------------
# relational stage: dedup -> join -> aggregate, entirely on device
# ---------------------------------------------------------------------------

def build_watchlist(tracked_tokens):
    """Precompute the deduplicated join build table for a token watchlist.

    ``relational_stage`` accepts the result in place of the raw token
    array — do this once per run so the per-batch hot path only probes.
    """
    from repro.relational import distinct, join
    tracked = jnp.asarray(tracked_tokens, jnp.uint32)
    _, fresh = distinct.first_occurrence(
        distinct.create(max(2 * tracked.shape[0], 32)), tracked)
    table, _ = join.build(tracked, mask=fresh)
    return table


def relational_stage(dedup_table, tokens: jax.Array, tracked_tokens,
                     pair_capacity: int | None = None, tracer=None):
    """Run a batch through a dedup -> join -> aggregate chain on device.

    The paper's pitch is "data processing pipelines entirely on the GPU"
    (§I); this stage is that pipeline, built from repro.relational:

    1. **dedup** — drop sequences whose fingerprint is already in
       ``dedup_table`` (cross-batch memory, same table ``dedup_filter``
       uses);
    2. **join** — inner hash join of the kept token stream against the
       ``tracked_tokens`` watchlist (build side): every (tracked token,
       stream position) hit becomes an output pair;
    3. **aggregate** — group-by count of the hits per sequence, giving a
       per-sequence tracked-token count without leaving the device.

    Returns ``(dedup_table, keep_mask, hits_per_seq)`` where
    ``hits_per_seq`` is (batch,) int32 (zero for dropped sequences).
    ``pair_capacity`` bounds the join output (default: every stream
    position matches once — safe because the build side is deduplicated,
    so each position joins at most one watchlist row).

    ``tracked_tokens`` may be a raw token array (build table constructed
    in-line, convenient for one-offs) or a prebuilt ``build_watchlist``
    table (probe-only per batch — use this on the training hot path).

    ``tracer`` (an ``obs.trace.Tracer``) wraps each stage in a wall-time
    span (``pipeline.dedup`` / ``pipeline.join`` / ``pipeline.aggregate``);
    spans block on stage outputs so they measure real device time.  Omit
    it (the default) for the fully-async hot path.
    """
    from repro.core.multi_value import MultiValueHashTable
    from repro.obs.trace import Tracer
    from repro.relational import groupby, join

    if tracer is None:
        tracer = Tracer(enabled=False)
    batch, seq_len = tokens.shape
    with tracer.span("pipeline.dedup", batch=batch):
        dedup_table, keep = dedup_filter(dedup_table, tokens)
        if tracer.enabled:
            jax.block_until_ready(keep)

    with tracer.span("pipeline.join", n_probe=batch * seq_len):
        flat = tokens.reshape(-1).astype(jnp.uint32)
        stream_mask = jnp.broadcast_to(keep[:, None], tokens.shape).reshape(-1)
        if pair_capacity is None:
            pair_capacity = batch * seq_len
        if not isinstance(tracked_tokens, MultiValueHashTable):
            tracked_tokens = build_watchlist(tracked_tokens)
        res = join.probe(tracked_tokens, flat, pair_capacity, "inner",
                         mask=stream_mask)
        if tracer.enabled:
            jax.block_until_ready(res.valid)

    with tracer.span("pipeline.aggregate", groups=batch):
        seq_of_pair = jnp.where(res.valid, res.probe_idx // seq_len, 0)
        table = groupby.create(groupby.capacity_for(batch))
        table, _ = groupby.update(table, "count",
                                  seq_of_pair.astype(jnp.uint32),
                                  mask=res.valid)
        hits, _ = groupby.lookup(table, "count",
                                 jnp.arange(batch, dtype=jnp.uint32))
        if tracer.enabled:
            jax.block_until_ready(hits)
    return dedup_table, keep, hits.astype(jnp.int32)
