"""Packed-u64 sort lane parity (``bulk._sort_batch``).

Two-word keys (u64 two-plane and composite kw=2) fuse their sort planes
into one ``plane0 << 32 | plane1`` uint64 word when the config sorts
genuine uint64 (``compat.supports_u64_sort`` — x64 on).  The packed word
compares exactly like the two-plane lexicographic pair, so EVERYTHING
downstream of the general dedup lane — group structure, insert/update
table state, statuses, fused retrieval layout, join pairs — must be
bit-identical between the two lanes.  These tests run each op on the
default config (two-plane lane) and again under
``jax.experimental.enable_x64`` (packed lane) and diff the u32 outputs.
"""

import numpy as np
import pytest

import jax
import jax.experimental
import jax.numpy as jnp

from repro.core import bulk
from repro.core import compat
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.relational import join as rjoin

_U = jnp.uint32


def _x64():
    return jax.experimental.enable_x64()


def _np(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


def test_lane_detection_tracks_x64():
    assert not compat.supports_u64_sort()
    with _x64():
        assert compat.supports_u64_sort()
    assert not compat.supports_u64_sort()


def test_sort_batch_bit_exact():
    rng = np.random.default_rng(7)
    n = 4096
    # tiny universes: heavy duplicate groups, shared-lo and shared-hi keys
    keys = rng.integers(0, 40, size=(n, 2)).astype(np.uint32)
    mask = rng.random(n) < 0.85
    pay = rng.integers(0, 2**31, size=(n,)).astype(np.uint32)
    args = (jnp.asarray(keys), jnp.asarray(mask), [jnp.asarray(pay)])
    ref = _np(bulk._sort_batch(*args))
    with _x64():
        got = _np(bulk._sort_batch(*args))
    for r, g in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(r, g)


def _composite_batch(rng, n):
    hi = rng.integers(0, 5, n).astype(np.uint32)
    lo = rng.integers(1, 9, n).astype(np.uint32)
    vals = rng.integers(0, 2**31, n).astype(np.uint32)
    return (jnp.asarray(hi), jnp.asarray(lo)), jnp.asarray(vals)


def test_single_value_insert_update_bit_exact():
    rng = np.random.default_rng(11)
    keys, vals = _composite_batch(rng, 600)
    mask = jnp.asarray(rng.random(600) < 0.9)

    def run():
        t = sv.create(2048, key_words=2)
        t, st = sv.insert(t, keys, vals, mask=mask)
        t, st2 = sv.update_values(t, keys, lambda old, k, v: old + v, 0,
                                  values=vals, combine=("add",))
        got, found = sv.retrieve(t, keys)
        return _np((t.store, t.count, st, st2, got, found))

    ref = run()
    with _x64():
        got = run()
    for r, g in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(r, g)


def test_multi_value_retrieve_bit_exact():
    rng = np.random.default_rng(13)
    keys, vals = _composite_batch(rng, 500)

    def run():
        t = mv.create(2048, key_words=2)
        t, st = mv.insert(t, keys, vals)
        out, off, cnt = mv.retrieve_all(t, keys, out_capacity=2048)
        return _np((st, out, off, cnt))

    ref = run()
    with _x64():
        got = run()
    for r, g in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(r, g)


@pytest.mark.parametrize("how", ["inner", "left"])
def test_composite_join_bit_exact(how):
    rng = np.random.default_rng(17)
    bkeys, bvals = _composite_batch(rng, 400)
    pkeys, _ = _composite_batch(rng, 300)

    def run():
        t, _ = rjoin.build(bkeys, capacity=2048, key_words=2)
        res = rjoin.probe(t, pkeys, 4096, how)
        return _np((res.build_idx, res.probe_idx, res.valid, res.matched,
                    res.total))

    ref = run()
    with _x64():
        got = run()
    for r, g in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(r, g)
