"""Serving: paged KV cache (hash-table page table), generation loops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_zoo as zoo
from repro.serving import kv_cache as pkv
from repro.serving import serve_loop


class TestPagedKVCache:
    def test_allocation_and_gather(self):
        c = pkv.create(num_layers=2, num_pages=64, page_size=4,
                       num_kv_heads=2, head_dim=8)
        seq = jnp.asarray([5, 9, 77], jnp.int32)
        for pos in range(10):
            k = jnp.full((2, 3, 2, 8), pos + 1, jnp.bfloat16)
            v = jnp.full((2, 3, 2, 8), -(pos + 1.0), jnp.bfloat16)
            c = pkv.append_token(c, seq, jnp.full((3,), pos, jnp.int32), k, v)
        assert int(c.free_top) == 9            # 3 seqs x ceil(10/4) pages
        k, v = pkv.gather_kv(c, seq, max_len=10)
        assert k.shape == (2, 3, 10, 2, 8)
        np.testing.assert_array_equal(
            np.asarray(k.astype(jnp.float32))[0, 0, :, 0, 0],
            np.arange(1, 11))

    def test_allocation_idempotent(self):
        c = pkv.create(num_layers=1, num_pages=16, page_size=4,
                       num_kv_heads=1, head_dim=4)
        seq = jnp.asarray([3, 3, 4], jnp.int32)
        page = jnp.asarray([0, 0, 0], jnp.int32)
        c, phys, ok = pkv.allocate_pages(c, seq, page)
        assert bool(jnp.all(ok))
        assert int(phys[0]) == int(phys[1])    # same (seq, page) -> same page
        assert int(phys[0]) != int(phys[2])
        assert int(c.free_top) == 2

    def test_free_sequences_tombstones(self):
        c = pkv.create(num_layers=1, num_pages=16, page_size=4,
                       num_kv_heads=1, head_dim=4)
        seq = jnp.asarray([1, 2], jnp.int32)
        c, _, _ = pkv.allocate_pages(c, seq, jnp.zeros((2,), jnp.int32))
        c, freed = pkv.free_sequences(c, seq[:1], max_pages=2)
        assert int(freed) == 1
        _, found = pkv.lookup_pages(c, seq, jnp.zeros((2,), jnp.int32))
        assert not bool(found[0]) and bool(found[1])

    def test_page_table_is_warpcore_table(self):
        from repro.core.single_value import SingleValueHashTable
        c = pkv.create(num_layers=1, num_pages=8, page_size=2,
                       num_kv_heads=1, head_dim=2)
        assert isinstance(c.page_table, SingleValueHashTable)

    def test_sequence_flood_zero_full_under_growth(self):
        """The example's flood scenario: an undersized page table with an
        auto-growth policy absorbs a sequence flood with ZERO allocation
        failures — the table grows online until the physical pages (not
        the table) are the limit."""
        import importlib.util
        import pathlib
        path = (pathlib.Path(__file__).parent.parent / "examples"
                / "paged_serving.py")
        spec = importlib.util.spec_from_file_location("paged_serving", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        tally = mod.sequence_flood(num_pages=256, waves=8, batch=32)
        assert tally["failures"] == 0
        assert tally["allocated"] == 256           # every physical page
        assert tally["free_top"] == 256
        assert tally["capacity_after"] > tally["capacity_before"]


class TestGeneration:
    @pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-3b",
                                      "jamba-1.5-large-398b"])
    def test_generate_shapes_and_determinism(self, arch):
        cfg = configs.get_smoke_config(arch)
        model = zoo.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        out1 = serve_loop.generate(model, params, prompts, 6)
        out2 = serve_loop.generate(model, params, prompts, 6)
        assert out1.shape == (2, 6)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert (np.asarray(out1) < cfg.vocab_size).all()

    def test_prefill_path_matches_decode_warmup(self):
        """Dense prefill+decode == pure decode-scan generation."""
        cfg = configs.get_smoke_config("olmo-1b")
        model = zoo.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        out_prefill = serve_loop.generate(model, params, prompts, 5)
        # force the warmup path by hiding prefill
        import dataclasses
        model_nopf = dataclasses.replace(model, prefill=None)
        out_scan = serve_loop.generate(model_nopf, params, prompts, 5)
        np.testing.assert_array_equal(np.asarray(out_prefill),
                                      np.asarray(out_scan))
