"""High-load-factor regression suite (the rho -> 1 collapse fix).

Two families of regressions are pinned here:

1. **Probe-coverage clamp** — every walk's budget is clamped to the
   scheme's distinct-row coverage (``probing.effective_probes``), so a
   quadratic table fills past 50% without spurious FULL statuses (the
   revisit bug: l^2 == (p-l)^2 mod p halves quadratic coverage, and an
   unclamped budget burned attempts on revisited rows).

2. **Bucketed two-choice storage lane** — insert -> erase -> retrieve
   round trips at rho in {0.90, 0.95} across table kinds (single-value
   cops / bucketed / bucketedq-quotient, multi-value cops / bucketed)
   stay BIT-EXACT between the jax engine and the sequential scan
   reference, probe walks stay <= 2 buckets (``probe_len_p99`` via
   ``stats=True``), and the quotient lane stores < one u32 word of key
   per slot (``BucketedOps.bits_per_slot``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import probing
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_UPDATED,
)

RHOS = (0.90, 0.95)
N = 512


def _keys(n, seed=0):
    rng = np.random.default_rng(seed)
    u = rng.choice(np.arange(1, 32 * n, dtype=np.uint32), n, replace=False)
    return jnp.asarray(u), jnp.asarray(u ^ np.uint32(0x5A5A))


def _sv_pair(capacity, kind_kw):
    tj = sv.create(capacity, window=8, **kind_kw)
    ts = sv.create(capacity, window=8, backend="scan", **kind_kw)
    return tj, ts


SV_KINDS = {
    "cops": dict(scheme="cops", max_probes=4096),
    "bucketed": dict(kind="bucketed"),
    "bucketedq": dict(kind="bucketed", quotient=True),
}


class TestHighLoadRoundTripParity:
    """insert -> erase -> retrieve at rho 0.90/0.95: jax vs scan bit-exact,
    and both agree with the python dict model on every surviving key."""

    @pytest.mark.parametrize("rho", RHOS)
    @pytest.mark.parametrize("kind", sorted(SV_KINDS))
    def test_single_value(self, rho, kind):
        keys, vals = _keys(N, seed=int(rho * 100))
        capacity = int(N / rho)
        tj, ts = _sv_pair(capacity, SV_KINDS[kind])
        tj, st_j = sv.insert(tj, keys, vals)
        ts, st_s = sv.insert(ts, keys, vals)
        np.testing.assert_array_equal(np.asarray(st_j), np.asarray(st_s))
        landed = np.asarray(st_j) <= STATUS_UPDATED
        # the two-choice lane may legitimately report bounded-eviction
        # FULLs at rho 0.95; the walks above must land everything
        if kind == "cops":
            assert landed.all(), f"spurious FULL at rho={rho}"
        else:
            assert landed.mean() > 0.95
        model = {int(k): int(v) for k, v, ok in
                 zip(np.asarray(keys), np.asarray(vals), landed) if ok}
        # erase a third, round-trip the rest
        ek = keys[::3]
        tj, er_j = sv.erase(tj, ek)
        ts, er_s = sv.erase(ts, ek)
        np.testing.assert_array_equal(np.asarray(er_j), np.asarray(er_s))
        for k in np.asarray(ek):
            model.pop(int(k), None)
        got_j, found_j = sv.retrieve(tj, keys)
        got_s, found_s = sv.retrieve(ts, keys)
        np.testing.assert_array_equal(np.asarray(found_j),
                                      np.asarray(found_s))
        np.testing.assert_array_equal(
            np.where(np.asarray(found_j), np.asarray(got_j), 0),
            np.where(np.asarray(found_s), np.asarray(got_s), 0))
        for i, k in enumerate(np.asarray(keys)):
            assert bool(found_j[i]) == (int(k) in model)
            if int(k) in model:
                assert int(got_j[i]) == model[int(k)]
        # key planes bit-exact too (placement, not just answers)
        for pj, ps in zip(tj.key_planes(), ts.key_planes()):
            np.testing.assert_array_equal(np.asarray(pj), np.asarray(ps))

    @pytest.mark.parametrize("rho", RHOS)
    @pytest.mark.parametrize("kind_kw", [dict(scheme="cops",
                                              max_probes=4096),
                                         dict(kind="bucketed")],
                             ids=["cops", "bucketed"])
    def test_multi_value(self, rho, kind_kw):
        keys, vals = _keys(N, seed=int(rho * 7))
        capacity = int(N / rho)
        tj = mv.create(capacity, window=8, **kind_kw)
        ts = mv.create(capacity, window=8, backend="scan", **kind_kw)
        tj, st_j = mv.insert(tj, keys, vals)
        ts, st_s = mv.insert(ts, keys, vals)
        np.testing.assert_array_equal(np.asarray(st_j), np.asarray(st_s))
        ek = keys[::4]
        tj, ec_j = mv.erase(tj, ek)
        ts, ec_s = mv.erase(ts, ek)
        np.testing.assert_array_equal(np.asarray(ec_j), np.asarray(ec_s))
        cnt_j = mv.count_values(tj, keys)
        cnt_s = mv.count_values(ts, keys)
        np.testing.assert_array_equal(np.asarray(cnt_j), np.asarray(cnt_s))
        cap = int(jnp.sum(cnt_j)) + 1
        out_j, off_j, _ = mv.retrieve_all(tj, keys, cap)
        out_s, off_s, _ = mv.retrieve_all(ts, keys, cap)
        np.testing.assert_array_equal(np.asarray(out_j), np.asarray(out_s))
        np.testing.assert_array_equal(np.asarray(off_j), np.asarray(off_s))


class TestBucketedProbeLength:
    """The two-choice walk is length <= 2 at ANY load factor — the flat
    retrieve-throughput claim, pinned via the stats=True telemetry."""

    @pytest.mark.parametrize("quotient", [False, True],
                             ids=["plain", "quotient"])
    def test_probe_len_p99_at_rho95(self, quotient):
        keys, vals = _keys(N, seed=3)
        t = sv.create(int(N / 0.95), window=8, kind="bucketed",
                      quotient=quotient)
        t, _ = sv.insert(t, keys, vals)
        _, _, stats = sv.retrieve(t, keys, stats=True)
        assert float(stats.as_dict()["probe_len_p99"]) <= 2.0

    def test_cops_probe_len_grows(self):
        """Contrast: the cops walk's p99 exceeds the bucketed bound at
        rho 0.95 (the collapse the bucketed lane exists to avoid)."""
        keys, vals = _keys(N, seed=3)
        t = sv.create(int(N / 0.95), window=8, scheme="cops",
                      max_probes=4096)
        t, _ = sv.insert(t, keys, vals)
        _, _, stats = sv.retrieve(t, keys, stats=True)
        assert float(stats.as_dict()["probe_len_p99"]) > 2.0


class TestQuadraticCoverageClamp:
    """Satellite bugfix: quadratic probing reaches only (p+1)/2 distinct
    rows (l^2 == (p-l)^2 mod p).  The budget clamp makes the walk spend
    its attempts on distinct rows, so a quadratic table fills past 50%
    of capacity without spurious FULL statuses."""

    def test_fill_past_half_no_spurious_full(self):
        capacity = 1024
        t = sv.create(capacity, window=8, scheme="quadratic")
        n = int(capacity * 0.6)                 # past the 50% mark
        keys, vals = _keys(n, seed=11)
        t, status = sv.insert(t, keys, vals)
        status = np.asarray(status)
        assert (status <= STATUS_UPDATED).all(), \
            f"{int((status == STATUS_FULL).sum())} spurious FULLs"
        _, found = sv.retrieve(t, keys)
        assert np.asarray(found).all()

    def test_effective_probes_clamp(self):
        p = 101
        assert probing.effective_probes("quadratic", 4096, p) == (p + 1) // 2
        assert probing.effective_probes("bucketed", 4096, p) == 2
        assert probing.effective_probes("cops", 50, p) == 50
        assert probing.effective_probes("cops", 4096, p) == p
        # degenerate geometry never clamps to zero
        assert probing.effective_probes("bucketed", 4096, 1) == 1

    def test_insert_matches_retrieve_budget(self):
        """The insert walk and the retrieve walk see the same clamped
        budget — a key that was placed is always found again."""
        capacity = 512
        for scheme in ("quadratic", "linear", "cops", "bucketed"):
            kw = dict(kind="bucketed") if scheme == "bucketed" else \
                dict(scheme=scheme, max_probes=4096)
            t = sv.create(capacity, window=8, **kw)
            keys, vals = _keys(200, seed=5)
            t, status = sv.insert(t, keys, vals)
            landed = np.asarray(status) <= STATUS_UPDATED
            _, found = sv.retrieve(t, keys)
            np.testing.assert_array_equal(np.asarray(found), landed)


class TestQuotientStorage:
    """Compact hashing: the quotient lane stores < one u32 word of key
    per slot and still decodes every key exactly (no false positives)."""

    def test_bits_per_slot_below_32(self):
        for capacity in (128, 1024, 1 << 14):
            t = sv.create(capacity, kind="bucketed", quotient=True)
            assert t.ops.bits_per_slot < 32, \
                f"{t.ops.bits_per_slot} bits at p={t.num_rows}"

    def test_no_false_positives(self):
        keys, vals = _keys(N, seed=9)
        t = sv.create(int(N / 0.9), window=8, kind="bucketed",
                      quotient=True)
        t, status = sv.insert(t, keys, vals)
        absent = jnp.asarray(
            np.setdiff1d(np.arange(1, 4 * N, dtype=np.uint32),
                         np.asarray(keys))[:N])
        _, found = sv.retrieve(t, absent)
        assert not np.asarray(found).any()

    def test_multi_value_rejects_quotient(self):
        with pytest.raises(ValueError):
            mv.create(256, kind="bucketed", quotient=True)
