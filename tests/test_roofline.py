"""Roofline machinery: HLO census on known programs, collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_census, roofline


def test_census_counts_scan_trip_multipliers():
    """A scan of matmuls must be counted trip_count times."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    compiled = jax.jit(f).lower(w, x).compile()
    cen = hlo_census.census(compiled.as_text())
    expected = 2 * 8 * 64 * 64 * 10                # 10 matmul trips
    assert expected * 0.9 <= cen.flops <= expected * 1.3, cen.flops
    assert 10 in cen.loops


def test_census_no_loops_single_matmul():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    cen = hlo_census.census(compiled.as_text())
    expected = 2 * 128 * 256 * 512
    assert expected * 0.99 <= cen.flops <= expected * 1.01


def test_collective_wire_formulas():
    hlo = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[4,8]<=[32], to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups=[4,8]<=[32], dimensions={0}
}
"""
    cen = hlo_census.census(hlo, default_group=8)
    bytes_ = 1024 * 4
    want = 2 * bytes_ * 7 / 8 + bytes_ * 7 / 8
    assert abs(cen.wire_bytes - want) < 1
    assert cen.coll_counts == {"all-reduce": 1, "all-gather": 1}


def test_model_flops_conventions():
    from repro import configs
    from repro.configs.base import SHAPES
    cfg = configs.get_config("smollm-360m")
    n = cfg.active_param_count()
    t = SHAPES["train_4k"]
    assert roofline.model_flops_for(cfg, t) == pytest.approx(
        6 * n * 4096 * 256)
    d = SHAPES["decode_32k"]
    assert roofline.model_flops_for(cfg, d) == pytest.approx(2 * n * 128)


def test_moe_uses_active_params():
    from repro import configs
    from repro.configs.base import SHAPES
    cfg = configs.get_config("deepseek-v2-236b")
    mf = roofline.model_flops_for(cfg, SHAPES["train_4k"])
    assert mf < 6 * cfg.param_count() * 4096 * 256 * 0.2   # active << total


def test_analyze_end_to_end_tiny():
    """Full analyze() on a tiny jitted train-ish step."""
    def step(w, x):
        def loss(w):
            h = x
            for _ in range(2):
                h = jnp.tanh(h @ w)
            return jnp.sum(h * h)
        g = jax.grad(loss)(w)
        return w - 0.1 * g

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    compiled = jax.jit(step).lower(w, x).compile()
    rl = roofline.analyze(compiled, chips=1, model_flops=1e6)
    assert rl.compute_s > 0 and rl.memory_s > 0
    assert rl.bottleneck in ("compute", "memory", "collective")
