"""Multi-device tests (8 host devices, run in subprocesses so the main
pytest process keeps its single real device — see conftest note).

All mesh/shard_map construction goes through the jax-version shims in
``repro.core.compat`` (re-exported by ``repro.distributed.sharding``) so
the same tests pass on the container's jax 0.4.x and on current jax.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src"}


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=540,
                       env=_ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


class TestDistributedTables:
    def test_distributed_mode_insert_retrieve(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import distributed as dist
            from repro.core.compat import make_mesh_compat
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 2048, window=16)
            n = 8 * 512
            keys = jnp.asarray(np.random.default_rng(0).permutation(
                np.arange(1, n + 1, dtype=np.uint32)))
            vals = keys * 3
            table, status, ov = dist.shard_insert(mesh, 'x', table, keys, vals)
            assert int(np.asarray(ov).sum()) == 0, 'exchange overflow'
            assert (np.asarray(status) != 2).all()
            got, found, _ = dist.shard_retrieve(mesh, 'x', table, keys)
            assert np.asarray(found).all()
            assert (np.asarray(got) == np.asarray(vals)).all()
            miss, mf, _ = dist.shard_retrieve(
                mesh, 'x', table,
                jnp.arange(n + 10, n + 10 + n, dtype=jnp.uint32))
            assert not np.asarray(mf).any()
            print('OK')
        """)
        assert "OK" in out

    def test_single_owner_invariant(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import distributed as dist
            from repro.core.common import EMPTY_KEY, TOMBSTONE_KEY
            from repro.core.compat import make_mesh_compat
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 1024, window=16)
            keys = jnp.arange(1, 2001, dtype=jnp.uint32)
            table, _, ov = dist.shard_insert(mesh, 'x', table, keys, keys)
            assert int(np.asarray(ov).sum()) == 0
            kp = np.asarray(table.key_planes())[:, 0]   # (8, p, W)
            seen = {}
            for shard in range(8):
                live = kp[shard][(kp[shard] != EMPTY_KEY)
                                 & (kp[shard] != TOMBSTONE_KEY)]
                for k in live.tolist():
                    assert k not in seen, f'key {k} on two shards'
                    seen[k] = shard
            assert len(seen) == 2000
            # owners match hash_owner
            from repro.core import hashing
            owners = np.asarray(hashing.hash_owner(keys, 8))
            for k, o in zip(np.asarray(keys).tolist(), owners.tolist()):
                assert seen[k] == o
            print('OK')
        """)
        assert "OK" in out

    def test_independent_mode(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core import distributed as dist, single_value as sv
            from repro.core.compat import make_mesh_compat, shard_map_compat
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 1024, window=16)
            n = 8 * 64
            keys = jnp.arange(1, n + 1, dtype=jnp.uint32)
            vals = keys * 5
            spec = jax.tree.map(lambda _: P('x'), table)
            def ins(t, k, v):
                tl = dist._local(t)
                tl, st = dist.insert_independent(tl, k, v)
                return dist._relift(tl), st
            f = shard_map_compat(ins, mesh, in_specs=(spec, P('x'), P('x')),
                                 out_specs=(spec, P('x')))
            table, st = f(table, keys, vals)
            def ret(t, k):
                return dist.retrieve_independent(dist._local(t), k, 'x')
            g = shard_map_compat(ret, mesh, in_specs=(spec, P('x')),
                                 out_specs=(P('x'), P('x')))
            got, found = g(table, keys)
            assert np.asarray(found).all()
            assert (np.asarray(got) == np.asarray(vals)).all()
            print('OK')
        """)
        assert "OK" in out

    def test_erase_distributed(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core import distributed as dist
            from repro.core.compat import make_mesh_compat, shard_map_compat
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 1024, window=16)
            n = 8 * 128
            keys = jnp.arange(1, n + 1, dtype=jnp.uint32)
            table, _, ov = dist.shard_insert(mesh, 'x', table, keys, keys)
            assert int(np.asarray(ov).sum()) == 0
            spec = jax.tree.map(lambda _: P('x'), table)
            def er(t, k):
                tl, erased, ov = dist.erase_distributed(dist._local(t), k, 'x')
                return dist._relift(tl), erased, ov[None]
            f = shard_map_compat(er, mesh, in_specs=(spec, P('x')),
                                 out_specs=(spec, P('x'), P('x')))
            half = keys[:n // 2]
            pad = jnp.concatenate([half, jnp.arange(
                2 * n, 2 * n + n // 2, dtype=jnp.uint32)])
            table, erased, ov = f(table, pad)
            assert int(np.asarray(ov).sum()) == 0
            assert np.asarray(erased)[:n // 2].all()
            got, found, _ = dist.shard_retrieve(mesh, 'x', table, keys)
            found = np.asarray(found)
            assert not found[:n // 2].any() and found[n // 2:].all()
            print('OK')
        """)
        assert "OK" in out


class TestGradSyncCompression:
    def test_int8_cross_pod_sync(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed import collectives
            from repro.training import compression as comp
            from repro.core.compat import make_mesh_compat, set_mesh_compat
            mesh = make_mesh_compat((2, 4), ('pod', 'data'))
            sync = collectives.make_grad_sync(
                mesh, comp.CompressionConfig(kind='int8'))
            g = {'w': jnp.asarray(np.random.default_rng(0).normal(
                size=(64, 64)).astype(np.float32))}
            with set_mesh_compat(mesh):
                out = jax.jit(sync)(g)
            np.testing.assert_allclose(np.asarray(out['w']),
                                       np.asarray(g['w']), atol=0.05)
            print('OK')
        """)
        assert "OK" in out

    def test_none_sync_is_mean(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed import collectives
            from repro.training import compression as comp
            from repro.core.compat import make_mesh_compat, set_mesh_compat
            mesh = make_mesh_compat((2, 4), ('pod', 'data'))
            sync = collectives.make_grad_sync(
                mesh, comp.CompressionConfig(kind='none'))
            g = {'w': jnp.ones((8, 8), jnp.float32)}
            with set_mesh_compat(mesh):
                out = jax.jit(sync)(g)
            np.testing.assert_allclose(np.asarray(out['w']), 1.0)
            print('OK')
        """)
        assert "OK" in out


class TestElastic:
    def test_kill_and_resume_on_smaller_mesh(self):
        out = _run("""
            import repro.launch.elastic as el
            import sys
            sys.exit(el.main(['--steps', '16', '--kill-at', '8']))
        """)
        assert "elastic restart OK" in out


class TestPipelineParallel:
    def test_pipelined_forward_matches_sequential(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.distributed import pipeline_parallel as pp
            from repro.core.compat import make_mesh_compat, shard_map_compat
            mesh = make_mesh_compat((4,), ('pod',))
            L, D, M, mb = 8, 16, 8, 4
            key = jax.random.PRNGKey(0)
            blocks = {'w': jax.random.normal(key, (L, D, D)) * 0.1}
            x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, D))
            def block_fn(blk, h):
                return jnp.tanh(h @ blk['w'])
            # sequential reference
            ref = x
            for i in range(L):
                ref = block_fn({'w': blocks['w'][i]}, ref)
            staged = pp.stage_params(blocks, 4)
            spec = jax.tree.map(lambda _: P('pod'), staged)
            f = shard_map_compat(
                lambda s, xx: pp.pipelined_apply(block_fn, s, xx, 'pod'),
                mesh, in_specs=(spec, P()), out_specs=P())
            out = f(staged, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
            print('OK')
        """)
        assert "OK" in out
