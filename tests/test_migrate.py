"""Online growth + tombstone compaction (repro.core.migrate).

The robustness contract: no table hard-fails under sustained churn.
Covers the migration engine (grow/compact bit-exact on the live set for
all three table kinds), the policy layer (insert_or_grow retries FULL
after growth; maybe_migrate trips on load factor / tombstone density),
the registry counters, erase-slot bookkeeping exactness across both
backends, the kv-cache free-list fix (no aliasing on exhaustion), and
the pipeline dedup churn loop.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_list as bl
from repro.core import counting
from repro.core import migrate
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_POOL_FULL,
)
from repro.obs import metrics
from repro.obs.registry import REGISTRY

_U = jnp.uint32


def _keys(n, start=1):
    return jnp.arange(start, start + n, dtype=_U)


class TestGrowCompactSingleValue:
    def _churned(self):
        t = sv.create(256, window=8)
        t, _ = sv.insert(t, _keys(100), _keys(100) * 3)
        t, er = sv.erase(t, _keys(40))            # keys 1..40 tombstoned
        assert np.asarray(er).all()
        return t

    def test_compact_drops_tombstones_preserves_live(self):
        t = self._churned()
        _, tomb0, _ = metrics.slot_stats(t.ops, t.store)
        assert int(tomb0) == 40
        c = migrate.compact(t)
        assert c.capacity == t.capacity
        live, tomb, _ = metrics.slot_stats(c.ops, c.store)
        assert int(tomb) == 0 and int(live) == 60
        assert int(c.count) == 60
        got, found = sv.retrieve(c, _keys(100))
        np.testing.assert_array_equal(np.asarray(found),
                                      np.arange(1, 101) > 40)
        np.testing.assert_array_equal(np.asarray(got)[40:],
                                      np.arange(41, 101) * 3)

    def test_grow_preserves_live_set(self):
        t = self._churned()
        g = migrate.grow(t, 4096)
        assert g.capacity >= 4096 > t.capacity
        live, tomb, _ = metrics.slot_stats(g.ops, g.store)
        assert int(tomb) == 0 and int(live) == 60
        got, found = sv.retrieve(g, _keys(60, start=41))
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(got),
                                      np.arange(41, 101) * 3)
        # erased keys stay erased
        _, dfound = sv.retrieve(g, _keys(40))
        assert not np.asarray(dfound).any()

    def test_grow_shrink_guard(self):
        t = sv.create(1024, window=8)
        t, _ = sv.insert(t, _keys(500), _keys(500))
        with pytest.raises(ValueError):
            migrate.grow(t, 256)                  # would drop live keys

    def test_counters(self):
        t = self._churned()
        g0 = REGISTRY.counter("table.grows").value
        c0 = REGISTRY.counter("table.compactions").value
        m0 = REGISTRY.counter("table.migrated_slots").value
        t = migrate.grow(t, 2048)
        t = migrate.compact(t)
        assert REGISTRY.counter("table.grows").value == g0 + 1
        assert REGISTRY.counter("table.compactions").value == c0 + 1
        assert REGISTRY.counter("table.migrated_slots").value == m0 + 120


class TestGrowCompactMultiValue:
    def test_fanout_and_multisets_preserved(self):
        t = mv.create(512, window=8)
        ks = jnp.repeat(_keys(30), 3)             # 30 keys x 3 values
        vs = jnp.arange(90, dtype=_U) * 7
        t, _ = mv.insert(t, ks, vs)
        t, ecnt = mv.erase(t, _keys(10))          # drop keys 1..10 entirely
        np.testing.assert_array_equal(np.asarray(ecnt), 3)
        for fresh in (migrate.grow(t, 2048), migrate.compact(t)):
            cnt = mv.count_values(fresh, _keys(30))
            np.testing.assert_array_equal(
                np.asarray(cnt), [0] * 10 + [3] * 20)
            out, off, _ = mv.retrieve_all(fresh, _keys(30), out_capacity=90)
            out, off = np.asarray(out), np.asarray(off)
            for i in range(10, 30):
                got = sorted(out[off[i]:off[i + 1]].tolist())
                want = sorted((np.arange(3 * i, 3 * i + 3) * 7).tolist())
                assert got == want


class TestGrowCompactBucketList:
    def _filled(self):
        t = bl.create(128, pool_capacity=512, s0=2, growth=1.5)
        ks = jnp.repeat(_keys(20), 4)             # per-key insertion order
        vs = jnp.arange(80, dtype=_U) + 100
        t, stt = bl.insert(t, ks, vs)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        return t

    @pytest.mark.parametrize("op", ["grow", "compact"])
    def test_retrieval_bit_identical(self, op):
        t = self._filled()
        fresh = (migrate.grow(t, 512) if op == "grow"
                 else migrate.compact(t))
        q = _keys(20)
        out0, off0, cnt0 = bl.retrieve_all(t, q, out_capacity=80)
        out1, off1, cnt1 = bl.retrieve_all(fresh, q, out_capacity=80)
        # values keep per-key insertion order bit-exactly (the migration
        # replays the chains as an ordered stream)
        np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
        np.testing.assert_array_equal(np.asarray(off0), np.asarray(off1))
        np.testing.assert_array_equal(np.asarray(cnt0), np.asarray(cnt1))
        # migration replays the chains as one ordered stream, so the
        # rebuilt pool follows the same bucket schedule as the original
        # single-batch build — no extra slack accumulates across cycles
        assert int(fresh.alloc_top) == int(t.alloc_top)

    def test_grow_pool_only(self):
        t = self._filled()
        g = migrate.grow(t, t.key_store.capacity, new_pool_capacity=2048)
        assert g.pool_capacity >= 2048
        out0, off0, _ = bl.retrieve_all(t, _keys(20), out_capacity=80)
        out1, off1, _ = bl.retrieve_all(g, _keys(20), out_capacity=80)
        np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
        np.testing.assert_array_equal(np.asarray(off0), np.asarray(off1))


class TestInsertOrGrow:
    def test_single_value_never_full(self):
        t = sv.create(64, window=8)
        policy = migrate.GrowthPolicy(max_load_factor=0.8, growth_factor=2.0)
        for b in range(8):
            t, stt = sv.insert_or_grow(t, _keys(64, start=1 + b * 64),
                                       _keys(64, start=1 + b * 64),
                                       policy=policy)
            assert not bool(jnp.any(stt == STATUS_FULL))
        assert int(t.count) == 512
        assert t.capacity > 512 / 0.8 * 0.99      # grew past the threshold
        got, found = sv.retrieve(t, _keys(512))
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(got), np.arange(1, 513))

    def test_counting_counts_survive_growth(self):
        t = counting.create(32, window=8)
        policy = migrate.GrowthPolicy(max_load_factor=0.7)
        for _ in range(3):                        # 3 occurrences of each key
            t, stt = counting.insert_or_grow(t, _keys(100), policy=policy)
            assert not bool(jnp.any(stt == STATUS_FULL))
        got, found = sv.retrieve(t, _keys(100))
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(got), 3)

    def test_bucket_list_pool_growth(self):
        t = bl.create(64, pool_capacity=16, s0=1, growth=2.0)
        policy = migrate.GrowthPolicy(max_load_factor=0.8)
        for b in range(4):
            vs = jnp.arange(32, dtype=_U) + b * 32
            t, stt = bl.insert_or_grow(t, jnp.repeat(_keys(8), 4), vs,
                                       policy=policy)
            assert not bool(jnp.any(stt == STATUS_POOL_FULL))
            assert not bool(jnp.any(stt == STATUS_FULL))
        assert t.pool_capacity > 16
        cnt = bl.count_values(t, _keys(8))
        np.testing.assert_array_equal(np.asarray(cnt), 16)

    def test_compacts_at_max_capacity(self):
        # at the cap, reclaim tombstones instead of growing
        t = sv.create(128, window=8)
        cap = t.capacity
        policy = migrate.GrowthPolicy(max_load_factor=0.9,
                                      max_capacity=cap)
        t, _ = sv.insert(t, _keys(100), _keys(100))
        t, _ = sv.erase(t, _keys(60))
        c0 = REGISTRY.counter("table.compactions").value
        t, stt = sv.insert_or_grow(t, _keys(60, start=200),
                                   _keys(60, start=200), policy=policy)
        assert not bool(jnp.any(stt == STATUS_FULL))
        assert t.capacity == cap                  # never exceeded the cap
        assert REGISTRY.counter("table.compactions").value > c0


class TestMaybeMigrate:
    def test_tombstone_density_trigger(self):
        t = sv.create(256, window=8)
        t, _ = sv.insert(t, _keys(150), _keys(150))
        t, _ = sv.erase(t, _keys(100))
        policy = migrate.GrowthPolicy(max_tombstone_density=0.2)
        fresh = migrate.maybe_migrate(t, policy)
        assert fresh is not t
        assert fresh.capacity == t.capacity       # compaction, not growth
        _, tomb, _ = metrics.slot_stats(fresh.ops, fresh.store)
        assert int(tomb) == 0

    def test_below_thresholds_noop(self):
        t = sv.create(256, window=8)
        t, _ = sv.insert(t, _keys(50), _keys(50))
        assert migrate.maybe_migrate(t, migrate.DEFAULT_POLICY) is t

    def test_load_factor_trigger_grows(self):
        t = sv.create(64, window=8)
        t, _ = sv.insert(t, _keys(60), _keys(60))
        policy = migrate.GrowthPolicy(max_load_factor=0.5)
        fresh = migrate.maybe_migrate(t, policy)
        assert fresh.capacity > t.capacity


class TestEraseSlotBookkeeping:
    """Satellite: erase on tombstoned/absent keys leaves the live and
    tombstone censuses exact — double-erase and erase-then-reinsert do
    not drift the counters, on either backend."""

    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_double_erase_exact(self, backend):
        t = sv.create(128, window=8, backend=backend)
        t, _ = sv.insert(t, _keys(30), _keys(30))
        t, er1 = sv.erase(t, _keys(30))
        assert np.asarray(er1).all()
        live1, tomb1, _ = metrics.slot_stats(t.ops, t.store)
        assert (int(live1), int(tomb1)) == (0, 30)
        assert int(t.count) == 0
        # erasing the same keys again: all report absent, census unchanged
        t, er2 = sv.erase(t, _keys(30))
        assert not np.asarray(er2).any()
        live2, tomb2, _ = metrics.slot_stats(t.ops, t.store)
        assert (int(live2), int(tomb2)) == (0, 30)
        assert int(t.count) == 0

    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_erase_absent_key_exact(self, backend):
        t = sv.create(128, window=8, backend=backend)
        t, _ = sv.insert(t, _keys(10), _keys(10))
        t, er = sv.erase(t, _keys(10, start=500))  # never inserted
        assert not np.asarray(er).any()
        live, tomb, _ = metrics.slot_stats(t.ops, t.store)
        assert (int(live), int(tomb)) == (10, 0)
        assert int(t.count) == 10

    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_erase_then_reinsert_exact(self, backend):
        t = sv.create(128, window=8, backend=backend)
        t, _ = sv.insert(t, _keys(20), _keys(20))
        t, _ = sv.erase(t, _keys(20))
        # reinsert reclaims each key's own tombstone: live back to 20,
        # tombstones back to 0 — no slot leaks in either direction
        t, stt = sv.insert(t, _keys(20), _keys(20) * 9)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        live, tomb, _ = metrics.slot_stats(t.ops, t.store)
        assert (int(live), int(tomb)) == (20, 0)
        assert int(t.count) == 20
        got, found = sv.retrieve(t, _keys(20))
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(got), np.arange(1, 21) * 9)

    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_multi_value_double_erase_exact(self, backend):
        t = mv.create(256, window=8, backend=backend)
        t, _ = mv.insert(t, jnp.repeat(_keys(10), 2),
                         jnp.arange(20, dtype=_U))
        t, e1 = mv.erase(t, _keys(10))
        np.testing.assert_array_equal(np.asarray(e1), 2)
        live1, tomb1, _ = metrics.slot_stats(t.ops, t.store)
        t, e2 = mv.erase(t, _keys(10))
        np.testing.assert_array_equal(np.asarray(e2), 0)
        live2, tomb2, _ = metrics.slot_stats(t.ops, t.store)
        assert (int(live1), int(tomb1)) == (int(live2), int(tomb2)) == (0, 20)
        assert int(t.count) == 0


class TestKVCacheAllocation:
    """Satellite: free-list exhaustion reports per-key failures instead
    of aliasing everything onto the last physical page."""

    def test_exhaustion_no_aliasing(self):
        from repro.serving import kv_cache as pkv
        c = pkv.create(num_layers=1, num_pages=4, page_size=4,
                       num_kv_heads=1, head_dim=4)
        full0 = REGISTRY.counter("kv_cache.alloc_full").value
        seq = jnp.arange(6, dtype=jnp.int32) + 10  # 6 seqs, 4 pages
        c, phys, ok = pkv.allocate_pages(c, seq, jnp.zeros((6,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(ok),
                                      [True] * 4 + [False] * 2)
        assert sorted(np.asarray(phys)[:4].tolist()) == [0, 1, 2, 3]
        assert int(c.free_top) == 4
        assert REGISTRY.counter("kv_cache.alloc_full").value == full0 + 2
        # the failed keys are NOT in the page table; a retry after a free
        # can still allocate them
        _, found = pkv.lookup_pages(c, seq, jnp.zeros((6,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(found),
                                      [True] * 4 + [False] * 2)

    def test_duplicate_keys_one_draw(self):
        from repro.serving import kv_cache as pkv
        c = pkv.create(num_layers=1, num_pages=8, page_size=4,
                       num_kv_heads=1, head_dim=4)
        seq = jnp.asarray([7, 7, 7, 8], jnp.int32)
        c, phys, ok = pkv.allocate_pages(c, seq, jnp.zeros((4,), jnp.int32))
        assert bool(jnp.all(ok))
        p = np.asarray(phys)
        assert p[0] == p[1] == p[2] != p[3]
        assert int(c.free_top) == 2               # distinct keys only

    def test_policy_grows_page_table(self):
        from repro.serving import kv_cache as pkv
        policy = migrate.GrowthPolicy(max_load_factor=0.8)
        c = pkv.create(num_layers=1, num_pages=512, page_size=4,
                       num_kv_heads=1, head_dim=4, table_slack=0.125,
                       policy=policy)
        cap0 = c.page_table.capacity
        for wave in range(8):
            seq = jnp.arange(64, dtype=jnp.int32) + wave * 64
            c, _, ok = pkv.allocate_pages(c, seq, jnp.zeros((64,), jnp.int32))
            assert bool(jnp.all(ok)), f"allocation failed in wave {wave}"
        assert int(c.free_top) == 512
        assert c.page_table.capacity > cap0       # the table grew


class TestPipelineChurn:
    def test_dedup_churn_compacts_and_never_fails(self):
        from repro.data import pipeline
        cfg = pipeline.DataConfig(vocab_size=64, seq_len=16, global_batch=32)
        policy = migrate.GrowthPolicy(max_load_factor=0.7,
                                      max_tombstone_density=0.15)
        table = counting.create(64, window=8)
        c0 = REGISTRY.counter("table.compactions").value
        window = []                               # sliding retention window
        for step in range(16):
            batch = pipeline.get_batch(cfg, step)
            table, keep = pipeline.dedup_filter(table, batch["tokens"],
                                                policy=policy)
            assert bool(jnp.any(keep))            # fresh data passes
            window.append(batch["tokens"])
            if len(window) > 3:
                table, _ = pipeline.dedup_forget(table, window.pop(0))
        # churn produced tombstones; the policy compacted at least once
        assert REGISTRY.counter("table.compactions").value > c0
        # the surviving window is still deduplicated exactly
        _, keep_again = pipeline.dedup_filter(table, window[-1],
                                              policy=policy)
        assert not bool(jnp.any(keep_again))
