"""Per-kernel allclose sweeps: Pallas (interpret mode) vs the pure-jnp
oracle in each kernel's ref.py — shapes, windows, load factors, dtypes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bloom as bloom_core
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.kernels.bloom import ops as bloom_ops
from repro.kernels.bloom import ref as bloom_ref
from repro.kernels.cops import ops as cops_ops
from repro.kernels.cops import ref as cops_ref
from repro.kernels.minhash import ops as mh_ops
from repro.kernels.minhash import ref as mh_ref


def _mk_pairs(rng, n):
    keys = rng.choice(np.arange(1, 8 * n, dtype=np.uint32), size=n,
                      replace=False)
    vals = rng.integers(0, 2 ** 32 - 1, n, dtype=np.uint32)
    return jnp.asarray(keys), jnp.asarray(vals)


class TestCopsKernel:
    @pytest.mark.parametrize("window", [8, 32, 128])
    @pytest.mark.parametrize("load", [0.5, 0.9])
    def test_insert_matches_ref(self, window, load):
        rng = np.random.default_rng(window)
        t_k = sv.create(2048, window=window, backend="pallas")
        t_r = sv.create(2048, window=window, backend="jax")
        n = int(t_k.capacity * load)
        keys, vals = _mk_pairs(rng, n)
        t_k, st_k = sv.insert(t_k, keys, vals)
        t_r, st_r = cops_ref.insert(t_r, keys, vals)
        np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
        for pk, pr in zip(jax.tree.leaves(t_k.store),
                          jax.tree.leaves(t_r.store)):
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        assert int(t_k.count) == int(t_r.count)

    @pytest.mark.parametrize("window", [16, 64])
    def test_lookup_matches_ref(self, window):
        rng = np.random.default_rng(7)
        t = sv.create(1024, window=window, backend="pallas")
        keys, vals = _mk_pairs(rng, 600)
        t, _ = sv.insert(t, keys, vals)
        queries = jnp.concatenate([keys[:300],
                                   jnp.arange(10 ** 6, 10 ** 6 + 300,
                                              dtype=jnp.uint32)])
        got_k, f_k = cops_ops.retrieve(t, queries)
        got_r, f_r = cops_ref.retrieve(t, queries)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(got_r))
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))

    def test_duplicate_keys_within_batch(self):
        """Sequential semantics: later duplicate upserts the earlier one."""
        t_k = sv.create(256, backend="pallas")
        t_r = sv.create(256, backend="jax")
        keys = jnp.asarray([5, 7, 5, 9, 5], jnp.uint32)
        vals = jnp.asarray([1, 2, 3, 4, 5], jnp.uint32)
        t_k, st_k = sv.insert(t_k, keys, vals)
        t_r, st_r = sv.insert(t_r, keys, vals)
        np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
        got, _ = cops_ops.retrieve(t_k, jnp.asarray([5], jnp.uint32))
        assert int(got[0]) == 5

    def test_linear_scheme_kernel(self):
        rng = np.random.default_rng(2)
        t_k = sv.create(512, scheme="linear", window=16, backend="pallas")
        t_r = sv.create(512, scheme="linear", window=16, backend="jax")
        keys, vals = _mk_pairs(rng, 300)
        t_k, _ = sv.insert(t_k, keys, vals)
        t_r, _ = sv.insert(t_r, keys, vals)
        for pk, pr in zip(jax.tree.leaves(t_k.store),
                          jax.tree.leaves(t_r.store)):
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))

    @pytest.mark.parametrize("mult", [1, 4, 16])
    def test_multi_value_matches_ref(self, mult):
        rng = np.random.default_rng(mult)
        t_k = mv.create(4096, window=32, backend="pallas")
        t_r = mv.create(4096, window=32, backend="jax")
        base = rng.choice(np.arange(1, 4000, dtype=np.uint32), 150,
                          replace=False)
        keys = jnp.asarray(np.repeat(base, mult))
        vals = jnp.arange(150 * mult, dtype=jnp.uint32)
        t_k, st_k = mv.insert(t_k, keys, vals)
        t_r, st_r = cops_ref.insert_multi(t_r, keys, vals)
        np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
        for pk, pr in zip(jax.tree.leaves(t_k.store),
                          jax.tree.leaves(t_r.store)):
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))

    @pytest.mark.parametrize("window", [16, 32])
    def test_64bit_keys_kernel_matches_ref(self, window):
        """2-plane u64 keys on the kernel path (paper: beyond 32-bit)."""
        rng = np.random.default_rng(window)
        n = 600
        keys = np.unique(np.stack(
            [rng.integers(0, 2 ** 32 - 2, n, dtype=np.uint32),
             rng.integers(0, 2 ** 32 - 2, n, dtype=np.uint32)], axis=1), axis=0)
        vals = (keys[:, 0] ^ keys[:, 1]).astype(np.uint32)
        tk = sv.create(2048, key_words=2, window=window, backend="pallas")
        tr = sv.create(2048, key_words=2, window=window, backend="jax")
        tk, st_k = sv.insert(tk, jnp.asarray(keys), jnp.asarray(vals))
        tr, st_r = sv.insert(tr, jnp.asarray(keys), jnp.asarray(vals))
        np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
        for pk, pr in zip(jax.tree.leaves(tk.store),
                          jax.tree.leaves(tr.store)):
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        got, found = cops_ops.retrieve(tk, jnp.asarray(keys))
        assert found.all() and (np.asarray(got) == vals).all()

    def test_wider_value_fallback_dispatches_to_jax(self):
        """2-word values are outside the kernel contract -> pure-JAX path."""
        t = sv.create(512, key_words=1, value_words=2, backend="pallas")
        keys = jnp.arange(1, 101, dtype=jnp.uint32)
        vals = jnp.stack([keys, keys * 2], axis=1)
        t, st = sv.insert(t, keys, vals)
        got, f = sv.retrieve(dataclasses.replace(t, backend="jax"), keys)
        assert f.all()


class TestFlashKernel:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("shape", [(2, 256, 4, 2, 64), (1, 384, 2, 2, 32),
                                       (2, 128, 4, 4, 64)])
    def test_matches_naive_reference(self, causal, shape):
        from repro.kernels.flash import ops as fops, ref as fref
        b, s, h, hkv, hd = shape
        rng = np.random.default_rng(s + h)
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
        out = fops.flash_attention(q, k, v, causal=causal)
        rep = h // hkv
        qe = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        ke = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        ve = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        want = fref.attention(qe, ke, ve, causal=causal)
        want = want.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16_inputs(self):
        from repro.kernels.flash import ops as fops, ref as fref
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 256, 2, 64))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 256, 2, 64))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 256, 2, 64))).astype(jnp.bfloat16)
        out = fops.flash_attention(q, k, v)
        qe = q.transpose(0, 2, 1, 3).reshape(2, 256, 64)
        ke = k.transpose(0, 2, 1, 3).reshape(2, 256, 64)
        ve = v.transpose(0, 2, 1, 3).reshape(2, 256, 64)
        want = fref.attention(qe, ke, ve).reshape(1, 2, 256, 64)
        np.testing.assert_allclose(
            np.asarray(out.transpose(0, 2, 1, 3), np.float32),
            np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)


class TestBloomKernel:
    @pytest.mark.parametrize("k", [2, 4, 8])
    @pytest.mark.parametrize("n", [100, 3000])
    def test_states_and_queries_match_ref(self, k, n):
        f = bloom_core.create(1 << 13, k=k)
        keys = jnp.arange(1, n + 1, dtype=jnp.uint32)
        fk = bloom_ops.insert(f, keys)
        fr = bloom_ref.insert(f, keys)
        np.testing.assert_array_equal(np.asarray(fk.bits), np.asarray(fr.bits))
        q = jnp.arange(1, 2 * n + 1, dtype=jnp.uint32)
        np.testing.assert_array_equal(np.asarray(bloom_ops.contains(fk, q)),
                                      np.asarray(bloom_ref.contains(fr, q)))

    def test_masked_inserts(self):
        f = bloom_core.create(1 << 12, k=3)
        keys = jnp.arange(1, 101, dtype=jnp.uint32)
        mask = keys % 2 == 0
        fk = bloom_ops.insert(f, keys, mask)
        fr = bloom_ref.insert(f, keys, mask)
        np.testing.assert_array_equal(np.asarray(fk.bits), np.asarray(fr.bits))


class TestMinhashKernel:
    @pytest.mark.parametrize("k", [8, 16])
    @pytest.mark.parametrize("length", [100, 1337, 4096])
    def test_kmer_hashes_match_ref(self, k, length):
        rng = np.random.default_rng(length)
        bases = jnp.asarray(rng.integers(0, 4, length).astype(np.uint8))
        hk = mh_ops.kmer_hashes(bases, k=k, tile=256)
        hr = mh_ref.kmer_hashes(bases, k=k)
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))

    def test_invalid_bases_invalidate_kmers(self):
        bases = np.zeros(100, np.uint8)
        bases[50] = 4                                  # N base
        hk = np.asarray(mh_ops.kmer_hashes(jnp.asarray(bases), k=8, tile=64))
        assert (hk[43:51] == mh_ref.INVALID).all()
        assert (hk[:43] != mh_ref.INVALID).all()

    def test_canonical_reverse_complement(self):
        """A sequence and its reverse complement share canonical k-mers."""
        rng = np.random.default_rng(5)
        fwd = rng.integers(0, 4, 64).astype(np.uint8)
        rc = (3 - fwd)[::-1].copy()
        k = 8
        hf = set(np.asarray(mh_ref.kmer_hashes(jnp.asarray(fwd), k)).tolist())
        hr = set(np.asarray(mh_ref.kmer_hashes(jnp.asarray(rc), k)).tolist())
        assert hf == hr

    def test_sketch_smallest_distinct(self):
        hashes = jnp.asarray([5, 3, 3, 9, 1, 1, 7], jnp.uint32)
        sk = np.asarray(mh_ref.minhash_sketch(hashes, 4))
        assert sk.tolist() == [1, 3, 5, 7]

    def test_sketch_reads_shape(self):
        rng = np.random.default_rng(0)
        reads = jnp.asarray(rng.integers(0, 4, (4, 120)).astype(np.uint8))
        sk = mh_ops.sketch_reads(reads, k=16, s=8)
        assert sk.shape == (4, 8)
