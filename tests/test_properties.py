"""Hypothesis property tests: table semantics vs python reference models,
and the structural invariants the probing scheme relies on."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import bloom as bf
from repro.core import bucket_list as bl
from repro.core import hashing, layouts, probing
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    EMPTY_KEY,
    STATUS_INSERTED,
    STATUS_UPDATED,
    TOMBSTONE_KEY,
)

SETTINGS = settings(max_examples=20, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow,
                                           HealthCheck.data_too_large])

keys_st = st.lists(st.integers(1, 0xFFFF00), min_size=1, max_size=80)
vals_st = st.integers(0, 0xFFFFFFFF)


@st.composite
def ops_st(draw):
    """A sequence of (op, key, value) against a small key universe."""
    n = draw(st.integers(1, 60))
    ops = []
    for _ in range(n):
        op = draw(st.sampled_from(["insert", "insert", "insert", "erase"]))
        k = draw(st.integers(1, 40))
        v = draw(st.integers(0, 10 ** 6))
        ops.append((op, k, v))
    return ops


class TestSingleValueVsDict:
    @SETTINGS
    @given(ops=ops_st(), window=st.sampled_from([4, 16, 32]),
           scheme=st.sampled_from(["cops", "linear"]))
    def test_matches_dict_model(self, ops, window, scheme):
        t = sv.create(512, window=window, scheme=scheme)
        model = {}
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, stt = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
                code = int(stt[0])
                assert code == (STATUS_UPDATED if k in model
                                else STATUS_INSERTED)
                model[k] = v & 0xFFFFFFFF
            else:
                t, er = sv.erase(t, ka)
                assert bool(er[0]) == (k in model)
                model.pop(k, None)
        assert int(t.count) == len(model)
        universe = jnp.arange(1, 41, dtype=jnp.uint32)
        got, found = sv.retrieve(t, universe)
        for i, k in enumerate(range(1, 41)):
            assert bool(found[i]) == (k in model)
            if k in model:
                assert int(got[i]) == model[k]

    @SETTINGS
    @given(keys=keys_st)
    def test_cops_invariant_lowest_candidate(self, keys):
        """Every stored key sits at the lowest candidate position of its
        probe sequence (what makes stop-at-EMPTY retrieval sound)."""
        t = sv.create(256, window=8)
        u = np.unique(np.asarray(keys, np.uint32))
        t, _ = sv.insert(t, jnp.asarray(u), jnp.asarray(u))
        kp = np.asarray(t.key_planes()[0])          # (p, W)
        word = hashing.mix_murmur3(jnp.asarray(u))
        for k in u:
            row = int(probing.initial_row(jnp.uint32(k), t.num_rows, t.seed))
            step = int(probing.row_step("cops", jnp.uint32(k), t.num_rows,
                                        t.seed))
            for attempt in range(t.num_rows):
                win = kp[row]
                if (win == k).any():
                    lane = int(np.argmax(win == k))
                    before = win[:lane]
                    assert not (before == EMPTY_KEY).any(), \
                        f"key {k} not at lowest candidate lane"
                    break
                assert not (win == EMPTY_KEY).any(), \
                    f"EMPTY window before key {k} was found"
                row = (row + step) % t.num_rows


class TestMultiValueVsMultiDict:
    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 20),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=100))
    def test_multiset_semantics(self, pairs):
        t = mv.create(1024, window=16)
        model: dict = {}
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        for k, v in pairs:
            model.setdefault(k, []).append(v & 0xFFFFFFFF)
        t, stt = mv.insert(t, ks, vs)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        q = jnp.arange(1, 21, dtype=jnp.uint32)
        cnt = mv.count_values(t, q)
        for i, k in enumerate(range(1, 21)):
            assert int(cnt[i]) == len(model.get(k, []))
        out, off, _ = mv.retrieve_all(t, q, out_capacity=len(pairs))
        out, off = np.asarray(out), np.asarray(off)
        for i, k in enumerate(range(1, 21)):
            got = sorted(out[off[i]:off[i + 1]].tolist())
            assert got == sorted(model.get(k, []))


class TestBucketListVsMultiDict:
    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 15),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=80),
           growth=st.sampled_from([1.0, 1.1, 2.0]),
           s0=st.sampled_from([1, 2, 4]))
    def test_matches_multidict(self, pairs, growth, s0):
        t = bl.create(256, pool_capacity=4096, s0=s0, growth=growth)
        model: dict = {}
        for k, v in pairs:
            model.setdefault(k, []).append(v & 0xFFFFFFFF)
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        t, stt = bl.insert(t, ks, vs)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        q = jnp.arange(1, 16, dtype=jnp.uint32)
        out, off, cnt = bl.retrieve_all(t, q, out_capacity=len(pairs))
        out, off = np.asarray(out), np.asarray(off)
        for i, k in enumerate(range(1, 16)):
            assert int(cnt[i]) == len(model.get(k, []))
            # bucket-list preserves insertion order within a key
            assert out[off[i]:off[i + 1]].tolist() == model.get(k, [])


class TestBucketListRoundTrip:
    """insert -> count_values -> retrieve_all invariants across BOTH
    backends (the batched engine build and the sequential scan), over
    duplicates, masks, growth schedules and pool exhaustion: counts match
    the surviving model, values keep insertion order, both backends agree
    bit for bit on statuses, handles, pool planes and retrievals."""

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 15),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=80),
           growth=st.sampled_from([1.0, 1.1, 2.0]),
           s0=st.sampled_from([1, 2, 4]),
           pool_capacity=st.sampled_from([24, 128, 4096]),
           use_mask=st.booleans(),
           batches=st.integers(1, 2))
    def test_round_trip_invariants(self, pairs, growth, s0, pool_capacity,
                                   use_mask, batches):
        kw = dict(key_capacity=256, pool_capacity=pool_capacity,
                  s0=s0, growth=growth)
        tb = bl.create(backend="jax", **kw)
        ts = bl.create(backend="scan", **kw)
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        rng = np.random.default_rng(len(pairs))
        model: dict = {}
        for b in range(batches):
            mask = (jnp.asarray(rng.random(len(pairs)) < 0.7)
                    if use_mask else None)
            tb, stb = bl.insert(tb, ks, vs + b, mask)
            ts, sts = bl.insert(ts, ks, vs + b, mask)
            # backends bit-exact: statuses + handles + pool + allocator
            np.testing.assert_array_equal(np.asarray(stb), np.asarray(sts))
            for pb, ps in zip(jax.tree_util.tree_leaves(tb.key_store.store),
                              jax.tree_util.tree_leaves(ts.key_store.store)):
                np.testing.assert_array_equal(np.asarray(pb), np.asarray(ps))
            np.testing.assert_array_equal(np.asarray(tb.pool),
                                          np.asarray(ts.pool))
            assert int(tb.alloc_top) == int(ts.alloc_top)
            # model: statuses say exactly which writes landed (pool
            # exhaustion drops the tail of a key's stream, masks drop
            # elements) — INSERTED elements append in batch order
            for i, (k, v) in enumerate(pairs):
                if int(stb[i]) == STATUS_INSERTED:
                    model.setdefault(k, []).append((v + b) & 0xFFFFFFFF)
        q = jnp.arange(1, 16, dtype=jnp.uint32)
        cb = bl.count_values(tb, q)
        cs = bl.count_values(ts, q)
        np.testing.assert_array_equal(np.asarray(cb), np.asarray(cs))
        total = sum(map(len, model.values()))
        outb, offb, cntb = bl.retrieve_all(tb, q, out_capacity=total + 1)
        outs, offs, cnts = bl.retrieve_all(ts, q, out_capacity=total + 1)
        np.testing.assert_array_equal(np.asarray(outb), np.asarray(outs))
        np.testing.assert_array_equal(np.asarray(offb), np.asarray(offs))
        np.testing.assert_array_equal(np.asarray(cntb), np.asarray(cnts))
        outb, offb = np.asarray(outb), np.asarray(offb)
        for i, k in enumerate(range(1, 16)):
            assert int(cb[i]) == len(model.get(k, []))
            # bucket lists preserve insertion order within a key
            assert outb[offb[i]:offb[i + 1]].tolist() == model.get(k, [])
        # the allocator never hands out past the pool, and the handles'
        # counts sum to the model total (pool-exhaustion bookkeeping)
        assert int(tb.alloc_top) <= pool_capacity
        assert int(jnp.sum(tb._counts_all())) == total


class TestBloomProperties:
    @SETTINGS
    @given(keys=keys_st)
    def test_never_false_negative(self, keys):
        f = bf.create(1 << 10, k=3)
        ka = jnp.asarray(np.asarray(keys, np.uint32))
        f = bf.insert(f, ka)
        assert bf.contains(f, ka).all()

    @SETTINGS
    @given(keys=keys_st)
    def test_insert_idempotent(self, keys):
        f = bf.create(1 << 10, k=3)
        ka = jnp.asarray(np.asarray(keys, np.uint32))
        f1 = bf.insert(f, ka)
        f2 = bf.insert(f1, ka)
        assert (f1.bits == f2.bits).all()


class TestMultisplitProperties:
    @SETTINGS
    @given(keys=keys_st, parts=st.sampled_from([2, 4, 8]))
    def test_multisplit_is_stable_partition(self, keys, parts):
        from repro.core import distributed as dist
        ka = jnp.asarray(np.asarray(keys, np.uint32))
        owners = dist.owner_of(ka, parts, 1)
        so, counts, order, sk = dist.multisplit(owners, parts, ka)
        so, counts, sk = np.asarray(so), np.asarray(counts), np.asarray(sk)
        assert sorted(sk.tolist()) == sorted(np.asarray(ka).tolist())
        assert (np.diff(so) >= 0).all()             # grouped by owner
        assert counts.sum() == len(keys)
        # stability: equal-owner keys keep relative order
        for p in range(parts):
            orig = [k for k, o in zip(np.asarray(ka), np.asarray(owners))
                    if o == p]
            assert sk[so == p].tolist() == orig


class TestInsertEraseRetrieveRoundTrip:
    """Round-trip invariants across BOTH backends: after insert -> erase ->
    retrieve, erased keys retrieve empty, survivors keep their exact value
    multisets, and the live count matches the distinct live keys."""

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 25),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=80),
           erase_keys=st.lists(st.integers(1, 30), max_size=15),
           backend=st.sampled_from(["jax", "scan"]),
           window=st.sampled_from([4, 16]))
    def test_multi_value_round_trip(self, pairs, erase_keys, backend, window):
        t = mv.create(1024, window=window, backend=backend)
        model: dict = {}
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        for k, v in pairs:
            model.setdefault(k, []).append(v & 0xFFFFFFFF)
        t, _ = mv.insert(t, ks, vs)
        if erase_keys:
            ek = jnp.asarray(erase_keys, jnp.uint32)
            t, ecnt = mv.erase(t, ek)
            # every occurrence (duplicates included) reports the key's full
            # pre-erase multiplicity: the batch walk reads each window once
            for i, k in enumerate(erase_keys):
                assert int(ecnt[i]) == len(model.get(k, []))
            for k in erase_keys:
                model.pop(k, None)
        # live pair count == surviving multiset size
        assert int(t.count) == sum(map(len, model.values()))
        q = jnp.arange(1, 31, dtype=jnp.uint32)
        cnt = mv.count_values(t, q)
        for i, k in enumerate(range(1, 31)):
            assert int(cnt[i]) == len(model.get(k, []))
        out, off, _ = mv.retrieve_all(t, q, out_capacity=len(pairs) + 1)
        out, off = np.asarray(out), np.asarray(off)
        for i, k in enumerate(range(1, 31)):
            got = sorted(out[off[i]:off[i + 1]].tolist())
            assert got == sorted(model.get(k, [])), \
                f"key {k} multiset mismatch on backend={backend}"

    @SETTINGS
    @given(ops=ops_st(), backend=st.sampled_from(["jax", "scan"]),
           window=st.sampled_from([4, 16]))
    def test_single_value_round_trip(self, ops, backend, window):
        t = sv.create(512, window=window, backend=backend)
        model = {}
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, _ = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
                model[k] = v & 0xFFFFFFFF
            else:
                t, er = sv.erase(t, ka)
                assert bool(er[0]) == (k in model)
                model.pop(k, None)
        assert int(t.count) == len(model)   # live count == distinct live keys
        q = jnp.arange(1, 41, dtype=jnp.uint32)
        got, found = sv.retrieve(t, q)
        for i, k in enumerate(range(1, 41)):
            assert bool(found[i]) == (k in model)
            if k in model:
                assert int(got[i]) == model[k]

    @SETTINGS
    @given(keys=st.lists(st.integers(1, 40), min_size=1, max_size=60),
           backend=st.sampled_from(["jax", "scan"]))
    def test_erase_then_reinsert_recovers(self, keys, backend):
        """erase(k); insert(k, v') must behave as if k was never there."""
        ka = jnp.asarray(np.unique(np.asarray(keys, np.uint32)))
        t = sv.create(256, backend=backend)
        t, _ = sv.insert(t, ka, ka)
        t, er = sv.erase(t, ka)
        assert np.asarray(er).all()
        assert int(t.count) == 0
        _, found = sv.retrieve(t, ka)
        assert not np.asarray(found).any()  # erased keys retrieve empty
        t, stt = sv.insert(t, ka, ka * 2)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        got, found = sv.retrieve(t, ka)
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ka) * 2)


class TestBucketedRoundTrip:
    """The two-choice bucketed lane (plain and quotient storage) against
    the dict model, across BOTH backends: insert/erase sequences preserve
    exact map semantics, and quotient decode never produces a false
    positive (the mixer is a bijection, so q*p + b1 recovers the key
    exactly)."""

    @SETTINGS
    @given(ops=ops_st(), backend=st.sampled_from(["jax", "scan"]),
           quotient=st.booleans())
    def test_single_value_bucketed_round_trip(self, ops, backend, quotient):
        t = sv.create(512, window=8, kind="bucketed", quotient=quotient,
                      backend=backend)
        model = {}
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, stt = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
                if int(stt[0]) <= STATUS_UPDATED:
                    assert int(stt[0]) == (STATUS_UPDATED if k in model
                                           else STATUS_INSERTED)
                    model[k] = v & 0xFFFFFFFF
            else:
                t, er = sv.erase(t, ka)
                assert bool(er[0]) == (k in model)
                model.pop(k, None)
        assert int(t.count) == len(model)
        q = jnp.arange(1, 41, dtype=jnp.uint32)
        got, found = sv.retrieve(t, q)
        for i, k in enumerate(range(1, 41)):
            assert bool(found[i]) == (k in model)
            if k in model:
                assert int(got[i]) == model[k]

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 20),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=80),
           erase_keys=st.lists(st.integers(1, 25), max_size=10),
           backend=st.sampled_from(["jax", "scan"]))
    def test_multi_value_bucketed_round_trip(self, pairs, erase_keys,
                                             backend):
        t = mv.create(1024, window=16, kind="bucketed", backend=backend)
        model: dict = {}
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        t, stt = mv.insert(t, ks, vs)
        for i, (k, v) in enumerate(pairs):
            if int(stt[i]) == STATUS_INSERTED:
                model.setdefault(k, []).append(v & 0xFFFFFFFF)
        if erase_keys:
            ek = jnp.asarray(erase_keys, jnp.uint32)
            t, ecnt = mv.erase(t, ek)
            for i, k in enumerate(erase_keys):
                assert int(ecnt[i]) == len(model.get(k, []))
            for k in erase_keys:
                model.pop(k, None)
        assert int(t.count) == sum(map(len, model.values()))
        q = jnp.arange(1, 26, dtype=jnp.uint32)
        cnt = mv.count_values(t, q)
        out, off, _ = mv.retrieve_all(t, q, out_capacity=len(pairs) + 1)
        out, off = np.asarray(out), np.asarray(off)
        for i, k in enumerate(range(1, 26)):
            assert int(cnt[i]) == len(model.get(k, []))
            got = sorted(out[off[i]:off[i + 1]].tolist())
            assert got == sorted(model.get(k, []))


class TestCompositeKeyRoundTrip:
    """Composite (multi-column) keys vs a dict-of-tuples model AND the
    u32-packed single-word rendering of the same columns: insert -> erase
    -> retrieve round-trips, with outputs bit-equal across the two
    representations (the packing never leaks into results)."""

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(0, 5), st.integers(1, 6),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=60),
           erase=st.lists(st.tuples(st.integers(0, 6), st.integers(1, 7)),
                          max_size=10),
           backend=st.sampled_from(["jax", "scan"]))
    def test_multi_value_composite_round_trip(self, pairs, erase, backend):
        hi = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        lo = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[2] for p in pairs], jnp.uint32)
        packed = (hi << 4) | lo
        model: dict = {}
        for h, l, v in pairs:
            model.setdefault((h, l), []).append(v & 0xFFFFFFFF)
        tc = mv.create(512, key_words=2, backend=backend)
        tp = mv.create(512, key_words=1, backend=backend)
        tc, st_c = mv.insert(tc, (hi, lo), vs)
        tp, st_p = mv.insert(tp, packed, vs)
        np.testing.assert_array_equal(np.asarray(st_c), np.asarray(st_p))
        if erase:
            eh = jnp.asarray([e[0] for e in erase], jnp.uint32)
            el = jnp.asarray([e[1] for e in erase], jnp.uint32)
            tc, ec = mv.erase(tc, (eh, el))
            tp, ep = mv.erase(tp, (eh << 4) | el)
            np.testing.assert_array_equal(np.asarray(ec), np.asarray(ep))
            for h, l in erase:
                model.pop((h, l), None)
        assert int(tc.count) == sum(map(len, model.values()))
        assert int(tc.count) == int(tp.count)
        qh = jnp.asarray([h for h in range(6) for _ in range(1, 7)],
                         jnp.uint32)
        ql = jnp.asarray([l for _ in range(6) for l in range(1, 7)],
                         jnp.uint32)
        cap = len(pairs) + 1
        out_c, off_c, cnt_c = mv.retrieve_all(tc, (qh, ql), cap)
        out_p, off_p, cnt_p = mv.retrieve_all(tp, (qh << 4) | ql, cap)
        np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))
        np.testing.assert_array_equal(np.asarray(off_c), np.asarray(off_p))
        np.testing.assert_array_equal(np.asarray(cnt_c), np.asarray(cnt_p))
        out, off = np.asarray(out_c), np.asarray(off_c)
        for i, (h, l) in enumerate(zip(np.asarray(qh), np.asarray(ql))):
            got = sorted(out[off[i]:off[i + 1]].tolist())
            assert got == sorted(model.get((int(h), int(l)), [])), \
                f"key ({h},{l}) multiset mismatch on backend={backend}"

    @SETTINGS
    @given(ops=st.lists(st.tuples(st.sampled_from(["insert", "insert",
                                                   "erase"]),
                                  st.integers(0, 4), st.integers(1, 5),
                                  st.integers(0, 10 ** 6)),
                        min_size=1, max_size=50),
           backend=st.sampled_from(["jax", "scan"]))
    def test_single_value_composite_round_trip(self, ops, backend):
        t = sv.create(256, key_words=2, backend=backend)
        model = {}
        for op, h, l, v in ops:
            key = (jnp.asarray([h], jnp.uint32), jnp.asarray([l], jnp.uint32))
            if op == "insert":
                t, stt = sv.insert(t, key, jnp.asarray([v], jnp.uint32))
                assert int(stt[0]) == (STATUS_UPDATED if (h, l) in model
                                       else STATUS_INSERTED)
                model[(h, l)] = v & 0xFFFFFFFF
            else:
                t, er = sv.erase(t, key)
                assert bool(er[0]) == ((h, l) in model)
                model.pop((h, l), None)
        assert int(t.count) == len(model)
        qh = jnp.asarray([h for h in range(5) for _ in range(1, 6)],
                         jnp.uint32)
        ql = jnp.asarray([l for _ in range(5) for l in range(1, 6)],
                         jnp.uint32)
        got, found = sv.retrieve(t, (qh, ql))
        for i, (h, l) in enumerate(zip(np.asarray(qh), np.asarray(ql))):
            assert bool(found[i]) == ((int(h), int(l)) in model)
            if (int(h), int(l)) in model:
                assert int(got[i]) == model[(int(h), int(l))]


class TestMigrationRoundTrip:
    """insert -> erase -> grow -> compact -> retrieve preserves the exact
    live set for every table kind (repro.core.migrate): grown/compacted
    tables answer every query identically to the churned original, erased
    keys stay erased, and tombstones are gone after migration."""

    @SETTINGS
    @given(ops=ops_st(), window=st.sampled_from([4, 16]),
           new_capacity=st.sampled_from([600, 2048]))
    def test_single_value_migration(self, ops, window, new_capacity):
        from repro.core import migrate
        from repro.obs import metrics
        t = sv.create(512, window=window)
        model = {}
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, _ = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
                model[k] = v & 0xFFFFFFFF
            else:
                t, _ = sv.erase(t, ka)
                model.pop(k, None)
        t = migrate.compact(migrate.grow(t, new_capacity))
        _, tomb, _ = metrics.slot_stats(t.ops, t.store)
        assert int(tomb) == 0                  # migration drops tombstones
        assert int(t.count) == len(model)
        q = jnp.arange(1, 41, dtype=jnp.uint32)
        got, found = sv.retrieve(t, q)
        for i, k in enumerate(range(1, 41)):
            assert bool(found[i]) == (k in model)
            if k in model:
                assert int(got[i]) == model[k]

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 20),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=80),
           erase_keys=st.lists(st.integers(1, 25), max_size=10))
    def test_multi_value_migration(self, pairs, erase_keys):
        from repro.core import migrate
        t = mv.create(512, window=8)
        model: dict = {}
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        for k, v in pairs:
            model.setdefault(k, []).append(v & 0xFFFFFFFF)
        t, _ = mv.insert(t, ks, vs)
        if erase_keys:
            t, _ = mv.erase(t, jnp.asarray(erase_keys, jnp.uint32))
            for k in erase_keys:
                model.pop(k, None)
        t = migrate.compact(migrate.grow(t, 2048))
        assert int(t.count) == sum(map(len, model.values()))
        q = jnp.arange(1, 21, dtype=jnp.uint32)
        cnt = mv.count_values(t, q)
        out, off, _ = mv.retrieve_all(t, q, out_capacity=len(pairs) + 1)
        out, off = np.asarray(out), np.asarray(off)
        for i, k in enumerate(range(1, 21)):
            assert int(cnt[i]) == len(model.get(k, []))  # fan-out preserved
            got = sorted(out[off[i]:off[i + 1]].tolist())
            assert got == sorted(model.get(k, []))

    @SETTINGS
    @given(pairs=st.lists(st.tuples(st.integers(1, 12),
                                    st.integers(0, 10 ** 6)),
                          min_size=1, max_size=60),
           s0=st.sampled_from([1, 2]),
           growth=st.sampled_from([1.0, 1.5]))
    def test_bucket_list_migration(self, pairs, s0, growth):
        from repro.core import migrate
        t = bl.create(128, pool_capacity=1024, s0=s0, growth=growth)
        ks = jnp.asarray([p[0] for p in pairs], jnp.uint32)
        vs = jnp.asarray([p[1] for p in pairs], jnp.uint32)
        t, stt = bl.insert(t, ks, vs)
        assert (np.asarray(stt) == STATUS_INSERTED).all()
        q = jnp.arange(1, 13, dtype=jnp.uint32)
        want = bl.retrieve_all(t, q, out_capacity=len(pairs))
        fresh = migrate.compact(migrate.grow(t, 512))
        got = bl.retrieve_all(fresh, q, out_capacity=len(pairs))
        # migration preserves per-key insertion order bit-exactly
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


class TestLayoutEquivalence:
    @SETTINGS
    @given(keys=keys_st, window=st.sampled_from([8, 32]))
    def test_all_layouts_same_results(self, keys, window):
        u = np.unique(np.asarray(keys, np.uint32))
        vals = (u * 31 + 7).astype(np.uint32)
        results = {}
        for layout in layouts.LAYOUTS:
            t = sv.create(512, window=window, layout=layout)
            t, _ = sv.insert(t, jnp.asarray(u), jnp.asarray(vals))
            got, found = sv.retrieve(t, jnp.asarray(u))
            results[layout] = (np.asarray(got), np.asarray(found))
        a = results["soa"]
        for layout in ("aos", "packed"):
            assert (results[layout][0] == a[0]).all()
            assert (results[layout][1] == a[1]).all()


class TestSnapshotProperties:
    """Checkpoint/restore round-trips under arbitrary op interleavings."""

    @SETTINGS
    @given(ops=ops_st(), layout=st.sampled_from(["soa", "aos", "packed"]))
    def test_insert_snapshot_erase_restore_retrieve(self, ops, layout):
        """Snapshot mid-sequence, keep mutating, restore: the restored
        table answers exactly as the table did at snapshot time."""
        from repro.core import snapshot
        t = sv.create(512, window=16, layout=layout)
        model = {}
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, _ = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
                model[k] = v & 0xFFFFFFFF
            else:
                t, _ = sv.erase(t, ka)
                model.pop(k, None)
        blob = snapshot.snapshot_bytes(t)
        frozen = dict(model)
        # post-snapshot mutations that must NOT leak into the restore
        for k in list(model)[: len(model) // 2]:
            t, _ = sv.erase(t, jnp.asarray([k], jnp.uint32))
        t, _ = sv.insert(t, jnp.asarray([41], jnp.uint32),
                         jnp.asarray([0], jnp.uint32))
        restored = snapshot.restore_bytes(blob)
        assert int(restored.count) == len(frozen)
        universe = jnp.arange(1, 42, dtype=jnp.uint32)
        got, found = sv.retrieve(restored, universe)
        for i, k in enumerate(range(1, 42)):
            assert bool(found[i]) == (k in frozen)
            if k in frozen:
                assert int(got[i]) == frozen[k]

    @SETTINGS
    @given(ops=ops_st())
    def test_snapshot_bytes_deterministic(self, ops):
        """Same table state => byte-identical snapshot (stable manifest
        ordering), so checksums are meaningful across processes."""
        from repro.core import snapshot
        t = sv.create(256, window=8)
        for op, k, v in ops:
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                t, _ = sv.insert(t, ka, jnp.asarray([v], jnp.uint32))
            else:
                t, _ = sv.erase(t, ka)
        assert snapshot.snapshot_bytes(t) == snapshot.snapshot_bytes(t)


class TestShardedBloomInvariant:
    """The elastic front-end's one-sided filter contract: every key live
    in a shard's table is contains=True in that shard's filter, across
    arbitrary insert/erase/compaction sequences."""

    @SETTINGS
    @given(ops=ops_st(), num_shards=st.sampled_from([2, 4]))
    def test_live_keys_always_advertised(self, ops, num_shards):
        from repro.serving import elastic
        st_ = elastic.create(num_shards, 512, window=16)
        model = {}
        compact_every = 7
        for i, (op, k, v) in enumerate(ops):
            ka = jnp.asarray([k], jnp.uint32)
            if op == "insert":
                st_, _ = elastic.insert(st_, ka,
                                        jnp.asarray([v], jnp.uint32))
                model[k] = v & 0xFFFFFFFF
            else:
                st_, _ = elastic.erase(st_, ka)
                model.pop(k, None)
            if i % compact_every == compact_every - 1:
                st_ = elastic.compact_all(st_)   # filter rebuild point
            if not model:
                continue
            live = jnp.asarray(sorted(model), jnp.uint32)
            words = sv.key_hash_word(
                sv.normalize_key_batch(live, 1, "keys"))
            owners = hashing.hash_owner(words, num_shards)
            bits = jnp.stack([f.bits for f in st_.filters])
            admitted = bf.contains_stack(st_.filters[0], bits, owners,
                                         words)
            assert bool(jnp.all(admitted)), \
                "live key not advertised by its owner's filter"
        # and the lookup path agrees with the dict model end-to-end
        universe = jnp.arange(1, 41, dtype=jnp.uint32)
        got, found, stats = elastic.lookup(st_, universe)
        assert int(stats["overflow"]) == 0
        for i, k in enumerate(range(1, 41)):
            assert bool(found[i]) == (k in model)
            if k in model:
                assert int(got[i]) == model[k]

    @SETTINGS
    @given(keys=keys_st)
    def test_rebuild_is_subset_of_incremental(self, keys):
        """rebuild_from_table never advertises MORE than the incremental
        filter: rebuilt bits are a subset (erase-staleness only shrinks)."""
        t = sv.create(512, window=16)
        ka = jnp.asarray(np.unique(np.asarray(keys, np.uint32)))
        t, _ = sv.insert(t, ka, ka)
        f_inc = bf.insert(bf.create(1 << 12), sv.key_hash_word(
            sv.normalize_key_batch(ka, 1, "keys")))
        half = ka[: ka.shape[0] // 2]
        if half.shape[0]:
            t, _ = sv.erase(t, half)
        f_reb = bf.rebuild_from_table(f_inc, t)
        assert bool(jnp.all(f_inc.bits >= f_reb.bits)), \
            "rebuilt filter set a bit the incremental filter never did"
