"""Fault-injection suite for checkpoint/restore + elastic sharded serving.

Three failure families, per ISSUE 10:

- **crash recovery** — snapshot a live table, keep mutating the original
  (simulating the work lost after the checkpoint), restore, and demand
  the restored table is BIT-EXACT against the checkpointed state: same
  treedef (probe geometry/statics), same store planes, same slot census,
  and retrieve parity on the live set.  Every table kind × geometry.
- **torn snapshots** — truncations at every layer (magic, header,
  payload) and payload bit-flips must raise ``SnapshotError`` with a
  clear diagnosis, never restore a silently wrong table.
- **elastic restore** — restoring onto a different shard count must
  replay the ownership exchange exactly: each shard ends with precisely
  its owned keys (``check_ownership``), nothing lost, lookup parity
  intact.  Host-simulated meshes here; the 8-device shard_map leg runs
  in subprocesses via the harness from ``test_distributed.py``.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bloom
from repro.core import bucket_list as bl
from repro.core import counting, hashing, migrate, snapshot
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.serving import elastic

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src"}


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=540,
                       env=_ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def _keys(n, seed=0, lo=1, span=1 << 18):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(span, n, replace=False) + lo, jnp.uint32)


def _assert_bit_exact(a, b, what=""):
    """Same treedef (statics => probe geometry) and same plane bytes."""
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b), \
        f"{what}: treedef (static config) drifted through the snapshot"
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.dtype == lb.dtype and la.shape == lb.shape, what
        assert bool(jnp.array_equal(la, lb)), \
            f"{what}: store plane bytes differ after restore"


# every kind × geometry: (builder, insert, mutate-after-snapshot, verify)
def _sv_like(make):
    def build():
        t = make()
        ks, vs = _keys(150), _keys(150) * 7
        t, _ = sv.insert(t, ks, vs)
        return t, (ks, vs)

    def mutate(t, live):
        t, _ = sv.insert(t, _keys(60, seed=9, lo=1 << 20), _keys(60, seed=9))
        t, _ = sv.erase(t, live[0][:40])
        return t

    def verify(t, live):
        got, found = sv.retrieve(t, live[0])
        assert bool(jnp.all(found))
        assert bool(jnp.all(got == live[1]))
    return build, mutate, verify


def _mv_case():
    def build():
        t = mv.create(2048)
        ks = jnp.concatenate([_keys(100), _keys(100)])
        vs = jnp.concatenate([_keys(100) * 3, _keys(100) * 5])
        t, _ = mv.insert(t, ks, vs)
        return t, (ks, vs)

    def mutate(t, live):
        t, _ = mv.insert(t, _keys(50, seed=9, lo=1 << 20),
                         _keys(50, seed=9))
        return t

    def verify(t, live):
        _, _, cnt = mv.retrieve_all(t, live[0][:100], 400)
        assert bool(jnp.all(cnt == 2))
    return build, mutate, verify


def _mv_bucketed_case():
    b, m, v = _mv_case()

    def build():
        t = mv.create(2048, kind="bucketed")
        ks = jnp.concatenate([_keys(100), _keys(100)])
        vs = jnp.concatenate([_keys(100) * 3, _keys(100) * 5])
        t, _ = mv.insert(t, ks, vs)
        return t, (ks, vs)
    return build, m, v


def _counting_case():
    def build():
        t = counting.create(512)
        ks = _keys(80)
        t, _ = counting.insert(t, jnp.concatenate([ks, ks, ks[:40]]))
        return t, (ks,)

    def mutate(t, live):
        t, _ = counting.insert(t, live[0])
        return t

    def verify(t, live):
        c = counting.counts(t, live[0])
        assert bool(jnp.all(c[:40] == 3)) and bool(jnp.all(c[40:] == 2))
    return build, mutate, verify


def _bucket_list_case():
    def build():
        t = bl.create(256, 4096)
        ks = jnp.concatenate([_keys(80), _keys(80)])
        vs = jnp.arange(160, dtype=jnp.uint32)
        t, _ = bl.insert(t, ks, vs)
        return t, (ks, vs)

    def mutate(t, live):
        t, _ = bl.insert(t, _keys(40, seed=9, lo=1 << 20), _keys(40, seed=9))
        return t

    def verify(t, live):
        _, _, cnt = bl.retrieve_all(t, live[0][:80], 400)
        assert bool(jnp.all(cnt == 2))
    return build, mutate, verify


CASES = {
    "sv-soa": _sv_like(lambda: sv.create(1024)),
    "sv-aos": _sv_like(lambda: sv.create(1024, layout="aos")),
    "sv-packed": _sv_like(lambda: sv.create(1024, layout="packed")),
    "sv-bucketed": _sv_like(lambda: sv.create(1024, kind="bucketed")),
    "sv-quotient": _sv_like(
        lambda: sv.create(1024, kind="bucketed", quotient=True)),
    "sv-2word": _sv_like(lambda: sv.create(1024, key_words=2, value_words=2)),
    "mv-cops": _mv_case(),
    "mv-bucketed": _mv_bucketed_case(),
    "counting": _counting_case(),
    "bucket-list": _bucket_list_case(),
}


class TestCrashRecovery:
    """snapshot -> mutate original -> restore -> bit-exact + parity."""

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_round_trip_bit_exact(self, case, tmp_path):
        build, mutate, verify = CASES[case]
        if case == "sv-2word":
            # 2-word case feeds u32 pairs through the same sv path
            t = sv.create(1024, key_words=2, value_words=2)
            ks = jnp.stack([_keys(100), _keys(100, seed=3)], axis=1)
            vs = jnp.stack([_keys(100) * 3, _keys(100) * 5], axis=1)
            t, _ = sv.insert(t, ks, vs)
            live = (ks, vs)

            def mutate(tt, lv):
                tt, _ = sv.erase(tt, lv[0][:40])
                return tt

            def verify(tt, lv):
                got, found = sv.retrieve(tt, lv[0])
                assert bool(jnp.all(found))
                assert bool(jnp.all(got == lv[1]))
        else:
            t, live = build()
        path = tmp_path / f"{case}.snap"
        snapshot.save(t, str(path))
        checkpointed = t
        t = mutate(t, live)           # work lost after the checkpoint
        restored = snapshot.load(str(path))
        _assert_bit_exact(checkpointed, restored, case)
        verify(restored, live)        # retrieve parity on the live set

    @pytest.mark.parametrize("case", ["sv-soa", "sv-quotient", "bucket-list"])
    def test_census_preserved(self, case, tmp_path):
        build, _, _ = CASES[case]
        t, _ = build()
        restored = snapshot.restore_bytes(snapshot.snapshot_bytes(t))
        ka, _, la = migrate.live_entries(t)
        kb, _, lb = migrate.live_entries(restored)
        assert int(jnp.sum(la)) == int(jnp.sum(lb))
        assert bool(jnp.array_equal(jnp.where(la[:, None], ka, 0),
                                    jnp.where(lb[:, None], kb, 0)))


class TestTornSnapshots:
    """Damaged state must raise SnapshotError, never restore quietly."""

    def _blob(self):
        t, _ = CASES["sv-soa"][0]()
        return snapshot.snapshot_bytes(t)

    def test_bad_magic(self):
        with pytest.raises(snapshot.SnapshotError, match="magic"):
            snapshot.restore_bytes(b"NOTASNAP" + self._blob()[8:])

    def test_truncated_header(self):
        blob = self._blob()
        with pytest.raises(snapshot.SnapshotError, match="header"):
            snapshot.restore_bytes(blob[:20])

    def test_truncated_payload(self):
        blob = self._blob()
        with pytest.raises(snapshot.SnapshotError,
                           match="torn snapshot: payload"):
            snapshot.restore_bytes(blob[:-100])

    def test_corrupted_payload_bits(self):
        blob = bytearray(self._blob())
        blob[-40] ^= 0xFF             # flip bits deep in the payload
        with pytest.raises(snapshot.SnapshotError, match="sha256"):
            snapshot.restore_bytes(bytes(blob))

    def test_corrupted_header_json(self):
        blob = self._blob()
        nl = blob.find(b"\n", len(snapshot.MAGIC))
        bad = blob[:len(snapshot.MAGIC)] + b'{"version": ' + blob[nl:]
        with pytest.raises(snapshot.SnapshotError, match="header"):
            snapshot.restore_bytes(bad)

    def test_unknown_version(self):
        blob = self._blob()
        bad = blob.replace(b'"version": 1', b'"version": 99', 1)
        with pytest.raises(snapshot.SnapshotError, match="version"):
            snapshot.restore_bytes(bad)

    def test_empty_and_garbage_files(self, tmp_path):
        p = tmp_path / "x.snap"
        p.write_bytes(b"")
        with pytest.raises(snapshot.SnapshotError):
            snapshot.load(str(p))
        p.write_bytes(b"\x00" * 256)
        with pytest.raises(snapshot.SnapshotError):
            snapshot.load(str(p))

    def test_elastic_load_missing_manifest(self, tmp_path):
        with pytest.raises(snapshot.SnapshotError, match="manifest"):
            elastic.load(str(tmp_path))


class TestSnapshotWriter:
    """The async double-buffered writer."""

    def test_async_write_then_load(self, tmp_path):
        t, live = CASES["sv-soa"][0]()
        p = str(tmp_path / "w.snap")
        with snapshot.SnapshotWriter() as w:
            w.save(t, p)
            w.flush()
            restored = snapshot.load(p)
        _assert_bit_exact(t, restored, "writer")

    def test_donation_safe(self, tmp_path):
        """The host copy is taken synchronously in save(): donating the
        table's buffers immediately afterwards must not corrupt the
        queued snapshot."""
        t, live = CASES["sv-soa"][0]()
        p = str(tmp_path / "w.snap")
        donating = jax.jit(lambda tt, k, v: sv.insert(tt, k, v)[0],
                           donate_argnums=(0,))
        with snapshot.SnapshotWriter() as w:
            w.save(t, p)
            t2 = donating(t, _keys(50, seed=5, lo=1 << 21),
                          _keys(50, seed=5))   # invalidates t's buffers
            jax.block_until_ready(t2.count)
            w.flush()
        restored = snapshot.load(p)
        got, found = sv.retrieve(restored, live[0])
        assert bool(jnp.all(found)) and bool(jnp.all(got == live[1]))
        assert int(restored.count) == int(jnp.sum(
            jnp.ones_like(live[0], jnp.int32)))

    def test_latest_wins(self, tmp_path):
        """Queueing faster than the disk keeps only the freshest state."""
        t, _ = CASES["sv-soa"][0]()
        versions = [t]
        for i in range(4):
            t, _ = sv.insert(t, _keys(20, seed=10 + i, lo=(1 << 20) * (i + 2)),
                             _keys(20, seed=10 + i))
            versions.append(t)
        p = str(tmp_path / "w.snap")
        with snapshot.SnapshotWriter() as w:
            for v in versions:
                w.save(v, p)
            w.flush()
        restored = snapshot.load(p)
        assert int(restored.count) == int(versions[-1].count)
        _assert_bit_exact(versions[-1], restored, "latest-wins")

    def test_write_failure_surfaces(self, tmp_path):
        t, _ = CASES["sv-soa"][0]()
        w = snapshot.SnapshotWriter()
        w.save(t, str(tmp_path / "no" / "such" / "dir" / "x.snap"))
        with pytest.raises(OSError):
            w.flush()
        w.close()


class TestShardedServing:
    """The bloom-filtered sharded table vs a dict model."""

    def test_dict_model_parity(self):
        rng = np.random.default_rng(1)
        st = elastic.create(4, 2048)
        model = {}
        for step in range(4):
            ins = _keys(200, seed=20 + step, span=1 << 12)
            vs = jnp.asarray(rng.integers(0, 2 ** 31, 200), jnp.uint32)
            st, _ = elastic.insert(st, ins, vs)
            for k, v in zip(np.asarray(ins).tolist(), np.asarray(vs).tolist()):
                model[k] = v
            dels = _keys(60, seed=40 + step, span=1 << 12)
            st, erased = elastic.erase(st, dels)
            for i, k in enumerate(np.asarray(dels).tolist()):
                assert bool(erased[i]) == (k in model)
                model.pop(k, None)
        universe = jnp.asarray(sorted(set(np.asarray(
            _keys(4096, seed=99, span=1 << 12)).tolist())), jnp.uint32)
        got, found, stats = elastic.lookup(st, universe)
        for i, k in enumerate(np.asarray(universe).tolist()):
            assert bool(found[i]) == (k in model), f"key {k}"
            if k in model:
                assert int(got[i]) == model[k]
        assert int(elastic.count(st)) == len(model)

    def test_absent_keys_skip_exchange(self):
        st = elastic.create(4, 2048)
        st, _ = elastic.insert(st, _keys(500), _keys(500))
        absent = _keys(1000, seed=7, lo=1 << 20)
        _, found, stats = elastic.lookup(st, absent)
        assert not bool(jnp.any(found))
        frac = int(stats["skips"]) / 1000
        assert frac >= 0.5, \
            f"bloom front-end only skipped {frac:.0%} of absent traffic"

    def test_no_false_negatives_through_filter(self):
        """Every live key must pass its owner's filter (admission is
        exact for present keys — the one-sided bloom contract)."""
        st = elastic.create(4, 2048)
        ks = _keys(800)
        st, _ = elastic.insert(st, ks, ks)
        _, found, stats = elastic.lookup(st, ks)
        assert bool(jnp.all(found)), "filter produced a false negative"
        assert int(stats["skips"]) == 0

    def test_erase_staleness_and_compaction_rebuild(self):
        """Regression for the bloom staleness-after-erase fix: erase
        leaves the filter permissive; compact_all's rebuild stops
        advertising long-dead keys."""
        st = elastic.create(4, 2048)
        ks = _keys(600)
        st, _ = elastic.insert(st, ks, ks)
        dead, alive = ks[:500], ks[500:]
        st, _ = elastic.erase(st, dead)

        def advertised(s, keys):
            words = sv.key_hash_word(
                sv.normalize_key_batch(keys, 1, "keys"))
            owners = hashing.hash_owner(words, s.num_shards)
            bits = jnp.stack([f.bits for f in s.filters])
            return bloom.contains_stack(s.filters[0], bits, owners, words)

        stale = advertised(st, dead)
        assert bool(jnp.all(stale)), \
            "erase must leave the filter permissive (no bit clearing)"
        st = elastic.compact_all(st)
        stale_after = float(jnp.mean(advertised(st, dead)))
        assert stale_after < 0.1, \
            f"{stale_after:.0%} of long-dead keys still advertised " \
            "after compaction rebuild"
        # live keys must never be dropped by the rebuild
        assert bool(jnp.all(advertised(st, alive)))
        got, found, _ = elastic.lookup(st, alive)
        assert bool(jnp.all(found)) and bool(jnp.all(got == alive))

    def test_fill_fraction_only_grows_until_rebuild(self):
        st = elastic.create(2, 1024)
        fills = [float(bloom.fill_fraction(st.filters[0]))]
        for i in range(3):
            st, _ = elastic.insert(st, _keys(100, seed=i, lo=1 + (i << 12)),
                                   _keys(100, seed=i))
            st, _ = elastic.erase(st, _keys(50, seed=i, lo=1 + (i << 12)))
            fills.append(float(bloom.fill_fraction(st.filters[0])))
        assert all(b >= a for a, b in zip(fills, fills[1:])), fills


class TestElasticReshard:
    """Restore onto a different shard count: exact ownership replay."""

    @pytest.mark.parametrize("p_from,p_to", [(4, 8), (8, 3), (2, 7)])
    def test_reshard_ownership_exact(self, p_from, p_to):
        st = elastic.create(p_from, 4096)
        ks, vs = _keys(2000), _keys(2000) * 11
        st, _ = elastic.insert(st, ks, vs)
        st2 = elastic.reshard(st, p_to)
        assert st2.num_shards == p_to
        assert int(elastic.count(st2)) == 2000
        elastic.check_ownership(st2)
        got, found, _ = elastic.lookup(st2, ks)
        assert bool(jnp.all(found)) and bool(jnp.all(got == vs))

    def test_restore_onto_resized_mesh(self, tmp_path):
        st = elastic.create(4, 4096)
        ks, vs = _keys(1500), _keys(1500) * 13
        st, _ = elastic.insert(st, ks, vs)
        st, _ = elastic.erase(st, ks[:500])
        d = str(tmp_path / "ckpt")
        elastic.save(st, d)
        # same count -> bit-exact shard restore
        same = elastic.load(d)
        for a, b in zip(st.shards, same.shards):
            _assert_bit_exact(a, b, "same-count restore")
        # 2x count -> exact ownership under the new partition
        wide = elastic.load(d, num_shards=8)
        assert int(elastic.count(wide)) == 1000
        elastic.check_ownership(wide)
        got, found, _ = elastic.lookup(wide, ks[500:])
        assert bool(jnp.all(found)) and bool(jnp.all(got == vs[500:]))
        gone, gfound, _ = elastic.lookup(wide, ks[:500])
        assert not bool(jnp.any(gfound))

    def test_kill_restore_resume(self, tmp_path):
        """The fig12 leg in miniature: serve, checkpoint async, 'crash',
        restore, resume serving at parity."""
        rng = np.random.default_rng(3)

        def traffic(n):
            for _ in range(n):
                yield (jnp.asarray(rng.integers(1, 1 << 14, 128), jnp.uint32),
                       jnp.asarray(rng.integers(0, 2 ** 31, 128), jnp.uint32),
                       jnp.asarray(rng.integers(1, 1 << 16, 128), jnp.uint32),
                       jnp.asarray(rng.integers(1, 1 << 14, 64), jnp.uint32))

        st = elastic.create(4, 4096)
        st, _, _, _ = elastic.serve_traffic(st, traffic(4))
        d = str(tmp_path / "ckpt")
        with snapshot.SnapshotWriter() as w:
            elastic.save(st, d, writer=w)
            w.flush()
        sweeps = [migrate.live_entries(t) for t in st.shards]
        live_all = jnp.concatenate(
            [k[np.asarray(lv)] for k, _, lv in sweeps])
        pre_count = int(elastic.count(st))
        del st                                     # the crash
        st2 = elastic.load(d)
        assert int(elastic.count(st2)) == pre_count
        got, found, stats = elastic.lookup(st2, live_all)
        assert int(stats["overflow"]) == 0
        assert bool(jnp.all(found))
        st2, _, steps, _ = elastic.serve_traffic(st2, traffic(3))
        assert steps == 3


class TestElasticSubprocess:
    """8-device legs via the subprocess harness from test_distributed."""

    def test_mesh_shards_checkpoint_to_resized_service(self, tmp_path):
        """Build a REAL 8-shard mesh table, checkpoint each device's
        shard, restore as a 4-shard elastic service: ownership exact."""
        out = _run(f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import distributed as dist, snapshot, bloom
            from repro.core.compat import make_mesh_compat
            from repro.serving import elastic
            import dataclasses, json, os
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 2048, window=16)
            n = 8 * 400
            keys = jnp.asarray(np.random.default_rng(0).permutation(
                np.arange(1, n + 1, dtype=np.uint32)))
            vals = keys * 3
            table, status, ov = dist.shard_insert(mesh, 'x', table, keys, vals)
            assert int(np.asarray(ov).sum()) == 0
            d = {str(tmp_path)!r}
            os.makedirs(d, exist_ok=True)
            shards = [jax.tree.map(lambda x: x[i], table) for i in range(8)]
            f0 = bloom.create(16 * shards[0].capacity)
            st = elastic.ShardedTable(
                shards=tuple(shards),
                filters=tuple(bloom.rebuild_from_table(f0, t)
                              for t in shards),
                num_shards=8, slack=2.0)
            elastic.check_ownership(st)   # mesh partition == elastic partition
            elastic.save(st, d)
            st4 = elastic.load(d, num_shards=4)
            assert st4.num_shards == 4
            assert int(elastic.count(st4)) == n
            elastic.check_ownership(st4)
            got, found, stats = elastic.lookup(st4, keys)
            assert bool(jnp.all(found))
            assert bool(jnp.all(got == vals))
            print('OK')
        """)
        assert "OK" in out

    def test_filtered_retrieve_in_mesh(self):
        """retrieve_distributed_filtered inside shard_map: parity on
        present keys, >=50% of absent traffic killed pre-all_to_all."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core import distributed as dist, bloom
            from repro.core.compat import make_mesh_compat, shard_map_compat
            mesh = make_mesh_compat((8,), ('x',))
            table = dist.create_sharded(mesh, 'x', 2048)
            rng = np.random.default_rng(0)
            n = 8 * 512
            keys = jnp.asarray(rng.choice(1 << 19, n, replace=False) + 1,
                               jnp.uint32)
            vals = keys * 5
            table, _, ov = dist.shard_insert(mesh, 'x', table, keys, vals)
            assert int(np.asarray(ov).sum()) == 0
            proto = bloom.create(16 * 2048)
            spec = jax.tree.map(lambda _: P('x'), table)
            def mk(t):
                t0 = dist._local(t)
                return dist._relift(
                    bloom.rebuild_from_table(proto, t0).bits)
            fbits = shard_map_compat(mk, mesh, in_specs=(spec,),
                                     out_specs=P('x'))(table)
            import dataclasses
            def body(t, fb, k):
                f = dataclasses.replace(proto, bits=fb[0])
                v, fnd, sk, ov = dist.retrieve_distributed_filtered(
                    dist._local(t), f, k, 'x')
                return v, fnd, sk[None], ov[None]
            g = shard_map_compat(body, mesh,
                                 in_specs=(spec, P('x'), P('x')),
                                 out_specs=(P('x'), P('x'), P('x'), P('x')))
            v, fnd, sk, ov = g(table, fbits, keys)
            assert bool(jnp.all(fnd)) and bool(jnp.all(v == vals))
            assert int(jnp.max(ov)) == 0
            absent = jnp.asarray(
                rng.choice(1 << 19, n, replace=False) + (1 << 21), jnp.uint32)
            v2, f2, sk2, _ = g(table, fbits, absent)
            assert not bool(jnp.any(f2))
            frac = int(jnp.sum(sk2)) / n
            assert frac >= 0.5, frac
            print('OK skip_frac=%.3f' % frac)
        """)
        assert "OK" in out
