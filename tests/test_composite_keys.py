"""Composite multi-word key tests.

The contract under test: a composite key — N u32 columns packed into
``key_words = N`` planes by ``hashing.pack_columns`` — behaves *exactly*
like an equivalent scalar key.  Two reference representations anchor the
parity:

- the **u64-packed reference**: two columns packed host-side into numpy
  uint64 — the table-native (hi, lo) planes, so outputs AND table state
  must be bit-identical to the tuple-of-columns spelling;
- the **packed single-word reference**: columns narrow enough to pack
  into one u32 word, run through the 1-word fast lanes.  Hash placement
  differs completely, but relational OUTPUT (values, offsets, counts,
  statuses, join pairs, first-occurrence masks) is representation-
  independent — per-key result segments are emitted in build-batch
  order regardless of packing — so these must match bit for bit too.

Covered: all four join flavors x jax/scan backends, masks, tombstones,
duplicate composite keys differing only in the high word, 3-column keys
(the general lane), the pallas 2-plane fused-retrieve tile, and the
sharded ownership exchange.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core import hashset as hs
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import split_u64
from repro.relational import distinct as rdistinct
from repro.relational import groupby as rgroupby
from repro.relational import join as rjoin

_U = jnp.uint32


def two_cols(rng, n, hi_lim=4, lo_lim=8):
    """Small universes so duplicate pairs, shared-lo and shared-hi keys
    all occur; lo >= 1 keeps plane 0 off the sentinels."""
    hi = jnp.asarray(rng.integers(0, hi_lim, n).astype(np.uint32))
    lo = jnp.asarray(rng.integers(1, lo_lim, n).astype(np.uint32))
    return hi, lo


def packed_u32(hi, lo, lo_bits=16):
    return (hi << lo_bits) | lo


def packed_u64(hi, lo):
    return ((np.asarray(hi).astype(np.uint64) << np.uint64(32))
            | np.asarray(lo).astype(np.uint64))


# ---------------------------------------------------------------------------
# packing helpers
# ---------------------------------------------------------------------------

class TestPackColumns:
    def test_two_columns_are_u64_planes(self, rng):
        hi, lo = two_cols(rng, 50, 1 << 10, 1 << 16)
        planes = hashing.pack_columns((hi, lo))
        h2, l2 = split_u64(packed_u64(hi, lo))
        np.testing.assert_array_equal(np.asarray(planes[:, 0]), l2)
        np.testing.assert_array_equal(np.asarray(planes[:, 1]), h2)

    @pytest.mark.parametrize("ncols", [1, 2, 3, 4])
    def test_round_trip(self, rng, ncols):
        cols = tuple(jnp.asarray(rng.integers(0, 1 << 20, 37)
                                 .astype(np.uint32)) for _ in range(ncols))
        planes = hashing.pack_columns(cols)
        assert planes.shape == (37, ncols)
        back = hashing.unpack_columns(planes)
        assert len(back) == ncols
        for a, b in zip(back, cols):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_normalize_keys_inference(self, rng):
        hi, lo = two_cols(rng, 8)
        k, kw = sv.normalize_keys((hi, lo))
        assert kw == 2 and k.shape == (8, 2)
        k, kw = sv.normalize_keys(packed_u64(hi, lo))
        assert kw == 2 and k.shape == (8, 2)
        k, kw = sv.normalize_keys(hi)
        assert kw == 1 and k.shape == (8, 1)
        k, kw = sv.normalize_keys(jnp.stack([lo, hi], axis=1))
        assert kw == 2
        with pytest.raises(ValueError):
            sv.normalize_keys((hi, lo), words=1)

    def test_bad_inputs_raise(self, rng):
        hi, lo = two_cols(rng, 8)
        with pytest.raises(ValueError):
            hashing.pack_columns(())
        with pytest.raises(ValueError):
            hashing.pack_columns((hi, lo[:4]))
        with pytest.raises(TypeError):
            hashing.pack_columns((hi.astype(jnp.float32),))


# ---------------------------------------------------------------------------
# joins: all four flavors, both backends, three key representations
# ---------------------------------------------------------------------------

def assert_results_equal(a, b, ctx=""):
    for f in ("build_idx", "probe_idx", "valid", "matched"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{ctx}: {f}")
    assert int(a.total) == int(b.total), ctx


class TestCompositeJoinParity:
    @pytest.mark.parametrize("how", rjoin.HOW)
    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_bit_exact_vs_packed_references(self, rng, how, backend):
        n = 96
        bh, bl = two_cols(rng, n)
        ph, pl = two_cols(rng, n)
        cap = 6 * n
        res_c = rjoin.hash_join((bh, bl), (ph, pl), cap, how,
                                backend=backend)
        res_p = rjoin.hash_join(packed_u32(bh, bl, 4), packed_u32(ph, pl, 4),
                                cap, how, backend=backend)
        assert_results_equal(res_c, res_p, f"{how}/{backend} vs u32-packed")
        res_64 = rjoin.hash_join(packed_u64(bh, bl), packed_u64(ph, pl),
                                 cap, how, backend=backend)
        assert_results_equal(res_c, res_64, f"{how}/{backend} vs u64-packed")

    @pytest.mark.parametrize("how", rjoin.HOW)
    def test_masks(self, rng, how):
        n = 64
        bh, bl = two_cols(rng, n)
        ph, pl = two_cols(rng, n)
        bm = jnp.asarray(rng.random(n) < 0.7)
        pm = jnp.asarray(rng.random(n) < 0.7)
        cap = 6 * n
        res_c = rjoin.hash_join((bh, bl), (ph, pl), cap, how,
                                build_mask=bm, probe_mask=pm)
        res_p = rjoin.hash_join(packed_u32(bh, bl, 4), packed_u32(ph, pl, 4),
                                cap, how, build_mask=bm, probe_mask=pm)
        assert_results_equal(res_c, res_p, f"{how} masked")

    def test_high_word_only_duplicates(self, rng):
        # probe keys share the low word with build keys but differ in the
        # high word: a single-plane compare would join them, the composite
        # key must not
        n = 32
        lo = jnp.asarray(rng.integers(1, 5, n).astype(np.uint32))
        bh = jnp.zeros((n,), _U)
        ph = jnp.ones((n,), _U)
        res = rjoin.hash_join((bh, lo), (ph, lo), 4 * n, "inner")
        assert int(res.total) == 0
        assert not bool(res.matched.any())
        # and the anti join sees every probe row
        res = rjoin.hash_join((bh, lo), (ph, lo), 4 * n, "anti")
        assert int(res.total) == n

    def test_tombstoned_build_pairs(self, rng):
        n = 48
        bh, bl = two_cols(rng, n)
        table, _ = rjoin.build((bh, bl), capacity=4 * n)
        # erase a composite key subset, rebuild the packed equivalent
        table, _ = mv.erase(table, (bh[:8], bl[:8]))
        tp, _ = rjoin.build(packed_u32(bh, bl, 4), capacity=4 * n)
        tp, _ = mv.erase(tp, packed_u32(bh[:8], bl[:8], 4))
        ph, pl = two_cols(rng, n)
        for how in rjoin.HOW:
            res_c = rjoin.probe(table, (ph, pl), 6 * n, how=how)
            res_p = rjoin.probe(tp, packed_u32(ph, pl, 4), 6 * n, how=how)
            assert_results_equal(res_c, res_p, f"tombstoned {how}")

    def test_count_matches_accepts_tuples(self, rng):
        n = 40
        bh, bl = two_cols(rng, n)
        table, _ = rjoin.build((bh, bl))
        cnt = rjoin.count_matches(table, (bh, bl))
        cnt_p = rjoin.count_matches(
            rjoin.build(packed_u32(bh, bl, 4))[0], packed_u32(bh, bl, 4))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_p))

    def test_three_column_keys(self, rng):
        # 3 columns of <= 10 bits each still pack into one u32: the
        # general (key_words > 2) lane against the 1-word fast lane
        n = 64
        a = jnp.asarray(rng.integers(0, 8, n).astype(np.uint32))
        b = jnp.asarray(rng.integers(0, 8, n).astype(np.uint32))
        c = jnp.asarray(rng.integers(1, 8, n).astype(np.uint32))
        pa, pb, pc = (jnp.asarray(rng.integers(0, 8, n).astype(np.uint32))
                      for _ in range(3))
        pc = jnp.maximum(pc, 1)
        packed3 = lambda x, y, z: (x << 20) | (y << 10) | z
        for how in rjoin.HOW:
            res_c = rjoin.hash_join((a, b, c), (pa, pb, pc), 6 * n, how)
            res_p = rjoin.hash_join(packed3(a, b, c), packed3(pa, pb, pc),
                                    6 * n, how)
            assert_results_equal(res_c, res_p, f"3col {how}")


# ---------------------------------------------------------------------------
# group-by / distinct
# ---------------------------------------------------------------------------

class TestCompositeGroupBy:
    @pytest.mark.parametrize("agg", ["sum", "min", "max", "count", "mean"])
    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_parity_vs_packed(self, rng, agg, backend):
        n = 80
        kh, kl = two_cols(rng, n)
        vals = jnp.asarray(rng.integers(1, 1000, n).astype(np.uint32))
        tc = rgroupby.create(256, key_words=2, backend=backend)
        tp = rgroupby.create(256, key_words=1, backend=backend)
        tc, st_c = rgroupby.update(tc, agg, (kh, kl), vals)
        tp, st_p = rgroupby.update(tp, agg, packed_u32(kh, kl, 4), vals)
        # statuses are hash-placement independent (first occurrence claims)
        np.testing.assert_array_equal(np.asarray(st_c), np.asarray(st_p))
        out_c, f_c = rgroupby.lookup(tc, agg, (kh, kl))
        out_p, f_p = rgroupby.lookup(tp, agg, packed_u32(kh, kl, 4))
        np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))
        np.testing.assert_array_equal(np.asarray(f_c), np.asarray(f_p))
        assert int(tc.count) == int(tp.count)

    def test_aggregate_infers_and_finalize_unpacks(self, rng):
        n = 60
        kh, kl = two_cols(rng, n)
        vals = jnp.asarray(rng.integers(1, 100, n).astype(np.uint32))
        gk, out, live, table = rgroupby.aggregate((kh, kl), vals, 256, "sum")
        assert table.key_words == 2 and gk.shape[-1] == 2
        ghi, glo = hashing.unpack_columns(gk)
        got = {(int(h), int(l)): int(v)
               for h, l, v, lv in zip(ghi, glo, out, live) if lv}
        ref = {}
        for h, l, v in zip(np.asarray(kh), np.asarray(kl), np.asarray(vals)):
            ref[(int(h), int(l))] = ref.get((int(h), int(l)), 0) + int(v)
        assert got == ref

    def test_mask(self, rng):
        n = 50
        kh, kl = two_cols(rng, n)
        vals = jnp.asarray(rng.integers(1, 100, n).astype(np.uint32))
        mask = jnp.asarray(rng.random(n) < 0.6)
        _, out_c, live_c, tc = rgroupby.aggregate((kh, kl), vals, 256, "sum",
                                                  mask=mask)
        _, out_p, live_p, tp = rgroupby.aggregate(packed_u32(kh, kl, 4), vals,
                                                  256, "sum", mask=mask)
        o1, f1 = rgroupby.lookup(tc, "sum", (kh, kl))
        o2, f2 = rgroupby.lookup(tp, "sum", packed_u32(kh, kl, 4))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


class TestCompositeDistinct:
    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_parity_and_tuple_output(self, rng, backend):
        n = 90
        kh, kl = two_cols(rng, n)
        (uh, ul), n_c, fresh_c = rdistinct.distinct((kh, kl), n,
                                                    backend=backend)
        up, n_p, fresh_p = rdistinct.distinct(packed_u32(kh, kl, 4), n,
                                              backend=backend)
        np.testing.assert_array_equal(np.asarray(fresh_c),
                                      np.asarray(fresh_p))
        assert int(n_c) == int(n_p)
        np.testing.assert_array_equal(np.asarray(packed_u32(uh, ul, 4)),
                                      np.asarray(up))

    def test_mask_and_streaming(self, rng):
        n = 60
        kh, kl = two_cols(rng, n)
        mask = jnp.asarray(rng.random(n) < 0.7)
        (_, _), n_c, fresh_c = rdistinct.distinct((kh, kl), n, mask=mask)
        _, n_p, fresh_p = rdistinct.distinct(packed_u32(kh, kl, 4), n,
                                             mask=mask)
        np.testing.assert_array_equal(np.asarray(fresh_c),
                                      np.asarray(fresh_p))
        assert int(n_c) == int(n_p)
        # streaming across batches via first_occurrence
        dset = rdistinct.create(256, key_words=2)
        dset, f1 = rdistinct.first_occurrence(dset, (kh[:30], kl[:30]))
        dset, f2 = rdistinct.first_occurrence(dset, (kh[30:], kl[30:]))
        seen = set()
        ref = []
        for h, l in zip(np.asarray(kh), np.asarray(kl)):
            ref.append((int(h), int(l)) not in seen)
            seen.add((int(h), int(l)))
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(f1), np.asarray(f2)]),
            np.array(ref))


# ---------------------------------------------------------------------------
# core tables: single-value round trip, multi-value walks, hashset, pallas
# ---------------------------------------------------------------------------

class TestCompositeCoreTables:
    @pytest.mark.parametrize("backend", ["jax", "scan"])
    def test_single_value_round_trip(self, rng, backend):
        n = 70
        kh, kl = two_cols(rng, n, 6, 6)
        vals = jnp.arange(1, n + 1, dtype=_U)
        tc = sv.create(512, key_words=2, backend=backend)
        tp = sv.create(512, key_words=1, backend=backend)
        tc, st_c = sv.insert(tc, (kh, kl), vals)
        tp, st_p = sv.insert(tp, packed_u32(kh, kl, 4), vals)
        np.testing.assert_array_equal(np.asarray(st_c), np.asarray(st_p))
        v_c, f_c = sv.retrieve(tc, (kh, kl))
        v_p, f_p = sv.retrieve(tp, packed_u32(kh, kl, 4))
        np.testing.assert_array_equal(np.asarray(v_c), np.asarray(v_p))
        np.testing.assert_array_equal(np.asarray(f_c), np.asarray(f_p))
        tc, er_c = sv.erase(tc, (kh[:20], kl[:20]))
        tp, er_p = sv.erase(tp, packed_u32(kh[:20], kl[:20], 4))
        np.testing.assert_array_equal(np.asarray(er_c), np.asarray(er_p))
        assert int(tc.count) == int(tp.count)
        f_c = sv.contains(tc, (kh, kl))
        f_p = sv.contains(tp, packed_u32(kh, kl, 4))
        np.testing.assert_array_equal(np.asarray(f_c), np.asarray(f_p))

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_multi_value_walks_vs_scan(self, rng, backend):
        # duplicate composite pairs + tombstones; jax engine and the
        # 2-plane pallas fused-retrieve tile against the scan reference
        n = 150
        kh, kl = two_cols(rng, n, 3, 5)
        vals = jnp.arange(n, dtype=_U)
        q = (kh[:60], kl[:60])

        def run(bk):
            t = mv.create(1024, key_words=2, backend=bk)
            t, st = mv.insert(t, (kh, kl), vals)
            t, ec = mv.erase(t, (kh[:10], kl[:10]))
            cnt = mv.count_values(t, q)
            v, off, c = mv.retrieve_all(t, q, 800)
            return [np.asarray(x) for x in (st, ec, cnt, v, off, c)]

        ref = run("scan")
        got = run(backend)
        for i, (r, g) in enumerate(zip(ref, got)):
            np.testing.assert_array_equal(r, g, err_msg=f"{backend} out {i}")

    def test_hashset_composite(self, rng):
        n = 40
        kh, kl = two_cols(rng, n)
        s = hs.create(256, key_words=2)
        s, fresh = hs.add(s, (kh, kl))
        sp = hs.create(256, key_words=1)
        sp, fresh_p = hs.add(sp, packed_u32(kh, kl, 4))
        np.testing.assert_array_equal(np.asarray(fresh), np.asarray(fresh_p))
        np.testing.assert_array_equal(
            np.asarray(hs.contains(s, (kh, kl))),
            np.asarray(hs.contains(sp, packed_u32(kh, kl, 4))))
        assert int(hs.size(s)) == int(hs.size(sp))

    def test_jit_with_tuple_keys(self, rng):
        n = 32
        kh, kl = two_cols(rng, n)
        vals = jnp.arange(n, dtype=_U)
        t = sv.create(256, key_words=2)

        @jax.jit
        def go(t, a, b, v):
            t, st = sv.insert(t, (a, b), v)
            got, found = sv.retrieve(t, (a, b))
            return st, got, found

        st, got, found = go(t, kh, kl, vals)
        assert bool(found.all())
        # last-writer-wins per duplicate pair
        ref = {}
        for h, l, v in zip(np.asarray(kh), np.asarray(kl), np.asarray(vals)):
            ref[(int(h), int(l))] = int(v)
        want = np.array([ref[(int(h), int(l))]
                         for h, l in zip(np.asarray(kh), np.asarray(kl))])
        np.testing.assert_array_equal(np.asarray(got), want)
