"""Bucket-list store-protocol suite.

Two contracts:

1. **Engine parity** — `BucketListHashTable` on the batched engines
   (``backend="jax"`` build: sort/segment dedup + prefix-sum bucket
   allocator + scatter-arbitration handle claims; fused chain-walk
   retrieval over the pool slot arena) must be *bit-exact* against the
   sequential ``backend="scan"`` reference: identical key-store planes,
   handles, pool planes, alloc_top, live counts, per-element STATUS codes
   and (values, offsets, counts) retrievals — across duplicates, masks,
   growth schedules, multi-batch appends, pool exhaustion, key-store
   overflow, u64 keys and output truncation.  ``backend="pallas"`` runs
   the COPS bucket-walk tile through the same compaction.

2. **Store protocol** — `repro.core.layouts` exposes layouts as StoreOps
   objects (no string-kind dispatch left for consumers), including the
   slot-arena hook the fused engine rides.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_list as bl
from repro.core import layouts
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import (
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_POOL_FULL,
)


def _pair(**kw):
    return (bl.create(backend="jax", **kw), bl.create(backend="scan", **kw))


def assert_bl_equal(tb, ts, stb=None, sts=None):
    """Bit-exact: key-store planes (keys + packed handles), pool, top."""
    for pb, ps in zip(jax.tree_util.tree_leaves(tb.key_store.store),
                      jax.tree_util.tree_leaves(ts.key_store.store)):
        np.testing.assert_array_equal(np.asarray(pb), np.asarray(ps))
    np.testing.assert_array_equal(np.asarray(tb.pool), np.asarray(ts.pool))
    assert int(tb.alloc_top) == int(ts.alloc_top)
    assert int(tb.key_store.count) == int(ts.key_store.count)
    if stb is not None:
        np.testing.assert_array_equal(np.asarray(stb), np.asarray(sts))


def assert_retrieve_equal(tb, ts, q, cap):
    ob, os_ = bl.retrieve_all(tb, q, cap), bl.retrieve_all(ts, q, cap)
    for a, b, nm in zip(ob, os_, ("values", "offsets", "counts")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"retrieve_all {nm}")
    np.testing.assert_array_equal(np.asarray(bl.count_values(tb, q)),
                                  np.asarray(bl.count_values(ts, q)))


class TestInsertParity:
    @pytest.mark.parametrize("growth,s0", [(1.1, 1), (1.0, 4), (2.0, 1)])
    def test_duplicates_and_growth_schedules(self, growth, s0):
        rng = np.random.default_rng(0)
        keys = jnp.asarray(rng.integers(1, 20, 250, dtype=np.uint32))
        vals = jnp.arange(250, dtype=jnp.uint32)
        tb, ts = _pair(key_capacity=256, pool_capacity=4096,
                       growth=growth, s0=s0)
        tb, stb = bl.insert(tb, keys, vals)
        ts, sts = bl.insert(ts, keys, vals)
        assert_bl_equal(tb, ts, stb, sts)
        assert (np.asarray(stb) == STATUS_INSERTED).all()
        q = jnp.asarray(rng.integers(1, 30, 60, dtype=np.uint32))
        assert_retrieve_equal(tb, ts, q, 300)

    def test_masks(self):
        rng = np.random.default_rng(1)
        keys = jnp.asarray(rng.integers(1, 25, 200, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 2 ** 32 - 2, 200, dtype=np.uint32))
        mask = jnp.asarray(rng.random(200) < 0.7)
        tb, ts = _pair(key_capacity=256, pool_capacity=4096)
        tb, stb = bl.insert(tb, keys, vals, mask)
        ts, sts = bl.insert(ts, keys, vals, mask)
        assert_bl_equal(tb, ts, stb, sts)
        assert (np.asarray(stb)[~np.asarray(mask)] == STATUS_MASKED).all()

    def test_multi_batch_append_and_growth(self):
        """Later batches append to existing tails and grow chains — the
        in-batch/pre-existing bucket base-pointer split."""
        rng = np.random.default_rng(2)
        tb, ts = _pair(key_capacity=256, pool_capacity=8192)
        for b in range(4):
            keys = jnp.asarray(rng.integers(1, 15, 100, dtype=np.uint32))
            vals = jnp.arange(100, dtype=jnp.uint32) + 1000 * b
            tb, stb = bl.insert(tb, keys, vals)
            ts, sts = bl.insert(ts, keys, vals)
            assert_bl_equal(tb, ts, stb, sts)
        assert_retrieve_equal(tb, ts, jnp.arange(1, 16, dtype=jnp.uint32), 500)

    def test_pool_exhaustion(self):
        """Overflowing the pool mid-batch: the prefix-sum allocator must
        reproduce the sequential bump allocator's exact failure point and
        keep POOL_FULL statuses, handles and pool layout identical."""
        rng = np.random.default_rng(3)
        keys = jnp.asarray(rng.integers(1, 12, 150, dtype=np.uint32))
        vals = jnp.arange(150, dtype=jnp.uint32)
        tb, ts = _pair(key_capacity=512, pool_capacity=40, growth=1.5, s0=2)
        tb, stb = bl.insert(tb, keys, vals)
        ts, sts = bl.insert(ts, keys, vals)
        assert_bl_equal(tb, ts, stb, sts)
        assert (np.asarray(stb) == STATUS_POOL_FULL).any()
        assert_retrieve_equal(tb, ts, jnp.arange(1, 13, dtype=jnp.uint32), 60)

    def test_key_store_overflow(self):
        """Key store smaller than the distinct-key set: FULL statuses come
        from the engine's scatter arbitration and must match the scan."""
        rng = np.random.default_rng(4)
        keys = jnp.asarray(rng.permutation(
            np.arange(1, 200, dtype=np.uint32))[:150])
        vals = jnp.arange(150, dtype=jnp.uint32)
        tb, ts = _pair(key_capacity=8, pool_capacity=4096)
        tb, stb = bl.insert(tb, keys, vals)
        ts, sts = bl.insert(ts, keys, vals)
        assert_bl_equal(tb, ts, stb, sts)
        assert (np.asarray(stb) == STATUS_FULL).any()

    def test_u64_two_word_keys(self):
        rng = np.random.default_rng(5)
        kk = rng.integers(0, 2 ** 32 - 2, (60, 2), dtype=np.uint32)
        kk = np.concatenate([kk, kk[:20]])            # duplicates
        vals = jnp.arange(80, dtype=jnp.uint32)
        tb, ts = _pair(key_capacity=256, pool_capacity=2048, key_words=2)
        tb, stb = bl.insert(tb, jnp.asarray(kk), vals)
        ts, sts = bl.insert(ts, jnp.asarray(kk), vals)
        assert_bl_equal(tb, ts, stb, sts)
        assert_retrieve_equal(tb, ts, jnp.asarray(kk[:30]), 120)

    def test_empty_batch(self):
        tb, ts = _pair(key_capacity=64, pool_capacity=64)
        tb, stb = bl.insert(tb, jnp.zeros((0,), jnp.uint32),
                            jnp.zeros((0,), jnp.uint32))
        ts, sts = bl.insert(ts, jnp.zeros((0,), jnp.uint32),
                            jnp.zeros((0,), jnp.uint32))
        assert stb.shape == (0,)
        assert_bl_equal(tb, ts, stb, sts)

    @pytest.mark.parametrize("seed", range(6))
    def test_randomized_regimes(self, seed):
        r = np.random.default_rng(seed)
        n = int(r.integers(5, 150))
        keys = jnp.asarray(r.integers(1, int(r.integers(2, 40)), n,
                                      dtype=np.uint32))
        vals = jnp.asarray(r.integers(0, 2 ** 32 - 2, n, dtype=np.uint32))
        mask = (jnp.asarray(r.random(n) < 0.8)
                if r.random() < 0.5 else None)
        kw = dict(key_capacity=int(r.choice([64, 512])),
                  pool_capacity=int(r.choice([16, 64, 256, 4096])),
                  growth=float(r.choice([1.0, 1.1, 1.5, 2.0])),
                  s0=int(r.choice([1, 2, 4])),
                  window=int(r.choice([4, 16, 32])))
        tb, ts = _pair(**kw)
        for b in range(int(r.integers(1, 4))):
            tb, stb = bl.insert(tb, keys, vals + b, mask)
            ts, sts = bl.insert(ts, keys, vals + b, mask)
            assert_bl_equal(tb, ts, stb, sts)
        q = jnp.asarray(r.integers(1, 45, 30, dtype=np.uint32))
        assert_retrieve_equal(tb, ts, q, int(r.choice([5, 50, 500])))


class TestRetrieveParity:
    def _built(self, backend):
        rng = np.random.default_rng(6)
        keys = jnp.asarray(rng.integers(1, 20, 200, dtype=np.uint32))
        vals = jnp.arange(200, dtype=jnp.uint32)
        t = bl.create(256, pool_capacity=4096, backend=backend)
        t, _ = bl.insert(t, keys, vals)
        return t

    def test_truncation_and_misses(self):
        """out_capacity smaller than the total: the fused emit must drop
        exactly the same tail entries as the reference scatter."""
        tb, ts = self._built("jax"), self._built("scan")
        q = jnp.asarray([3, 3, 99, 7, 3, 12, 1000], jnp.uint32)  # dups+misses
        for cap in (0, 1, 7, 64, 400):
            assert_retrieve_equal(tb, ts, q, cap)

    def test_empty_query_batch(self):
        tb, ts = self._built("jax"), self._built("scan")
        assert_retrieve_equal(tb, ts, jnp.zeros((0,), jnp.uint32), 16)

    def test_pallas_bucket_walk_tile(self):
        """The COPS bucket-walk tile drives the same compaction."""
        rng = np.random.default_rng(7)
        keys = jnp.asarray(rng.integers(1, 20, 150, dtype=np.uint32))
        vals = jnp.arange(150, dtype=jnp.uint32)
        tp = bl.create(256, pool_capacity=2048, backend="pallas")
        ts = bl.create(256, pool_capacity=2048, backend="scan")
        tp, sp = bl.insert(tp, keys, vals)
        ts, ss = bl.insert(ts, keys, vals)
        assert_bl_equal(tp, ts, sp, ss)
        q = jnp.asarray(rng.integers(1, 25, 50, dtype=np.uint32))
        assert_retrieve_equal(tp, ts, q, 200)

    def test_for_each_rides_the_engine(self):
        t = self._built("jax")
        out = bl.for_each(t, jnp.asarray([3, 99], jnp.uint32),
                          lambda k, v, m: jnp.where(m, v, 0), max_values=32)
        ref, off, cnt = bl.retrieve_all(t, jnp.asarray([3], jnp.uint32), 32)
        assert int(out[0].sum()) == int(ref[: int(cnt[0])].sum())
        assert int(out[1].sum()) == 0


class TestStoreProtocol:
    """The layouts module is a protocol, not a string-dispatch switchboard."""

    def test_no_string_dispatch_surface(self):
        for fn in ("key_windows", "value_windows", "scatter_keys",
                   "scatter_values", "scatter_key_word", "tombstone_where",
                   "write_slot", "write_value"):
            assert not hasattr(layouts, fn), \
                f"string-kind free function layouts.{fn} resurfaced"

    def test_make_ops_cached_and_hashable(self):
        a = layouts.make_ops("soa", 11, 8, 1, 2)
        b = layouts.make_ops("soa", 11, 8, 1, 2)
        assert a is b and hash(a) == hash(b)
        assert a.planar and not layouts.make_ops("aos", 11, 8, 1, 2).planar
        with pytest.raises(ValueError):
            layouts.make_ops("packed", 11, 8, 2, 1)
        with pytest.raises(ValueError):
            layouts.make_ops("nope", 11, 8, 1, 1)

    @pytest.mark.parametrize("kind", layouts.LAYOUTS)
    def test_arena_values_matches_plane_view(self, kind):
        """The slot-arena hook gathers exactly the flat (row*W + lane)
        plane view — the contract the fused emit relies on."""
        rng = np.random.default_rng(8)
        t = sv.create(128, window=8, layout=kind)
        keys = jnp.asarray(rng.integers(1, 300, 100, dtype=np.uint32))
        t, _ = sv.insert(t, keys, keys * 3)
        ops = t.ops
        slots = jnp.asarray(rng.integers(0, ops.arena_capacity, 50))
        got = ops.arena_values(t.store, slots)
        vp = np.asarray(t.value_planes()).reshape(1, -1)
        np.testing.assert_array_equal(np.asarray(got)[:, 0], vp[0, slots])

    @pytest.mark.parametrize("kind", layouts.LAYOUTS)
    def test_arena_tombstone_flat_mask(self, kind):
        rng = np.random.default_rng(9)
        t = mv.create(128, window=8, layout=kind)
        keys = jnp.asarray(rng.integers(1, 30, 64, dtype=np.uint32))
        t, _ = mv.insert(t, keys, keys)
        occ = jnp.asarray(rng.random(t.ops.arena_capacity) < 0.5)
        store = t.ops.arena_tombstone(t.store, occ)
        kp = np.asarray(t.ops.key_planes(store)[0]).reshape(-1)
        from repro.core.common import TOMBSTONE_KEY
        assert (kp[np.asarray(occ)] == TOMBSTONE_KEY).all()

    def test_bucket_pool_as_slot_arena(self):
        """The bucket chain rides the same emit through its pool arena:
        chain_arena stamps exactly counts[i] slots per live query, ranked
        head-first."""
        rng = np.random.default_rng(10)
        keys = jnp.asarray(rng.integers(1, 10, 80, dtype=np.uint32))
        t = bl.create(128, pool_capacity=1024)
        t, _ = bl.insert(t, keys, jnp.arange(80, dtype=jnp.uint32))
        q = jnp.arange(1, 11, dtype=jnp.uint32)
        is_rep, rep_of, found, ptr, rcnt, bidx, counts = bl._handle_probe(t, q[:, None])
        qa, ra = bl.chain_arena(t, found, ptr, rcnt, bidx)
        qa, ra = np.asarray(qa), np.asarray(ra)
        for i in range(10):
            stamped = np.sort(ra[qa == i])
            assert stamped.shape[0] == int(rcnt[i])
            np.testing.assert_array_equal(stamped, np.arange(int(rcnt[i])))
