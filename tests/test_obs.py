"""Observability suite (docs/OBSERVABILITY.md).

Four gates:

1. **Stats parity** — every engine's *outputs* are bit-exact with
   ``stats=True`` vs ``stats=False``, across the jax / scan / pallas
   backends: telemetry must be a pure observer.
2. **Probe-histogram recount** — the in-graph probe-length histogram of a
   retrieval matches an independent python re-walk of the probe sequence
   against the same store (small tables, exhaustive).
3. **HLO identity** — with ``stats=False`` the compiled graph of bulk
   insert and fused retrieve is byte-identical to the default call (and
   the hlo_census byte/flop counts agree); ``stats=True`` must differ.
4. **Host-side plumbing** — registry counters/gauges/histograms, tracer
   spans + JSONL schema, report guards, BENCH schema validator.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_list as bl
from repro.core import counting, probing
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.core.common import EMPTY_KEY
from repro.launch import hlo_census
from repro.obs import metrics
from repro.obs import trace as obtrace
from repro.obs.registry import REGISTRY, Registry

from conftest import unique_keys

BACKENDS = ("jax", "scan", "pallas")


def _keys_vals(rng, n):
    ks = jnp.asarray(unique_keys(rng, n))
    return ks, ks ^ jnp.uint32(0x5A5A)


def _trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# 1. stats parity: outputs bit-exact with stats on/off, all backends
# ---------------------------------------------------------------------------

class TestStatsParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_single_value(self, rng, backend):
        keys, vals = _keys_vals(rng, 64)
        t0 = sv.create(128, window=8, backend=backend)
        t_off, s_off = jax.jit(lambda t, k, v: sv.insert(t, k, v))(
            t0, keys, vals)
        t_on, s_on, st = jax.jit(
            lambda t, k, v: sv.insert(t, k, v, stats=True))(t0, keys, vals)
        assert _trees_equal(t_off.store, t_on.store)
        assert bool(jnp.array_equal(s_off, s_on))
        assert int(jnp.sum(st.status_hist)) == 64
        assert int(st.live_slots) == 64

        r_off = jax.jit(lambda t, k: sv.retrieve(t, k))(t_off, keys)
        r_on = jax.jit(lambda t, k: sv.retrieve(t, k, stats=True))(
            t_on, keys)
        assert bool(jnp.array_equal(r_off[0], r_on[0]))
        assert bool(jnp.array_equal(r_off[1], r_on[1]))
        rst = r_on[2]
        assert int(rst.probe_n) == 64 and rst.mean_probe_len() >= 1.0

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_multi_value(self, rng, backend):
        keys, vals = _keys_vals(rng, 48)
        mkeys = jnp.concatenate([keys, keys[:16]])       # multiplicity 2 head
        mvals = jnp.arange(64, dtype=jnp.uint32)
        t0 = mv.create(192, window=8, backend=backend)
        t_off, s_off = jax.jit(lambda t, k, v: mv.insert(t, k, v))(
            t0, mkeys, mvals)
        t_on, s_on, _ = jax.jit(
            lambda t, k, v: mv.insert(t, k, v, stats=True))(t0, mkeys, mvals)
        assert _trees_equal(t_off.store, t_on.store)
        assert bool(jnp.array_equal(s_off, s_on))

        c_off = jax.jit(lambda t, k: mv.count_values(t, k))(t_off, keys)
        c_on, cst = jax.jit(
            lambda t, k: mv.count_values(t, k, stats=True))(t_on, keys)
        assert bool(jnp.array_equal(c_off, c_on))
        assert int(cst.probe_n) > 0

        cap = int(jnp.sum(c_off))
        r_off = jax.jit(lambda t, k: mv.retrieve_all(t, k, cap))(t_off, keys)
        r_on = jax.jit(lambda t, k: mv.retrieve_all(t, k, cap, stats=True))(
            t_on, keys)
        for a, b in zip(r_off, r_on[:3]):
            assert bool(jnp.array_equal(a, b))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_counting(self, rng, backend):
        keys = jnp.asarray(unique_keys(rng, 32))
        batch = jnp.concatenate([keys, keys, keys[:8]])
        t0 = counting.create(128, backend=backend)
        t_off, s_off = jax.jit(lambda t, k: counting.insert(t, k))(t0, batch)
        t_on, s_on, st = jax.jit(
            lambda t, k: counting.insert(t, k, stats=True))(t0, batch)
        assert _trees_equal(t_off.store, t_on.store)
        assert bool(jnp.array_equal(s_off, s_on))
        assert int(jnp.sum(st.status_hist)) == batch.shape[0]

        c_off = jax.jit(lambda t, k: counting.counts(t, k))(t_off, keys)
        c_on, _ = jax.jit(
            lambda t, k: counting.counts(t, k, stats=True))(t_on, keys)
        assert bool(jnp.array_equal(c_off, c_on))

    @pytest.mark.parametrize("backend", ("jax", "scan"))
    def test_bucket_list(self, rng, backend):
        keys = jnp.asarray(unique_keys(rng, 24))
        mkeys = jnp.concatenate([keys, keys[:12]])
        mvals = jnp.arange(36, dtype=jnp.uint32)
        t0 = bl.create(96, 256, window=8, backend=backend)
        t_off, s_off = jax.jit(lambda t, k, v: bl.insert(t, k, v))(
            t0, mkeys, mvals)
        t_on, s_on, st = jax.jit(
            lambda t, k, v: bl.insert(t, k, v, stats=True))(t0, mkeys, mvals)
        assert _trees_equal(t_off.key_store.store, t_on.key_store.store)
        assert bool(jnp.array_equal(t_off.pool, t_on.pool))
        assert bool(jnp.array_equal(s_off, s_on))
        assert int(st.live_slots) == 24

        c_off = jax.jit(lambda t, k: bl.count_values(t, k))(t_off, keys)
        c_on, _ = jax.jit(
            lambda t, k: bl.count_values(t, k, stats=True))(t_on, keys)
        assert bool(jnp.array_equal(c_off, c_on))

        cap = int(jnp.sum(c_off))
        r_off = jax.jit(lambda t, k: bl.retrieve_all(t, k, cap))(t_off, keys)
        r_on = jax.jit(lambda t, k: bl.retrieve_all(t, k, cap, stats=True))(
            t_on, keys)
        for a, b in zip(r_off, r_on[:3]):
            assert bool(jnp.array_equal(a, b))


# ---------------------------------------------------------------------------
# 2. probe-length histogram vs independent python recount
# ---------------------------------------------------------------------------

def _ref_probe_lengths(table, keys) -> np.ndarray:
    """Independent probe-length recount: replay each key's probe sequence
    in python against the store, counting windows until a match or an
    EMPTY-containing window (the walk's absence proof) — the same stop
    rule as ``bulk.probe_matches`` but none of its while-loop plumbing."""
    kb = sv.normalize_key_batch(keys, table.key_words, "keys")
    words = sv.key_hash_word(kb)
    num_rows = table.ops.num_rows
    row0 = np.asarray(probing.initial_row(words, num_rows, table.seed))
    step = np.asarray(probing.row_step(table.scheme, words, num_rows,
                                       table.seed))
    kb_np = np.asarray(kb)
    out = []
    for i in range(kb_np.shape[0]):
        row = np.uint32(row0[i])
        plen = 0
        for attempt in range(table.max_probes):
            win = np.asarray(table.ops.key_windows(
                table.store, jnp.asarray([row], jnp.uint32)))[0]   # (kw, W)
            plen += 1
            if bool((win == kb_np[i][:, None]).all(axis=0).any()):
                break
            if bool((win[0] == EMPTY_KEY).any()):
                break
            row = np.uint32(np.asarray(probing.advance_row(
                table.scheme, jnp.asarray([row], jnp.uint32),
                jnp.asarray([step[i]], jnp.uint32),
                jnp.asarray(attempt, jnp.int32), num_rows))[0])
        out.append(plen)
    return np.asarray(out, np.int32)


def _ref_hist(plens: np.ndarray) -> np.ndarray:
    edges = 2 ** np.arange(metrics.NUM_PROBE_BINS)
    b = np.searchsorted(edges, plens, side="left")
    return np.bincount(np.clip(b, 0, metrics.NUM_PROBE_BINS - 1),
                       minlength=metrics.NUM_PROBE_BINS).astype(np.int64)


class TestProbeHistRecount:
    @pytest.mark.parametrize("density", (0.5, 0.9))
    def test_retrieve_hist_matches_recount(self, rng, density):
        n = 48
        keys, vals = _keys_vals(rng, n)
        t = sv.create(int(n / density), window=4, max_probes=64)
        t, _ = sv.insert(t, keys, vals)
        missing = jnp.asarray(
            unique_keys(rng, 16, lo=0x7000_0000).astype(np.uint32))
        queries = jnp.concatenate([keys, missing])       # all distinct
        _, _, st = jax.jit(lambda tt, k: sv.retrieve(tt, k, stats=True))(
            t, queries)
        ref = _ref_probe_lengths(t, queries)
        assert int(st.probe_n) == queries.shape[0]
        assert int(st.probe_sum) == int(ref.sum())
        np.testing.assert_array_equal(np.asarray(st.probe_hist), _ref_hist(ref))
        # histogram-derived quantiles are upper bin edges of the recount
        assert st.probe_quantile(0.50) >= float(np.median(ref))

    def test_sparse_table_all_length_one(self, rng):
        # tiny load, wide windows: no bumping, so every key sits in the
        # first window of its probe sequence -> all probe lengths are 1
        keys = jnp.asarray(unique_keys(rng, 8))
        t = sv.create(1, window=32)
        t, _ = sv.insert(t, keys, keys)
        _, _, st = sv.retrieve(t, keys, stats=True)
        assert int(st.probe_hist[0]) == 8
        assert st.mean_probe_len() == 1.0


# ---------------------------------------------------------------------------
# 3. HLO identity: stats=False is byte-identical to the default graph
# ---------------------------------------------------------------------------

def _compiled_text(fn, *args) -> str:
    def entry(*a):                    # same jit name for every candidate
        return fn(*a)
    return jax.jit(entry).lower(*args).compile().as_text()


class TestHloIdentity:
    def test_bulk_insert_stats_off_identical(self, rng):
        keys, vals = _keys_vals(rng, 64)
        t0 = sv.create(128, window=8)
        default = _compiled_text(lambda t, k, v: sv.insert(t, k, v),
                                 t0, keys, vals)
        off = _compiled_text(lambda t, k, v: sv.insert(t, k, v, stats=False),
                             t0, keys, vals)
        on = _compiled_text(lambda t, k, v: sv.insert(t, k, v, stats=True),
                            t0, keys, vals)
        assert default == off                     # byte-identical HLO
        assert default != on                      # telemetry is real
        ca, cb = hlo_census.census(default), hlo_census.census(off)
        assert ca.bytes_moved == cb.bytes_moved and ca.flops == cb.flops

    def test_fused_retrieve_stats_off_identical(self, rng):
        keys, _ = _keys_vals(rng, 48)
        mkeys = jnp.concatenate([keys, keys[:16]])
        t0 = mv.create(192, window=8)
        t0, _ = mv.insert(t0, mkeys, jnp.arange(64, dtype=jnp.uint32))
        cap = int(jnp.sum(mv.count_values(t0, keys)))
        default = _compiled_text(lambda t, k: mv.retrieve_all(t, k, cap),
                                 t0, keys)
        off = _compiled_text(
            lambda t, k: mv.retrieve_all(t, k, cap, stats=False), t0, keys)
        on = _compiled_text(
            lambda t, k: mv.retrieve_all(t, k, cap, stats=True), t0, keys)
        assert default == off
        assert default != on
        ca, cb = hlo_census.census(default), hlo_census.census(off)
        assert ca.bytes_moved == cb.bytes_moved and ca.flops == cb.flops


# ---------------------------------------------------------------------------
# 4. host-side plumbing: registry / tracer / report / schema
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_histogram(self):
        r = Registry()
        r.counter("c").inc()
        r.counter("c").inc(4)
        r.gauge("g").set(2.5)
        for v in (0.1, 0.2, 0.3):
            r.histogram("h").record(v)
        snap = r.snapshot()
        assert snap["c"] == 5.0 and snap["g"] == 2.5
        assert snap["h"]["count"] == 3
        assert abs(r.histogram("h").percentile(50) - 0.2) < 1e-9
        assert "c: 5" in r.render()

    def test_kind_rebinding_raises(self):
        r = Registry()
        r.counter("x")
        with pytest.raises(TypeError):
            r.gauge("x")

    def test_tracer_values_are_noops_under_jit(self):
        r = Registry()

        @jax.jit
        def f(x):
            r.counter("jit.c").inc(x)          # x is a tracer here
            r.gauge("jit.g").set(x)
            return x + 1

        f(jnp.ones(()))
        assert r.counter("jit.c").value == 0.0
        assert np.isnan(r.gauge("jit.g").value)
        r.counter("jit.c").inc(jnp.asarray(3.0))   # concrete: records
        assert r.counter("jit.c").value == 3.0

    def test_kv_cache_counters(self):
        from repro.serving import kv_cache as pkv
        alloc0 = REGISTRY.counter("kv_cache.pages_allocated").value
        evict0 = REGISTRY.counter("kv_cache.pages_evicted").value
        c = pkv.create(num_layers=1, num_pages=16, page_size=4,
                       num_kv_heads=1, head_dim=4)
        seq = jnp.asarray([1, 2], jnp.int32)
        c, _, _ = pkv.allocate_pages(c, seq, jnp.zeros((2,), jnp.int32))
        c, _ = pkv.free_sequences(c, seq[:1], max_pages=2)
        assert REGISTRY.counter("kv_cache.pages_allocated").value == alloc0 + 2
        assert REGISTRY.counter("kv_cache.pages_evicted").value == evict0 + 1


class TestTracer:
    def test_spans_jsonl_and_percentiles(self, tmp_path):
        path = str(tmp_path / "trace.jsonl")
        tr = obtrace.Tracer(registry=Registry(), jsonl_path=path)
        with tr.span("unit.work", idx=0):
            pass
        with tr.span("unit.work", idx=1):
            pass
        tr.event("unit.marker", note=1)
        tr.close()
        events = obtrace.load_events(path)
        assert [e["event"] for e in events] == ["unit.work", "unit.work",
                                               "unit.marker"]
        for e in events:
            assert obtrace.is_event(e)
            obtrace.validate_event(e)
        p = tr.percentiles("unit.work")
        assert p["count"] == 2 and p["p50_s"] >= 0.0

    def test_disabled_tracer_is_silent(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        tr = obtrace.Tracer(registry=Registry(), jsonl_path=path,
                            enabled=False)
        with tr.span("nope"):
            pass
        tr.event("nope")
        tr.close()
        assert not (tmp_path / "t.jsonl").exists()

    def test_pipeline_stage_spans(self, tmp_path):
        from repro.data import pipeline as dp
        cfg = dp.DataConfig(vocab_size=64, seq_len=16, global_batch=4, seed=1)
        toks = dp.synthetic_batch(cfg, 0)["tokens"]
        table = counting.create(512)
        path = str(tmp_path / "pipe.jsonl")
        tr = obtrace.Tracer(registry=Registry(), jsonl_path=path)
        tracked = jnp.asarray([3, 7, 11], jnp.uint32)
        _, keep, hits = dp.relational_stage(table, toks, tracked, tracer=tr)
        tr.close()
        names = {e["event"] for e in obtrace.load_events(path)}
        assert names == {"pipeline.dedup", "pipeline.join",
                         "pipeline.aggregate"}
        # traced run computes the same outputs as the untraced one
        _, keep2, hits2 = dp.relational_stage(counting.create(512), toks,
                                              tracked)
        assert bool(jnp.array_equal(keep, keep2))
        assert bool(jnp.array_equal(hits, hits2))


class TestReportGuards:
    def test_load_skips_malformed_and_trace_lines(self, tmp_path):
        from repro.launch import report
        p = tmp_path / "recs.jsonl"
        lines = [
            {"arch": "a", "shape": "s", "mesh": "2x2", "kind": "fwd"},
            {"event": "serve.decode_step", "t_s": 0.0, "dur_s": 0.001},
            {"arch": "b"},                              # missing identity
            {"arch": "a", "shape": "s", "mesh": "4x4", "chips": 16,
             "compile_s": 1.0, "roofline": {
                 "flops_per_device": 1e9, "bytes_per_device": 1e6,
                 "wire_bytes": 0.0, "collectives": {},
                 "compute_s": 0.1, "memory_s": 0.2, "collective_s": 0.0,
                 "bottleneck": "memory", "model_flops": 1e9,
                 "useful_ratio": 0.5}},
        ]
        p.write_text("\n".join(json.dumps(r) for r in lines) + "\n")
        recs = report.load(str(p))
        assert len(recs) == 2
        assert report.meshes(recs) == ["2x2", "4x4"]
        # record without roofline/compile_s renders with placeholders
        table = report.dryrun_table(recs)
        assert "—" in table and "KeyError" not in table
        rt = report.roofline_table(recs, "4x4")
        assert "memory" in rt

    def test_table_metrics_section(self, tmp_path):
        from repro.launch import report
        bench = {"fig5": [
            {"name": "fig5.insert.wc-cops.rho0.5", "us_per_call": 10.0,
             "ops_per_s": 1e8, "probe_len_p50": 1.0, "probe_len_p99": 2.0,
             "load_factor": 0.5, "pct_of_roofline": 7.5, "spread": 0.05},
            {"name": "fig5.insert.pydict", "us_per_call": 50.0},
        ]}
        p = tmp_path / "BENCH_t.json"
        p.write_text(json.dumps(bench))
        sec = report.table_metrics_section(str(p))
        assert "fig5.insert.wc-cops.rho0.5" in sec
        assert "fig5.insert.pydict" not in sec          # no metric cols


class TestBenchSchema:
    def test_valid_bench_passes(self):
        from benchmarks import validate
        with open(validate.default_schema_path()) as f:
            schema = json.load(f)
        bench = {"fig5": [{"name": "r", "us_per_call": 1.0,
                           "ops_per_s": 2e6, "extra": "ok=1",
                           "load_factor": 0.9, "probe_len_p99": 4.0}]}
        assert validate.validate(bench, schema) == []

    def test_invalid_rows_fail(self):
        from benchmarks import validate
        with open(validate.default_schema_path()) as f:
            schema = json.load(f)
        missing = {"fig5": [{"us_per_call": 1.0}]}
        assert any("missing required" in e
                   for e in validate.validate(missing, schema))
        bad_type = {"fig5": [{"name": "r", "us_per_call": "fast"}]}
        assert any("expected number" in e
                   for e in validate.validate(bad_type, schema))
        bad_range = {"fig5": [{"name": "r", "us_per_call": 1.0,
                               "load_factor": 1.5}]}
        assert any("maximum" in e
                   for e in validate.validate(bad_range, schema))
        stray = {"fig5": [{"name": "r", "us_per_call": 1.0,
                           "custom": "not-a-number"}]}
        assert any("expected number" in e
                   for e in validate.validate(stray, schema))

    def test_parse_row_lifts_numeric_extras(self):
        from benchmarks.run import parse_row
        e = parse_row("fig5.x,12.5,8.00Mops/s,ok=1,probe_len_p99=4,note=abc")
        assert e["ops_per_s"] == 8e6
        assert e["probe_len_p99"] == 4.0
        assert "note" not in e and "note=abc" in e["extra"]


class TestServeLoopTraced:
    def test_generate_traced_records_latencies(self):
        from repro import configs
        from repro.models import model_zoo as zoo
        from repro.serving import serve_loop
        cfg = configs.get_smoke_config("smollm-360m")
        model = zoo.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        tr = obtrace.Tracer(registry=Registry())
        toks, tr = serve_loop.generate_traced(model, params, prompts, 5,
                                              tracer=tr)
        assert toks.shape == (2, 5)
        p = tr.percentiles("serve.decode_step")
        assert p["count"] == 5
        assert tr.percentiles("serve.prefill")["count"] == 1
        assert p["p50_s"] >= 0.0 and p["p99_s"] >= p["p50_s"]
        # traced decode == the scan-path generate (same sampling rule)
        import dataclasses as _dc
        ref = serve_loop.generate(_dc.replace(model, prefill=None), params,
                                  prompts, 5)
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
